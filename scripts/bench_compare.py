#!/usr/bin/env python
"""Regression gate over the repo's BENCH_*.json perf trajectory.

``benchmarks/run.py --json`` (and each bench's ``--json FILE``) records
one document per run; the committed ``BENCH_pr*.json`` series is the
repo's perf trajectory across PRs. This script joins those documents'
records on their identity — ``(kind, name)`` plus the schema-v2 axis
tuple (backend x gate x batch x devices x fuse_steps) — and compares
each series' **latest** point against its **previous** occurrence:

- ``us_per_call`` may not grow by more than ``--max-time-ratio`` x
  (wall timings; the default 2.0 tolerates machine-to-machine noise,
  CI's shared runners use a looser 5.0).
- efficiency ratios (``traffic_ratio``, ``sop_ratio`` — lower is
  better, these are arithmetic facts about gating, not timings) may not
  grow beyond ``max(prev * 1.10, prev + 0.02)``.
- ``overhead_frac`` (the observability tax measured by
  ``kernel_bench --obs-overhead``) must stay within
  ``--overhead-budget`` on EVERY record, not just the latest pair.
- ``counter_consistent`` (fused-kernel DMA-counter cross-checks) must
  be true on every record that carries it.

Schema-1 documents (PR 3-5, recorded before the axis contract) are
normalized on load by filling the missing axes with ``AXIS_DEFAULTS`` —
the same rule ``benchmarks/common.py`` applies at emit time for
schema >= 2. ``serve_snn --json-summary`` outputs (recognized by their
``meta`` + ``mode`` keys) join the trajectory too: each becomes one
``serve_summary`` record on its meta axes, so serving-throughput
regressions gate alongside kernel ones.

Exit status: 0 when every check passes, 1 otherwise (CI gate).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.bench_schema import AXIS_DEFAULTS, SCHEMA_VERSION  # noqa: E402

AXES = tuple(AXIS_DEFAULTS)

# lower-is-better arithmetic ratios: regressions here mean the event
# gate fetches/computes more than it used to, machine noise is no excuse
RATIO_METRICS = ("traffic_ratio", "sop_ratio")
RATIO_REL_SLACK = 0.10   # cur may exceed prev by 10%...
RATIO_ABS_SLACK = 0.02   # ...or 0.02 absolute, whichever is looser


def normalize_record(rec: dict) -> dict:
    """Fill the schema-v2 axis contract into a (possibly schema-1)
    record: every axis present, absent ones at their defaults."""
    out = dict(rec)
    for axis, default in AXIS_DEFAULTS.items():
        out.setdefault(axis, default)
    return out


def record_key(rec: dict) -> tuple:
    """The join identity: what makes two records the same measurement."""
    return ((rec.get("kind"), rec.get("name"))
            + tuple(rec.get(a) for a in AXES))


def _summary_records(doc: dict) -> list[dict]:
    """Synthesize bench records from one serve_snn --json-summary doc."""
    meta = doc["meta"]
    rec = {
        "kind": "serve_summary",
        "name": f"serve/{doc['mode']}",
        "info": f"serve_snn {doc['mode']} summary "
                f"@ {meta.get('git_commit') or 'unknown commit'}",
        **{a: meta["axes"].get(a, d) for a, d in AXIS_DEFAULTS.items()},
    }
    if doc.get("steps_per_s"):
        rec["steps_per_s"] = float(doc["steps_per_s"])
        rec["us_per_call"] = round(1e6 / float(doc["steps_per_s"]), 3)
    return [normalize_record(rec)]


def load_doc(source) -> tuple[str, list[dict]]:
    """Load one trajectory point: a BENCH_*.json document or a serve_snn
    --json-summary object. Returns (label, normalized records)."""
    if isinstance(source, (str, pathlib.Path)):
        label = pathlib.Path(source).name
        with open(source) as fh:
            doc = json.load(fh)
    else:
        label, doc = "<dict>", source
    if "results" in doc:  # a benchmarks/common.py document
        schema = doc.get("metadata", {}).get("schema")
        if schema is not None and schema > SCHEMA_VERSION:
            raise ValueError(
                f"{label}: schema {schema} is newer than this gate "
                f"understands ({SCHEMA_VERSION})")
        return label, [normalize_record(r) for r in doc["results"]]
    if "meta" in doc and "mode" in doc:  # a serve_snn summary
        return label, _summary_records(doc)
    raise ValueError(
        f"{label}: neither a bench document (no 'results') nor a "
        f"serve_snn summary (no 'meta'/'mode')")


def compare(trajectory, *, max_time_ratio: float = 2.0,
            overhead_budget: float = 0.05) -> list[dict]:
    """Run every check over a chronological list of (label, records).

    Returns one finding dict per check performed:
    ``{"key", "check", "prev", "cur", "limit", "ok", "detail"}``.
    """
    findings: list[dict] = []

    def add(key, check, prev, cur, limit, ok, detail):
        findings.append({"key": key, "check": check, "prev": prev,
                         "cur": cur, "limit": limit, "ok": bool(ok),
                         "detail": detail})

    # per-record invariants: hold at every point of the trajectory
    for label, records in trajectory:
        for rec in records:
            key = f"{label}:{rec['kind']}/{rec['name']}"
            if rec.get("overhead_frac") is not None:
                frac = float(rec["overhead_frac"])
                add(key, "overhead_frac", None, frac, overhead_budget,
                    frac <= overhead_budget,
                    f"observability overhead {frac:.1%} vs "
                    f"{overhead_budget:.0%} budget")
            if "counter_consistent" in rec:
                ok = bool(rec["counter_consistent"])
                add(key, "counter_consistent", None,
                    rec["counter_consistent"], True, ok,
                    "DMA counter cross-check")

    # trajectory regressions: latest occurrence vs the previous one
    series: dict[tuple, list] = {}
    for label, records in trajectory:
        for rec in records:
            series.setdefault(record_key(rec), []).append((label, rec))
    for rkey, occurrences in sorted(series.items(), key=str):
        if len(occurrences) < 2:
            continue
        (plabel, prev), (clabel, cur) = occurrences[-2], occurrences[-1]
        key = f"{rkey[0]}/{rkey[1]} [{plabel} -> {clabel}]"
        if (prev.get("us_per_call") or 0) and cur.get("us_per_call"):
            ratio = float(cur["us_per_call"]) / float(prev["us_per_call"])
            add(key, "us_per_call", prev["us_per_call"],
                cur["us_per_call"], max_time_ratio,
                ratio <= max_time_ratio,
                f"{ratio:.2f}x vs {max_time_ratio:.1f}x allowed")
        for metric in RATIO_METRICS:
            if prev.get(metric) is None or cur.get(metric) is None:
                continue
            p, c = float(prev[metric]), float(cur[metric])
            limit = max(p * (1 + RATIO_REL_SLACK), p + RATIO_ABS_SLACK)
            add(key, metric, p, c, round(limit, 4), c <= limit,
                f"{c:.4f} vs {limit:.4f} allowed (prev {p:.4f})")
    return findings


def render(findings: list[dict], *, verbose: bool = False) -> str:
    lines = []
    bad = [f for f in findings if not f["ok"]]
    for f in findings:
        if not f["ok"] or verbose:
            mark = "ok  " if f["ok"] else "FAIL"
            lines.append(f"{mark} {f['check']:<19} {f['key']}: "
                         f"{f['detail']}")
    n_time = sum(f["check"] == "us_per_call" for f in findings)
    lines.append(
        f"[bench-compare] {len(findings)} checks over the trajectory "
        f"({n_time} timing comparisons): "
        + (f"{len(bad)} FAILED" if bad else "all green"))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="BENCH_*.json perf-regression gate (exit 1 on any "
                    "threshold regression)")
    ap.add_argument("docs", nargs="+", metavar="FILE",
                    help="trajectory points in chronological order: "
                         "BENCH_*.json documents and/or serve_snn "
                         "--json-summary files")
    ap.add_argument("--max-time-ratio", type=float, default=2.0,
                    help="max allowed us_per_call growth, latest vs "
                         "previous occurrence (default 2.0; loosen on "
                         "noisy shared runners)")
    ap.add_argument("--overhead-budget", type=float, default=0.05,
                    help="max allowed obs_overhead overhead_frac on "
                         "every record (default 0.05)")
    ap.add_argument("--verbose", action="store_true",
                    help="print passing checks too, not just failures")
    args = ap.parse_args(argv)

    trajectory = [load_doc(p) for p in args.docs]
    findings = compare(trajectory, max_time_ratio=args.max_time_ratio,
                       overhead_budget=args.overhead_budget)
    print(render(findings, verbose=args.verbose))
    return 1 if any(not f["ok"] for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
