#!/usr/bin/env python
"""Docs CI checker: no dead links, no phantom or undocumented flags.

Two checks (the CI docs leg runs this; tests/test_docs.py runs it in
tier-1 too):

  1. **Links.** Every relative markdown link in README.md,
     ARCHITECTURE.md, docs/*.md, and benchmarks/README.md must resolve
     to an existing file, and every ``#anchor`` (same-file or
     cross-file) must match a real heading's GitHub-style slug.
     External (http/https/mailto) links are not fetched.
  2. **Flags.** docs/serving.md is the launcher flag reference: every
     ``--flag`` it documents must exist in the argparsers of
     ``repro.launch.serve_snn`` and ``benchmarks/kernel_bench.py``
     (no phantom flags), and every flag those parsers define must be
     documented there (no undocumented flags).
  3. **Metrics.** docs/observability.md is the metric reference: every
     backticked ``snn_*`` name it mentions must be registered in
     ``repro.obs.METRIC_SPECS`` (no phantom metrics), and every spec
     the registry defines must appear there (no undocumented metrics).

Prints each violation; exit code 0 when clean, 1 otherwise.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DOC_FILES = [
    "README.md",
    "ARCHITECTURE.md",
    "docs/serving.md",
    "docs/observability.md",
    "docs/glossary.md",
    "benchmarks/README.md",
]

FLAG_DOC = "docs/serving.md"
METRIC_DOC = "docs/observability.md"

_METRIC_RE = re.compile(r"`(snn_[a-z0-9_]+)`")

# markdown inline links: [text](target) — target up to the first ')' or
# whitespace (none of our docs use spaces or nested parens in targets)
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FLAG_RE = re.compile(r"(?<![\w-])--[a-z][a-z0-9-]*")
_FENCE_RE = re.compile(r"^(```|~~~)", re.M)


def strip_fences(text: str) -> str:
    """Remove fenced code blocks (their '#' lines are not headings and
    their contents are not markdown)."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop everything but word
    chars / spaces / hyphens, spaces -> hyphens."""
    h = re.sub(r"[`*_]", "", heading.strip()).lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def heading_slugs(md_path: Path) -> set[str]:
    slugs: set[str] = set()
    for line in strip_fences(md_path.read_text()).splitlines():
        m = re.match(r"#{1,6}\s+(.*)", line)
        if m:
            slugs.add(slugify(m.group(1)))
    return slugs


def check_links(doc_files=DOC_FILES, repo: Path = REPO) -> list[str]:
    """Dead relative links / anchors across the doc set."""
    problems = []
    for rel in doc_files:
        md = repo / rel
        if not md.exists():
            problems.append(f"{rel}: documentation file missing")
            continue
        for target in _LINK_RE.findall(strip_fences(md.read_text())):
            if re.match(r"[a-z][a-z0-9+.-]*:", target):  # http:, mailto:
                continue
            path_part, _, anchor = target.partition("#")
            dest = md if not path_part else (
                md.parent / path_part).resolve()
            if not dest.exists():
                problems.append(f"{rel}: dead link -> {target}")
                continue
            if anchor and dest.suffix == ".md":
                if slugify(anchor) not in heading_slugs(dest):
                    problems.append(
                        f"{rel}: dead anchor -> {target} "
                        f"(no such heading in {dest.name})")
    return problems


def parser_flag_sets(repo: Path = REPO) -> dict[str, set[str]]:
    """{launcher name: set of --flags} from the real argparsers."""
    for p in (str(repo / "src"), str(repo)):
        if p not in sys.path:
            sys.path.insert(0, p)
    from benchmarks.kernel_bench import build_parser as bench_parser
    from repro.launch.serve_snn import build_parser as serve_parser

    flags: dict[str, set[str]] = {}
    for name, build in (("repro.launch.serve_snn", serve_parser),
                        ("benchmarks/kernel_bench.py", bench_parser)):
        opts: set[str] = set()
        for action in build()._actions:
            opts.update(o for o in action.option_strings
                        if o.startswith("--") and o != "--help")
        flags[name] = opts
    return flags


def check_flags(doc_text: str, parser_flags: dict[str, set[str]],
                doc_name: str = FLAG_DOC) -> list[str]:
    """Two-way flag sync, scoped per launcher section.

    A ``##`` section whose heading names a launcher (by basename, e.g.
    ``serve_snn``) must document exactly that launcher's flags: flags it
    mentions must exist in THAT parser (a kernel_bench-only flag in the
    serve_snn table is a violation, not a pass-by-union), and every flag
    the parser defines must appear in the section. Flags mentioned
    outside any launcher section must exist in at least one parser; a
    launcher with no dedicated section falls back to
    anywhere-in-the-doc coverage.
    """
    problems = []
    known = set().union(*parser_flags.values())
    documented_anywhere = set(_FLAG_RE.findall(doc_text))
    base_of = {re.sub(r"\.py$", "", n).replace("/", ".").split(".")[-1]: n
               for n in parser_flags}
    parts = re.split(r"^(##\s+.*)$", doc_text, flags=re.M)
    section_flags: dict[str, set[str]] = {}
    loose = set(_FLAG_RE.findall(parts[0]))
    for head, body in zip(parts[1::2], parts[2::2]):
        owner = next((n for b, n in base_of.items() if b in head), None)
        flags = set(_FLAG_RE.findall(body))
        if owner is None:
            loose |= flags
        else:
            section_flags.setdefault(owner, set()).update(flags)
    problems += [f"{doc_name}: phantom flag {f} (no launcher defines it)"
                 for f in sorted(loose - known)]
    for launcher, flags in sorted(parser_flags.items()):
        doc_flags = section_flags.get(launcher)
        if doc_flags is None:
            missing = flags - documented_anywhere
        else:
            problems += [
                f"{doc_name}: {launcher} section documents {f}, which "
                f"that launcher does not define"
                for f in sorted(doc_flags - flags)]
            missing = flags - doc_flags
        problems += [f"{doc_name}: {launcher} flag {f} is undocumented"
                     for f in sorted(missing)]
    return problems


def registry_metric_names(repo: Path = REPO) -> set[str]:
    """Every metric name the registry catalogue defines."""
    p = str(repo / "src")
    if p not in sys.path:
        sys.path.insert(0, p)
    from repro.obs import METRIC_SPECS
    return set(METRIC_SPECS)


def check_metrics(doc_text: str, registry_names: set[str],
                  doc_name: str = METRIC_DOC) -> list[str]:
    """Two-way metric-name sync between the docs table and the
    registry catalogue. Fenced code blocks are ignored (exposition
    examples show derived ``_bucket``/``_sum`` series, not families)."""
    documented = set(_METRIC_RE.findall(strip_fences(doc_text)))
    problems = [f"{doc_name}: documents {m}, which the registry does "
                f"not define" for m in sorted(documented - registry_names)]
    problems += [f"{doc_name}: registry metric {m} is undocumented"
                 for m in sorted(registry_names - documented)]
    return problems


def main() -> int:
    problems = check_links()
    flag_doc = REPO / FLAG_DOC
    if flag_doc.exists():
        problems += check_flags(flag_doc.read_text(), parser_flag_sets())
    else:
        problems.append(f"{FLAG_DOC}: flag reference missing")
    metric_doc = REPO / METRIC_DOC
    if metric_doc.exists():
        problems += check_metrics(metric_doc.read_text(),
                                  registry_metric_names())
    else:
        problems.append(f"{METRIC_DOC}: metric reference missing")
    for p in problems:
        print(f"[check-docs] {p}")
    if problems:
        print(f"[check-docs] FAILED: {len(problems)} problem(s)")
        return 1
    n = len(DOC_FILES)
    print(f"[check-docs] OK: {n} docs, links + launcher flag reference "
          f"+ metric reference all verified")
    return 0


if __name__ == "__main__":
    sys.exit(main())
