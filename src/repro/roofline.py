"""Roofline accounting (TPU v5e targets).

Terms per (arch, shape, mesh), derived from the compiled dry-run artifact
(EXPERIMENTS.md §Roofline):

    compute_s    = HLO_flops_per_device / PEAK_FLOPS
    memory_s     = HLO_bytes_per_device / HBM_BW
    collective_s = collective_wire_bytes_per_device / ICI_BW

``cost_analysis()`` on a post-SPMD executable reports PER-DEVICE flops and
bytes. Collective bytes are not in cost_analysis: we parse the post-SPMD
HLO text and sum operand bytes per collective kind, weighting all-reduce
x2 (ring reduce-scatter + all-gather traffic).

MODEL_FLOPS sanity term: 6*N*D for dense training (3 matmul passes), 2*N*D
for inference-prefill, 2*N_active per token for decode; the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch overhead (< 1 means the
compiled graph does extra/redundant work, e.g. recompute; ~0.5 with full
remat of every matmul).
"""

from __future__ import annotations

import re

import numpy as np

# TPU v5e hardware constants (per chip), as specified for this evaluation.
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9_]+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,128]' or '(f32[2], f32[4,4])' -> total bytes."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",") if d]))
        total += n * nbytes
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum collective RESULT bytes per kind from post-SPMD HLO.

    Wire-cost weighting (ring algorithms, per device):
      all-reduce       2x size   (reduce-scatter + all-gather phases)
      all-gather       1x result (each device receives size*(n-1)/n ~ 1x)
      reduce-scatter   1x operand ~ result*n ... we charge the RESULT size
                       times 1 for rs (bytes received), matching ag.
      all-to-all       1x
      collective-permute 1x
    """
    counts: dict[str, int] = {}
    bytes_: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        counts[kind] = counts.get(kind, 0) + 1
        bytes_[kind] = bytes_.get(kind, 0.0) + b
    weights = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}
    total = sum(bytes_[k] * weights.get(k, 1.0) for k in bytes_)
    return {
        "counts": counts,
        "bytes_by_kind": {k: float(v) for k, v in bytes_.items()},
        "total_bytes": float(total),
    }


def model_flops(cfg, shape, n_chips: int) -> float:
    """Useful-work FLOPs for the whole step, by the 6ND/2ND convention."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def roofline_terms(*, flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float, cfg, shape,
                   n_chips: int, n_micro: int = 1) -> dict:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get).replace("_s", "")
    mf = model_flops(cfg, shape, n_chips)
    mf_per_device = mf / n_chips
    return {
        **terms,
        "dominant": dominant,
        "model_flops_total": mf,
        "model_flops_per_device": mf_per_device,
        "useful_flops_ratio": (mf_per_device / flops_per_device
                               if flops_per_device else 0.0),
        "bound_s": max(terms.values()),
        # fraction of the roofline-limited time doing useful math
        "roofline_fraction": (
            (mf_per_device / PEAK_FLOPS) / max(terms.values())
            if max(terms.values()) > 0 else 0.0),
    }
