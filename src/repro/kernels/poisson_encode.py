"""Pallas TPU kernel: hardware rate encoder (Coding Hardware Unit).

The SoC's encoder turns sensor intensities in [0,1] into Bernoulli spike
trains. The ASIC uses an LFSR; we use a counter-based murmur-finalizer hash
over (seed, timestep, batch, dim) — a pure function, so the kernel and the
pure-jnp oracle (ref.hash_u32_ref) are bit-identical, and encoding is
reproducible across shardings (each position derives its own randomness,
no sequential state). All ops are plain uint32 arithmetic: interpret-safe
on CPU, VPU-native on TPU (no pltpu.prng_* dependency).

Grid: (T, batch_tiles); each step emits a (block_batch, D) spike block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["poisson_encode_kernel", "build_poisson_encode"]

_PRIME_T = 0x9E3779B1
_PRIME_B = 0x85EBCA77
_PRIME_D = 0xC2B2AE3D


def _mix(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def poisson_encode_kernel(seed_ref, intens_ref, out_ref, *,
                          block_batch: int):
    t = pl.program_id(0)
    bt = pl.program_id(1)
    intens = intens_ref[...]  # (block_batch, D) float32
    D = intens.shape[1]
    b_idx = (jax.lax.broadcasted_iota(jnp.uint32, (block_batch, D), 0)
             + jnp.uint32(bt * block_batch))
    d_idx = jax.lax.broadcasted_iota(jnp.uint32, (block_batch, D), 1)
    h = (seed_ref[0].astype(jnp.uint32)
         ^ (jnp.uint32(t) * jnp.uint32(_PRIME_T))
         ^ (b_idx * jnp.uint32(_PRIME_B))
         ^ (d_idx * jnp.uint32(_PRIME_D)))
    h = _mix(h)
    intens = jnp.clip(intens, 0.0, 1.0)
    thr = jnp.minimum(intens * jnp.float32(4294967296.0),
                      jnp.float32(4294967040.0)).astype(jnp.uint32)
    fire = (h < thr) | (intens >= 1.0)
    out_ref[...] = fire.astype(jnp.int32)[None]


def build_poisson_encode(batch: int, dim: int, num_steps: int, *,
                         block_batch: int = 8, interpret: bool = False):
    """Build fn(seed_arr, intensities) -> (T, batch, dim) int32 spikes.

    seed_arr: (1,) int32; intensities: (batch, dim) f32, batch pre-padded
    to a multiple of block_batch, dim to a multiple of 128.
    """
    if batch % block_batch or dim % 128:
        raise ValueError("shapes must be pre-padded (batch | dim)")
    nb = batch // block_batch
    kernel = functools.partial(poisson_encode_kernel,
                               block_batch=block_batch)
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_steps, nb),
        in_specs=[
            pl.BlockSpec((block_batch, dim), lambda t, b, seed: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_batch, dim),
                               lambda t, b, seed: (t, b, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_steps, batch, dim), jnp.int32),
        interpret=interpret,
    )
