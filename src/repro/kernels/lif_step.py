"""Pallas TPU kernel: fused hardware LIF update (decay+integrate+fire+reset).

One HBM pass over the membrane-potential state: reads V and the accumulated
synaptic input, writes V' and the spike raster. On the ASIC this is the
Potential-Decay Unit + Potential-Adder Unit pair (paper Fig. 4); fusing the
four stages keeps V resident in VMEM/VREGs instead of three round trips.

Tiling: elementwise over a (block_rows, block_cols) grid; blocks are VPU
aligned (rows multiple of 8, cols multiple of 128). All arithmetic is int32
(shift decay, wrapping adds) — bit-exact vs ref.lif_step_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401  (TPU target)

from repro.kernels.epilogue import decay_and_fire, validate_decay

__all__ = ["lif_step_kernel", "build_lif_step"]


def lif_step_kernel(v_ref, syn_ref, vout_ref, spk_ref, *, decay_rate: float,
                    threshold_raw: int, reset_mode: str):
    vout, spikes = decay_and_fire(
        v_ref[...], syn_ref[...],
        decay_kind="shift", decay_rate=decay_rate, decay_raw=0,
        threshold_raw=threshold_raw, reset_mode=reset_mode,
    )
    vout_ref[...] = vout
    spk_ref[...] = spikes


def build_lif_step(shape, *, decay_rate: float, threshold_raw: int,
                   reset_mode: str, block_rows: int = 256,
                   block_cols: int = 1024, interpret: bool = False):
    """Build a pallas_call for a (rows, cols) int32 LIF update.

    Caller guarantees rows % block_rows == 0 and cols % block_cols == 0
    (ops.py pads). Returns fn(v, syn) -> (v_out, spikes).
    """
    validate_decay("shift", decay_rate, 0)
    rows, cols = shape
    block_rows = min(block_rows, rows)
    block_cols = min(block_cols, cols)
    if rows % block_rows or cols % block_cols:
        raise ValueError(f"{shape} not divisible by block "
                         f"({block_rows},{block_cols})")
    grid = (rows // block_rows, cols // block_cols)
    spec = pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))
    kernel = functools.partial(
        lif_step_kernel,
        decay_rate=decay_rate,
        threshold_raw=threshold_raw,
        reset_mode=reset_mode,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(shape, jnp.int32),
            jax.ShapeDtypeStruct(shape, jnp.int32),
        ],
        interpret=interpret,
    )
