"""Public jitted wrappers around the Pallas kernels.

Responsibilities: shape padding to hardware-aligned blocks, activity-bitmap
computation for the event gate, platform dispatch (interpret=True on CPU so
the kernel bodies are validated everywhere; compiled Mosaic on TPU), and
un-padding of results. These are the functions the rest of the framework
calls; nothing else should touch pallas_call directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import lif_step as _lif
from repro.kernels import poisson_encode as _enc
from repro.kernels import spike_timestep as _ts

__all__ = ["lif_step", "spike_timestep", "poisson_encode", "on_cpu"]


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, axis: int, multiple: int, value=0):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


# --------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("decay_rate", "threshold_raw", "reset_mode",
                     "interpret"),
)
def lif_step(v, syn, *, decay_rate: float, threshold_raw: int,
             reset_mode: str = "zero", interpret: bool | None = None):
    """Fused LIF update. v, syn: (B, N) int32 -> (v_out, spikes)."""
    interpret = on_cpu() if interpret is None else interpret
    B, N = v.shape
    vp = _pad_to(_pad_to(v, 0, 8), 1, 128)
    sp = _pad_to(_pad_to(syn, 0, 8), 1, 128)
    rows, cols = vp.shape
    fn = _lif.build_lif_step(
        (rows, cols),
        decay_rate=decay_rate,
        threshold_raw=threshold_raw,
        reset_mode=reset_mode,
        block_rows=min(256, rows),
        block_cols=min(1024, cols),
        interpret=interpret,
    )
    v_out, spikes = fn(vp, sp)
    return v_out[:B, :N], spikes[:B, :N]


# --------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("decay_rate", "threshold_raw", "reset_mode",
                     "decay_kind", "decay_raw",
                     "use_mxu", "block_batch", "block_src", "interpret"),
)
def spike_timestep(sources, weights, v, *, decay_rate: float = 0.0,
                   threshold_raw: int, reset_mode: str = "zero",
                   decay_kind: str = "shift", decay_raw: int = 0,
                   use_mxu: bool = False, block_batch: int = 8,
                   block_src: int = 128, interpret: bool | None = None):
    """One fused, event-gated accelerator timestep.

    sources: (B, S) int/bool spikes; weights: (S, P) int32 raw Q16.16;
    v: (B, P) int32. Returns (v_out, spikes_out), each (B, P) int32.

    ``decay_kind='shift'`` (default) applies the Cerebra-H shift decay of
    ``decay_rate``; ``decay_kind='mul'`` applies the Cerebra-S fixed-point
    multiply by the raw Q16.16 retain factor ``decay_raw``.

    ``use_mxu=False`` (default) is bit-exact. ``use_mxu=True`` runs the
    accumulate on the MXU in f32 — exact only while per-output partial sums
    stay below 2^24 (fine for |w| <~ 1.0 Q16.16 and fan-in <= 256; the SNN
    trainer's weight clip guarantees it). The SpikeEngine enforces this
    bound from weight stats before selecting the mode.
    """
    interpret = on_cpu() if interpret is None else interpret
    B, S = sources.shape
    P = weights.shape[1]
    sources = sources.astype(jnp.int32)
    src_p = _pad_to(_pad_to(sources, 0, block_batch), 1, block_src)
    w_p = _pad_to(_pad_to(weights, 0, block_src), 1, 128)
    v_p = _pad_to(_pad_to(v, 0, block_batch), 1, 128)
    Bp, Sp = src_p.shape
    Pp = w_p.shape[1]
    nb, ns = Bp // block_batch, Sp // block_src
    # Per-(example, source-block) activity scalars — the Incoming
    # Forwarder's event ledger. The kernel gate consumes one scalar per
    # (batch tile, source block): with block_batch == 1 (the per-example
    # gate, SpikeEngine gate="per-example") the tile map IS the
    # per-example map and every silent (example, block) pair skips its
    # weight fetch; larger tiles OR their examples' rows together.
    per_example = (
        src_p.reshape(Bp, ns, block_src).sum(axis=2).astype(jnp.int32)
    )
    activity = per_example.reshape(nb, block_batch, ns).sum(axis=1)
    fn = _ts.build_spike_timestep(
        Bp, Sp, Pp,
        decay_rate=decay_rate,
        threshold_raw=threshold_raw,
        reset_mode=reset_mode,
        decay_kind=decay_kind,
        decay_raw=decay_raw,
        block_batch=block_batch,
        block_src=block_src,
        use_mxu=use_mxu,
        interpret=interpret,
    )
    v_out, spikes = fn(activity, src_p, w_p, v_p)
    return v_out[:B, :P], spikes[:B, :P]


# --------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("num_steps", "block_batch", "interpret")
)
def poisson_encode(seed, intensities, num_steps: int, *,
                   block_batch: int = 8, interpret: bool | None = None):
    """Hardware rate encoder. intensities: (B, D) f32 -> (T, B, D) i32."""
    interpret = on_cpu() if interpret is None else interpret
    B, D = intensities.shape
    x = _pad_to(_pad_to(intensities.astype(jnp.float32), 0, block_batch),
                1, 128)
    Bp, Dp = x.shape
    fn = _enc.build_poisson_encode(
        Bp, Dp, num_steps, block_batch=block_batch, interpret=interpret
    )
    seed_arr = jnp.asarray([seed], jnp.int32).reshape(1)
    out = fn(seed_arr, x)
    return out[:, :B, :D]
