"""Public jitted wrappers around the Pallas kernels.

Responsibilities: shape padding to hardware-aligned blocks, activity-bitmap
computation for the event gate, platform dispatch (interpret=True on CPU so
the kernel bodies are validated everywhere; compiled Mosaic on TPU), and
un-padding of results. These are the functions the rest of the framework
calls; nothing else should touch pallas_call directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bitpack
from repro.kernels import lif_step as _lif
from repro.kernels import poisson_encode as _enc
from repro.kernels import spike_timestep as _ts

__all__ = [
    "lif_step",
    "spike_timestep",
    "spike_timestep_fused",
    "ext_gate_activity",
    "poisson_encode",
    "on_cpu",
]


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, axis: int, multiple: int, value=0):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


# --------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("decay_rate", "threshold_raw", "reset_mode",
                     "interpret"),
)
def lif_step(v, syn, *, decay_rate: float, threshold_raw: int,
             reset_mode: str = "zero", interpret: bool | None = None):
    """Fused LIF update. v, syn: (B, N) int32 -> (v_out, spikes)."""
    interpret = on_cpu() if interpret is None else interpret
    B, N = v.shape
    vp = _pad_to(_pad_to(v, 0, 8), 1, 128)
    sp = _pad_to(_pad_to(syn, 0, 8), 1, 128)
    rows, cols = vp.shape
    fn = _lif.build_lif_step(
        (rows, cols),
        decay_rate=decay_rate,
        threshold_raw=threshold_raw,
        reset_mode=reset_mode,
        block_rows=min(256, rows),
        block_cols=min(1024, cols),
        interpret=interpret,
    )
    v_out, spikes = fn(vp, sp)
    return v_out[:B, :N], spikes[:B, :N]


# --------------------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("decay_rate", "threshold_raw", "reset_mode",
                     "decay_kind", "decay_raw",
                     "use_mxu", "block_batch", "block_src", "interpret"),
)
def spike_timestep(sources, weights, v, *, decay_rate: float = 0.0,
                   threshold_raw: int, reset_mode: str = "zero",
                   decay_kind: str = "shift", decay_raw: int = 0,
                   use_mxu: bool = False, block_batch: int = 8,
                   block_src: int = 128, interpret: bool | None = None):
    """One fused, event-gated accelerator timestep.

    sources: (B, S) int/bool spikes; weights: (S, P) int32 raw Q16.16;
    v: (B, P) int32. Returns (v_out, spikes_out), each (B, P) int32.

    ``decay_kind='shift'`` (default) applies the Cerebra-H shift decay of
    ``decay_rate``; ``decay_kind='mul'`` applies the Cerebra-S fixed-point
    multiply by the raw Q16.16 retain factor ``decay_raw``.

    ``use_mxu=False`` (default) is bit-exact. ``use_mxu=True`` runs the
    accumulate on the MXU in f32 — exact only while per-output partial sums
    stay below 2^24 (fine for |w| <~ 1.0 Q16.16 and fan-in <= 256; the SNN
    trainer's weight clip guarantees it). The SpikeEngine enforces this
    bound from weight stats before selecting the mode.
    """
    interpret = on_cpu() if interpret is None else interpret
    B, S = sources.shape
    P = weights.shape[1]
    sources = sources.astype(jnp.int32)
    src_p = _pad_to(_pad_to(sources, 0, block_batch), 1, block_src)
    w_p = _pad_to(_pad_to(weights, 0, block_src), 1, 128)
    v_p = _pad_to(_pad_to(v, 0, block_batch), 1, 128)
    Bp, Sp = src_p.shape
    Pp = w_p.shape[1]
    nb, ns = Bp // block_batch, Sp // block_src
    # Per-(example, source-block) activity scalars — the Incoming
    # Forwarder's event ledger, popcounted over bitpacked lanes (4 u32
    # lanes per 128-source block instead of a 128-wide integer sum). The
    # kernel gate consumes one scalar per (batch tile, source block): with
    # block_batch == 1 (the per-example gate, SpikeEngine
    # gate="per-example") the tile map IS the per-example map and every
    # silent (example, block) pair skips its weight fetch; larger tiles OR
    # their examples' rows together.
    if block_src % bitpack.LANE_BITS == 0:
        per_example = bitpack.block_activity(
            bitpack.pack_spikes(src_p), block_src
        )  # (Bp, ns)
    else:  # non-lane-aligned block (never the kernels' default 128)
        per_example = (
            src_p.reshape(Bp, ns, block_src).sum(axis=2).astype(jnp.int32)
        )
    activity = per_example.reshape(nb, block_batch, ns).sum(axis=1)
    fn = _ts.build_spike_timestep(
        Bp, Sp, Pp,
        decay_rate=decay_rate,
        threshold_raw=threshold_raw,
        reset_mode=reset_mode,
        decay_kind=decay_kind,
        decay_raw=decay_raw,
        block_batch=block_batch,
        block_src=block_src,
        use_mxu=use_mxu,
        interpret=interpret,
    )
    v_out, spikes = fn(activity, src_p, w_p, v_p)
    return v_out[:B, :P], spikes[:B, :P]


# --------------------------------------------------------------------------
def _fused_pad(ext, spikes_prev, weights, v, active, *, n_inputs,
               block_batch, block_src):
    """Pad every fused-kernel operand to its block multiples.

    Returns the padded operands plus the original (B, P) for un-padding.
    The weight image splits at ``n_inputs``: external rows pad to
    ``block_src`` multiples (the DMA'd blocks), recurrent rows/columns and
    the carries pad together to the 128/block_src-aligned physical axis so
    feedback stays square.
    """
    K, B, _ = ext.shape
    P = weights.shape[1]
    w_ext = weights[:n_inputs]
    w_rec = weights[n_inputs:]
    ext_p = _pad_to(_pad_to(ext.astype(jnp.int32), 1, block_batch),
                    2, block_src)
    v_p = _pad_to(_pad_to(v, 0, block_batch), 1, 128)
    v_p = _pad_to(v_p, 1, block_src)
    spk_p = _pad_to(_pad_to(spikes_prev, 0, block_batch), 1, 128)
    spk_p = _pad_to(spk_p, 1, block_src)
    act_p = _pad_to(active.astype(jnp.int32), 1, block_batch)
    Pp = v_p.shape[1]
    w_ext_p = _pad_to(_pad_to(w_ext, 0, block_src), 1, 128)
    w_ext_p = _pad_to(w_ext_p, 1, block_src)
    # recurrent rows and columns pad together to (Pp, Pp) with zeros —
    # pad neurons have no fan-in and no fan-out, so feedback stays square
    w_rec_p = jnp.zeros((Pp, Pp), jnp.int32).at[:P, :P].set(w_rec)
    if ext_p.shape[2] == 0:  # n_inputs == 0: keep one silent block
        ext_p = jnp.zeros((K, ext_p.shape[1], block_src), jnp.int32)
        w_ext_p = jnp.zeros((block_src, Pp), jnp.int32)
    return ext_p, spk_p, w_ext_p, w_rec_p, v_p, act_p, B, P


@functools.partial(
    jax.jit,
    static_argnames=("n_inputs", "decay_rate", "threshold_raw",
                     "reset_mode", "decay_kind", "decay_raw",
                     "use_mxu", "block_batch", "block_src", "interpret"),
)
def spike_timestep_fused(ext, spikes_prev, weights, v, active, *,
                         n_inputs: int, decay_rate: float = 0.0,
                         threshold_raw: int, reset_mode: str = "zero",
                         decay_kind: str = "shift", decay_raw: int = 0,
                         use_mxu: bool = False, block_batch: int = 8,
                         block_src: int = 128,
                         interpret: bool | None = None):
    """K fused, event-gated accelerator timesteps in ONE kernel call.

    ext: (K, B, n_inputs) external spikes for the whole window;
    spikes_prev, v: (B, P) carries at window entry; weights: (S, P) int32
    raw Q16.16 with S = n_inputs + P; active: (K, B) advance mask.
    Returns ``(v_out, spikes_carry, raster)`` with raster (K, B, P).

    Byte-identical to K chained :func:`spike_timestep` calls under the
    masked-slot contract (inactive (step, example) pairs keep their carry
    and emit zero spikes). External spikes travel bitpacked (32/u32 lane);
    each active external weight block is DMA'd ONCE for the whole window
    behind the accumulate, and the recurrent image is fetched once per
    window and applied per step — per-step weight traffic ~1/K of the
    single-step kernel. The ``use_mxu`` 2^24 exactness bound is unchanged
    by K (the window stacks along the dot's batch axis, never its
    reduction axis); see :func:`repro.core.engine.mxu_partial_sum_bound`.
    """
    interpret = on_cpu() if interpret is None else interpret
    K = ext.shape[0]
    (ext_p, spk_p, w_ext_p, w_rec_p, v_p, act_p, B, P) = _fused_pad(
        ext, spikes_prev, weights, v, active,
        n_inputs=n_inputs, block_batch=block_batch, block_src=block_src)
    Bp, Pp = v_p.shape
    nb = Bp // block_batch
    ns_ext = ext_p.shape[2] // block_src
    packed = bitpack.pack_spikes(ext_p)  # (K, Bp, lanes)
    # window-OR gate scalars: a block is fetched iff ANY step of the
    # window spikes on it for this batch tile (popcounts are counts, so
    # summing over steps and tile rows preserves "nonzero iff any").
    per_example = bitpack.block_activity(packed, block_src)  # (K, Bp, ns)
    activity = (per_example.sum(axis=0)
                .reshape(nb, block_batch, ns_ext).sum(axis=1))
    fn = _ts.build_spike_timestep_fused(
        Bp, ns_ext * block_src, Pp, K,
        decay_rate=decay_rate,
        threshold_raw=threshold_raw,
        reset_mode=reset_mode,
        decay_kind=decay_kind,
        decay_raw=decay_raw,
        block_batch=block_batch,
        block_src=block_src,
        use_mxu=use_mxu,
        interpret=interpret,
    )
    v_out, spk_carry, raster = fn(
        activity, packed, w_ext_p, w_rec_p, v_p, spk_p, act_p)
    return v_out[:B, :P], spk_carry[:B, :P], raster[:, :B, :P]


def ext_gate_activity(ext, *, block_batch: int = 8, block_src: int = 128,
                      fuse_steps: int = 1):
    """The external gate scalars the fused datapath acts on (host view).

    ext: (T, B, n_inputs) external raster. Returns an int32 array of shape
    ``(ceil(T / fuse_steps), B // block_batch (ceil), n_ext_blocks)``:
    window-OR spike counts per (window, batch tile, external source
    block), computed through the SAME bitpack/popcount pipeline the
    kernel wrapper uses. ``(activity > 0).sum()`` is therefore the exact
    number of external weight-block DMAs the fused kernel issues — the
    counter BENCH_pr6.json cross-checks against the
    :func:`repro.events.trace.block_traffic` model.
    """
    ext = jnp.asarray(ext).astype(jnp.int32)
    T, B, _ = ext.shape
    K = int(fuse_steps)
    pad_t = (-T) % K
    if pad_t:
        ext = jnp.pad(ext, ((0, pad_t), (0, 0), (0, 0)))
    ext_p = _pad_to(_pad_to(ext, 1, block_batch), 2, block_src)
    Tp, Bp, Sp = ext_p.shape
    if Sp == 0:
        return jnp.zeros((Tp // K, Bp // block_batch, 0), jnp.int32)
    packed = bitpack.pack_spikes(ext_p)
    per_example = bitpack.block_activity(packed, block_src)  # (Tp, Bp, ns)
    ns = per_example.shape[2]
    windows = per_example.reshape(Tp // K, K, Bp, ns).sum(axis=1)
    return (windows.reshape(Tp // K, Bp // block_batch, block_batch, ns)
            .sum(axis=2).astype(jnp.int32))


# --------------------------------------------------------------------------
@functools.partial(
    jax.jit, static_argnames=("num_steps", "block_batch", "interpret")
)
def poisson_encode(seed, intensities, num_steps: int, *,
                   block_batch: int = 8, interpret: bool | None = None):
    """Hardware rate encoder. intensities: (B, D) f32 -> (T, B, D) i32."""
    interpret = on_cpu() if interpret is None else interpret
    B, D = intensities.shape
    x = _pad_to(_pad_to(intensities.astype(jnp.float32), 0, block_batch),
                1, 128)
    Bp, Dp = x.shape
    fn = _enc.build_poisson_encode(
        Bp, Dp, num_steps, block_batch=block_batch, interpret=interpret
    )
    seed_arr = jnp.asarray([seed], jnp.int32).reshape(1)
    out = fn(seed_arr, x)
    return out[:, :B, :D]
