"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the bit-exact specification its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts exact equality for
the integer paths, allclose for the float paths).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "lif_step_ref",
    "spike_timestep_ref",
    "poisson_encode_ref",
    "hash_u32_ref",
]


def _shift_decay(v, rate: float):
    v = jnp.asarray(v, jnp.int32)
    if rate == 0.125:
        return v - (v >> 3)
    if rate == 0.25:
        return v - (v >> 2)
    if rate == 0.5:
        return v - (v >> 1)
    if rate == 0.75:
        return v >> 2
    raise ValueError(rate)


def lif_step_ref(v, syn, *, decay_rate: float, threshold_raw: int,
                 reset_mode: str):
    """Oracle for kernels.lif_step — fused hardware LIF update.

    v, syn: (..., N) int32. Returns (v_out, spikes) int32.
    """
    v = jnp.asarray(v, jnp.int32)
    syn = jnp.asarray(syn, jnp.int32)
    v_new = _shift_decay(v, decay_rate) + syn
    thr = jnp.int32(threshold_raw)
    spikes = (v_new >= thr).astype(jnp.int32)
    if reset_mode == "zero":
        v_out = jnp.where(spikes > 0, jnp.int32(0), v_new)
    elif reset_mode == "subtract":
        v_out = v_new - spikes * thr
    elif reset_mode == "hold":
        v_out = v_new
    else:
        raise ValueError(reset_mode)
    return v_out, spikes


def spike_timestep_ref(sources, weights, v, *, decay_rate: float,
                       threshold_raw: int, reset_mode: str):
    """Oracle for kernels.spike_timestep — one fused accelerator timestep.

    sources: (B, S) int32 in {0,1}; weights: (S, P) int32 (raw Q16.16 SRAM
    image, flattened over clusters); v: (B, P) int32.
    Returns (v_out, spikes_out, syn) int32.
    """
    sources = jnp.asarray(sources, jnp.int32)
    weights = jnp.asarray(weights, jnp.int32)
    syn = jnp.matmul(sources, weights, preferred_element_type=jnp.int32)
    v_out, spikes = lif_step_ref(
        v, syn, decay_rate=decay_rate, threshold_raw=threshold_raw,
        reset_mode=reset_mode,
    )
    return v_out, spikes, syn


# --------------------------------------------------------------------------
# Counter-based hash encoder (murmur3 finalizer). The ASIC uses an LFSR per
# coding unit; we use a counter-based hash so that spike(seed, t, b, d) is a
# pure function — the same reproducibility contract, and identical between
# the kernel and this oracle.
# --------------------------------------------------------------------------

_PRIME_T = jnp.uint32(0x9E3779B1)   # golden-ratio odd constants
_PRIME_B = jnp.uint32(0x85EBCA77)
_PRIME_D = jnp.uint32(0xC2B2AE3D)


def hash_u32_ref(seed, t, b, d):
    """Mix (seed, timestep, batch, dim) -> uniform uint32."""
    h = (jnp.uint32(seed)
         ^ (jnp.asarray(t, jnp.uint32) * _PRIME_T)
         ^ (jnp.asarray(b, jnp.uint32) * _PRIME_B)
         ^ (jnp.asarray(d, jnp.uint32) * _PRIME_D))
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def poisson_encode_ref(seed: int, intensities, num_steps: int):
    """Oracle for kernels.poisson_encode.

    intensities: (B, D) float32 in [0,1]. Returns (T, B, D) int32 {0,1}.
    spike <=> hash(seed,t,b,d) < intensity * 2^32.
    """
    intensities = jnp.clip(jnp.asarray(intensities, jnp.float32), 0.0, 1.0)
    B, D = intensities.shape
    t = jnp.arange(num_steps, dtype=jnp.uint32)[:, None, None]
    b = jnp.arange(B, dtype=jnp.uint32)[None, :, None]
    d = jnp.arange(D, dtype=jnp.uint32)[None, None, :]
    h = hash_u32_ref(jnp.uint32(seed), t, b, d)
    # threshold in uint32; intensity==1.0 -> always fire (use >= on negated)
    thr = jnp.minimum(intensities * jnp.float32(4294967296.0),
                      jnp.float32(4294967040.0)).astype(jnp.uint32)
    fire = (h < thr[None]) | (intensities[None] >= 1.0)
    return fire.astype(jnp.int32)
