"""Shared PDU + Potential-Adder epilogue for the Pallas kernel bodies.

Both fused kernels (``lif_step`` and ``spike_timestep``) end a timestep the
same way the ASIC does: decay the previous membrane potential, add the
accumulated synaptic input, compare against the threshold, apply the reset
mode. The fire/reset semantics live in ONE place —
:func:`repro.core.lif.fire_reset` — and the decay dispatch lives here, so
the kernels, the SpikeEngine reference backend, and the float software
reference can never drift apart.

The ``repro.core`` imports are deliberately deferred to trace time: the
kernels package must stay importable without triggering the core package
(core's engine imports the kernels, and eager imports here would close an
import cycle).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["DECAY_KINDS", "SHIFT_RATES", "validate_decay", "decay_and_fire"]

# "shift" — Cerebra-H arithmetic-shift decay, rate in {.125,.25,.5,.75}.
# "mul"   — Cerebra-S truncating fixed-point multiply by a raw Q16.16
#           retain factor (the S generation kept the multiplier).
DECAY_KINDS: tuple[str, ...] = ("shift", "mul")

# mirror of repro.core.fixedpoint.SHIFT_DECAY_RATES (kept literal so the
# kernels package needs no eager core import)
SHIFT_RATES: tuple[float, ...] = (0.125, 0.25, 0.5, 0.75)


def validate_decay(decay_kind: str, decay_rate: float, decay_raw: int):
    """Fail at the kernel-build call site, not from inside a traced body.

    Without this, a missing/mismatched decay parameter (e.g. the default
    ``decay_rate=0.0`` with ``decay_kind='shift'``) would only surface as
    a ValueError deep inside fixedpoint.py during kernel tracing.
    """
    if decay_kind == "shift":
        if decay_rate not in SHIFT_RATES:
            raise ValueError(
                f"decay_kind='shift' needs decay_rate in {SHIFT_RATES}, "
                f"got {decay_rate} (did you forget to pass decay_rate?)"
            )
    elif decay_kind == "mul":
        if not 0 <= decay_raw <= (1 << 16):
            raise ValueError(
                f"decay_kind='mul' needs decay_raw in [0, 2^16], got "
                f"{decay_raw} (did you forget to pass decay_raw?)"
            )
    else:
        raise ValueError(
            f"unknown decay kind {decay_kind!r}; expected one of "
            f"{DECAY_KINDS}"
        )


def decay_and_fire(v, acc, *, decay_kind: str, decay_rate: float,
                   decay_raw: int, threshold_raw: int, reset_mode: str):
    """Decay previous potential, integrate, fire, reset. All int32.

    Pure jnp ops only (shifts, bitwise, wrapping adds) so it traces inside
    Pallas kernel bodies and inside plain jitted scan bodies alike.
    Returns (v_out, spikes) int32.
    """
    from repro.core import fixedpoint as fxp
    from repro.core.lif import fire_reset

    if decay_kind == "shift":
        v_decayed = fxp.shift_decay(v, decay_rate)
    elif decay_kind == "mul":
        v_decayed = fxp.fx_mul(v, jnp.int32(decay_raw))
    else:
        raise ValueError(
            f"unknown decay kind {decay_kind!r}; expected one of {DECAY_KINDS}"
        )
    return fire_reset(v_decayed + acc, jnp.int32(threshold_raw), reset_mode)
