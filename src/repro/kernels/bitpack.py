"""u32-lane bitpacked spike rasters — move bits, not bytes.

The paper's spike packets carry single-bit events; our dense rasters spend
an int32 per possible spike. This module is the packed wire format the
kernel-side datapath uses instead: 32 sources per uint32 lane, so a
1024-source axis is 32 lanes (128 bytes per example-step instead of 4 KiB)
and an entire K-step external raster fits in VMEM next to the accumulator.

Lane layout (the contract ARCHITECTURE.md documents and the fused kernel
depends on): source ``s`` lives in lane ``s // 32`` at bit ``s % 32``,
little-endian within the lane::

    packed[..., l] = sum_{i=0}^{31} (dense[..., 32*l + i] != 0) << i

Sources past the true count (the ragged tail of the last lane) are always
zero — :func:`pack_spikes` zero-pads before packing, so popcounts over
packed lanes equal dense spike counts exactly. All ops are static-shape
and jitted; ``unpack_spikes(pack_spikes(x), x.shape[-1])`` is the identity
on {0,1} rasters (any nonzero packs to 1).

Activity reduction is ``jax.lax.population_count`` on the lanes: the
per-(example, source-block) gate scalars of :mod:`repro.kernels.ops` and
the AER ``total`` bookkeeping become popcounts over 4 lanes per 128-source
block instead of 128-element integer sums.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "LANE_BITS",
    "block_activity",
    "count_spikes",
    "packed_lanes",
    "pack_spikes",
    "unpack_spikes",
]

LANE_BITS = 32  # sources per uint32 lane


def packed_lanes(n_sources: int) -> int:
    """Lanes needed for ``n_sources`` (ceil; 0 sources pack to 0 lanes)."""
    return -(-int(n_sources) // LANE_BITS)


@jax.jit
def pack_spikes(dense):
    """Pack a dense ``(..., S)`` raster into ``(..., ceil(S/32))`` uint32.

    Any nonzero packs to a set bit (rasters here are {0,1} already); the
    ragged tail of the last lane is zero-filled, so lane popcounts equal
    dense spike counts.
    """
    dense = jnp.asarray(dense)
    S = dense.shape[-1]
    L = packed_lanes(S)
    bits = (dense != 0).astype(jnp.uint32)
    pad = L * LANE_BITS - S
    if pad:
        shape = list(bits.shape)
        shape[-1] = pad
        bits = jnp.concatenate([bits, jnp.zeros(shape, jnp.uint32)], axis=-1)
    lanes = bits.reshape(*bits.shape[:-1], L, LANE_BITS)
    weights = (jnp.uint32(1) << jnp.arange(LANE_BITS, dtype=jnp.uint32))
    return (lanes * weights).sum(axis=-1).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("n_sources",))
def unpack_spikes(packed, n_sources: int):
    """Unpack ``(..., L)`` uint32 lanes to a ``(..., n_sources)`` {0,1}
    int32 raster. Exact inverse of :func:`pack_spikes` on binary rasters."""
    packed = jnp.asarray(packed, jnp.uint32)
    L = packed.shape[-1]
    if L < packed_lanes(n_sources):
        raise ValueError(
            f"{L} lanes hold {L * LANE_BITS} sources; {n_sources} requested"
        )
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    dense = bits.reshape(*packed.shape[:-1], L * LANE_BITS)
    return dense[..., :n_sources].astype(jnp.int32)


@jax.jit
def count_spikes(packed):
    """Spike count per leading index: popcount summed over the lane axis.

    ``count_spikes(pack_spikes(x)) == (x != 0).sum(-1)`` — the packed
    replacement for dense activity sums. Returns int32 of shape
    ``packed.shape[:-1]``.
    """
    packed = jnp.asarray(packed, jnp.uint32)
    counts = jax.lax.population_count(packed).astype(jnp.int32)
    return counts.sum(axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_src",))
def block_activity(packed, block_src: int):
    """Per-source-block spike counts: ``(..., L) -> (..., L*32/block_src)``.

    The event gate's activity scalars, computed on packed lanes: block
    ``j`` covers sources ``[j*block_src, (j+1)*block_src)`` — exactly
    ``block_src // 32`` whole lanes, popcounted. ``block_src`` must be a
    multiple of the 32-bit lane width (the kernels' 128-source blocks are
    4 lanes).
    """
    if block_src % LANE_BITS:
        raise ValueError(
            f"block_src must be a multiple of {LANE_BITS}, got {block_src}"
        )
    packed = jnp.asarray(packed, jnp.uint32)
    L = packed.shape[-1]
    lanes_per_block = block_src // LANE_BITS
    if L % lanes_per_block:
        raise ValueError(
            f"{L} lanes do not tile into {lanes_per_block}-lane blocks"
        )
    counts = jax.lax.population_count(packed).astype(jnp.int32)
    blocks = counts.reshape(*packed.shape[:-1], L // lanes_per_block,
                            lanes_per_block)
    return blocks.sum(axis=-1).astype(jnp.int32)
