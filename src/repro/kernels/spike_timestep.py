"""Pallas TPU kernel: fused, cluster-gated Cerebra-H timestep.

This is the paper's core mechanism re-architected for TPU (DESIGN.md §2):

  ASIC                              TPU kernel
  ----                              ----------
  per-group weight SRAM row fetch   VMEM weight block (Sb x P), streamed
  incoming-forwarder event gating   @pl.when on a prefetched per-(batch-
                                    tile, source-block) activity scalar —
                                    silent source blocks are SKIPPED
  accumulator unit (32-wide row)    row-broadcast masked adds on the VPU
                                    (exact int32), or f32 MXU dot in
                                    high-throughput mode
  PDU + potential adder             fused shift-decay LIF epilogue on the
                                    final source block

Grid: (batch_tiles, source_tiles); source innermost so the int32
accumulator scratch completes before the LIF epilogue fires. The physical
neuron axis P (default 1024 = 8x128) stays whole inside a block — the
entire neuron array is one VPU tile set, mirroring "all clusters step in
parallel".

The event gate is the load-bearing adaptation: like Cerebra-H's resolver
only fetching rows for spiking sources, the kernel skips both the compute
and (on TPU, where `when` guards the pipeline stage) the DMA of weight
blocks whose source block carries no spike in this batch tile. Sparse SNN
activity (the paper's workloads are <10% active) turns directly into
skipped HBM traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.epilogue import decay_and_fire, validate_decay

__all__ = [
    "spike_timestep_kernel",
    "build_spike_timestep",
    "spike_timestep_fused_kernel",
    "build_spike_timestep_fused",
]


def spike_timestep_kernel(
    act_ref,      # scalar-prefetch: (nb, ns) int32 block activity
    src_ref,      # (Bb, Sb) int32 spikes
    w_ref,        # (Sb, P) int32 SRAM image block
    v_ref,        # (Bb, P) int32 membrane potential
    vout_ref,     # (Bb, P) int32
    spk_ref,      # (Bb, P) int32
    acc_ref,      # scratch (Bb, P) int32
    *,
    decay_kind: str,
    decay_rate: float,
    decay_raw: int,
    threshold_raw: int,
    reset_mode: str,
    use_mxu: bool,
):
    b = pl.program_id(0)
    s = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(act_ref[b, s] > 0)  # event gate: skip silent source blocks
    def _accumulate():
        src = src_ref[...]
        w = w_ref[...]
        if use_mxu:
            # High-throughput mode: f32 MXU dot. Exact while partial sums
            # stay below 2^24 (documented tolerance in ops.py).
            acc_ref[...] += jax.lax.dot(
                src.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            ).astype(jnp.int32)
        else:
            # Exact event-serial mode: one weight row per source, delivered
            # 1024-wide — the VPU analogue of the SRAM row broadcast.
            def body(j, acc):
                spk = jax.lax.dynamic_slice_in_dim(src, j, 1, axis=1)  # (Bb,1)
                row = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=0)    # (1,P)
                return acc + spk * row
            acc_ref[...] = jax.lax.fori_loop(
                0, src.shape[1], body, acc_ref[...]
            )

    @pl.when(s == ns - 1)  # LIF epilogue once accumulation is complete
    def _fire():
        vout, spikes = decay_and_fire(
            v_ref[...], acc_ref[...],
            decay_kind=decay_kind, decay_rate=decay_rate,
            decay_raw=decay_raw, threshold_raw=threshold_raw,
            reset_mode=reset_mode,
        )
        vout_ref[...] = vout
        spk_ref[...] = spikes


def build_spike_timestep(
    batch: int,
    n_sources: int,
    n_phys: int,
    *,
    decay_rate: float = 0.0,
    threshold_raw: int,
    reset_mode: str,
    decay_kind: str = "shift",
    decay_raw: int = 0,
    block_batch: int = 8,
    block_src: int = 128,
    use_mxu: bool = False,
    interpret: bool = False,
):
    """Build fn(activity, sources, weights, v) -> (v_out, spikes).

    ``decay_kind='shift'`` uses the Cerebra-H shift decay (``decay_rate``);
    ``decay_kind='mul'`` uses the Cerebra-S fixed-point multiply by the raw
    Q16.16 retain factor ``decay_raw``.

    Shapes (pre-padded by ops.py):
      activity: (batch//block_batch, n_sources//block_src) int32
      sources:  (batch, n_sources) int32 {0,1}
      weights:  (n_sources, n_phys) int32
      v:        (batch, n_phys) int32
    """
    validate_decay(decay_kind, decay_rate, decay_raw)
    if batch % block_batch or n_sources % block_src:
        raise ValueError("shapes must be pre-padded to block multiples")
    if n_phys % 128:
        raise ValueError("n_phys must be a multiple of 128 (VPU lanes)")
    nb = batch // block_batch
    ns = n_sources // block_src
    kernel = functools.partial(
        spike_timestep_kernel,
        decay_kind=decay_kind,
        decay_rate=decay_rate,
        decay_raw=decay_raw,
        threshold_raw=threshold_raw,
        reset_mode=reset_mode,
        use_mxu=use_mxu,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, ns),
        in_specs=[
            pl.BlockSpec((block_batch, block_src), lambda b, s, act: (b, s)),
            pl.BlockSpec((block_src, n_phys), lambda b, s, act: (s, 0)),
            pl.BlockSpec((block_batch, n_phys), lambda b, s, act: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_batch, n_phys), lambda b, s, act: (b, 0)),
            pl.BlockSpec((block_batch, n_phys), lambda b, s, act: (b, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((block_batch, n_phys), jnp.int32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((batch, n_phys), jnp.int32),
            jax.ShapeDtypeStruct((batch, n_phys), jnp.int32),
        ],
        interpret=interpret,
    )


# ==========================================================================
# K-step fused variant: bitpacked sources + double-buffered gated weight DMA
# ==========================================================================
#
# Recurrent feedback splits the weight image's fusion behaviour in two.
# Spikes of step t feed step t+1, so the RECURRENT rows (W_rec, the last
# n_phys rows) cannot be gated ahead of time — but they CAN be fetched once
# per K-step window and kept VMEM-resident while an in-kernel loop applies
# them per step. The EXTERNAL rows (W_ext, the first n_inputs rows) face
# known inputs for all K steps, so their gate scalars are ORed over the
# window and each active block is fetched ONCE for all K steps. Both halves
# therefore move ~1/K of the per-step weight traffic of the single-step
# kernel (events/trace.py's fused model counts exactly this).
#
# The external fetch is a MANUAL double-buffered DMA: the weight image
# stays in HBM (memory_space=ANY), the kernel compacts the active block
# ids into an SMEM schedule, then ping-pongs two VMEM slots — start the
# copy of block i+1, wait on block i, accumulate. Silent blocks never
# appear in the schedule, so they skip the DMA itself, not just the
# compute (the single-step kernel relies on `when` guarding the pipelined
# fetch; here the skip is explicit).
#
# External spikes arrive BITPACKED (repro.kernels.bitpack lane layout:
# source s = lane s//32, bit s%32): the whole (K, batch-tile) external
# raster rides in VMEM as uint32 lanes and is expanded to {0,1} rows only
# at accumulate time. Exactness: the int32 accumulator and the shared LIF
# epilogue run PER STEP inside the kernel, and inactive (step, example)
# slots keep their carry bit-for-bit and emit zero spikes — the same
# contract as SpikeEngine._masked_chunk_scan, which is what makes K-aligned
# chunking with a masked remainder byte-identical to K single steps.


def spike_timestep_fused_kernel(
    act_ref,      # scalar-prefetch: (nb, ns_ext) window-OR ext activity
    ext_ref,      # (K, Bb, n_lanes) uint32 bitpacked external spikes
    wext_ref,     # (n_ext, P) int32 — HBM (ANY); manually DMA'd per block
    wrec_ref,     # (P, P) int32 recurrent image, VMEM-resident per window
    v_ref,        # (Bb, P) int32 membrane potential at window entry
    spk0_ref,     # (Bb, P) int32 boundary spikes at window entry
    active_ref,   # (K, Bb) int32 per-(step, example) advance mask
    vout_ref,     # (Bb, P) int32 membrane potential at window exit
    spkc_ref,     # (Bb, P) int32 boundary spikes at window exit
    rast_ref,     # (K, Bb, P) int32 emitted spike raster
    wbuf,         # scratch VMEM (2, block_src, P) int32 — DMA ping-pong
    acc_ref,      # scratch VMEM (K*Bb, P) int32 external accumulator
    sched_ref,    # scratch SMEM (ns_ext,) int32 active-block schedule
    sem,          # DMA semaphores (2,)
    *,
    fuse_steps: int,
    block_src: int,
    decay_kind: str,
    decay_rate: float,
    decay_raw: int,
    threshold_raw: int,
    reset_mode: str,
    use_mxu: bool,
):
    b = pl.program_id(0)
    K = fuse_steps
    Bb = v_ref.shape[0]
    P = v_ref.shape[1]
    ns_ext = act_ref.shape[1]
    lanes_blk = block_src // 32

    acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- phase A: compact active external block ids into the schedule.
    # A block is scheduled iff ANY of the K steps spikes on it for this
    # batch tile (the window-OR the activity scalars carry).
    def _collect(s, n):
        @pl.when(act_ref[b, s] > 0)
        def _():
            sched_ref[n] = s

        return n + jnp.where(act_ref[b, s] > 0, 1, 0)

    n_active = jax.lax.fori_loop(0, ns_ext, _collect, jnp.int32(0))

    # ---- phase B: double-buffered gated DMA + K-batched accumulate.
    # Scheduled block i streams HBM -> wbuf[i % 2] while block i-1 is being
    # accumulated; unscheduled (silent) blocks are never copied at all.
    def _dma(i, slot):
        blk = sched_ref[i]
        return pltpu.make_async_copy(
            wext_ref.at[pl.ds(blk * block_src, block_src)],
            wbuf.at[slot],
            sem.at[slot],
        )

    @pl.when(n_active > 0)
    def _warmup():
        _dma(jnp.int32(0), jnp.int32(0)).start()

    # all K steps' packed lanes for the tile, flattened to (K*Bb, n_lanes):
    # one block's dense {0,1} rows are recovered lane-by-lane below.
    lanes_all = ext_ref[...].reshape(K * Bb, ext_ref.shape[2])
    bit_shift = (
        jnp.arange(block_src, dtype=jnp.uint32) % jnp.uint32(32)
    )[None, :]

    def _consume(i, _):
        slot = jax.lax.rem(i, 2)

        @pl.when(i + 1 < n_active)
        def _prefetch():
            _dma(i + 1, 1 - slot).start()

        _dma(i, slot).wait()
        blk = sched_ref[i]
        lanes = jax.lax.dynamic_slice_in_dim(
            lanes_all, blk * lanes_blk, lanes_blk, axis=1
        )  # (K*Bb, lanes_blk) uint32
        rep = jnp.repeat(lanes, 32, axis=1)  # lane l at cols [32l, 32l+32)
        src = ((rep >> bit_shift) & jnp.uint32(1)).astype(jnp.int32)
        w = wbuf[slot]
        if use_mxu:
            # f32 MXU dot: K stacks along the BATCH axis of the dot, so
            # each partial sum still reduces over one block_src block —
            # the 2^24 exactness bound is the single-step kernel's bound.
            acc_ref[...] += jax.lax.dot(
                src.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            ).astype(jnp.int32)
        else:
            def rows(j, acc):
                spk = jax.lax.dynamic_slice_in_dim(src, j, 1, axis=1)
                row = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=0)
                return acc + spk * row

            acc_ref[...] = jax.lax.fori_loop(
                0, block_src, rows, acc_ref[...]
            )
        return 0

    jax.lax.fori_loop(0, n_active, _consume, 0)

    # ---- phase C: K per-step recurrences + LIF epilogues on the resident
    # recurrent image. vout/spkc double as the in-flight carry registers.
    vout_ref[...] = v_ref[...]
    spkc_ref[...] = spk0_ref[...]
    acc_all = acc_ref[...]
    wrec = wrec_ref[...]
    active = active_ref[...]
    n_rec_blocks = P // block_src

    def _step(k, _):
        spk_prev = spkc_ref[...]
        syn = jax.lax.dynamic_slice_in_dim(acc_all, k * Bb, Bb, axis=0)

        # recurrent accumulate, chunked at block_src rows so each MXU dot
        # reduces over the same span as the single-step kernel (identical
        # partial-sum bound); inter-chunk accumulation is exact int32.
        def _rchunk(c, s2):
            wblk = jax.lax.dynamic_slice_in_dim(
                wrec, c * block_src, block_src, axis=0)
            sblk = jax.lax.dynamic_slice_in_dim(
                spk_prev, c * block_src, block_src, axis=1)
            if use_mxu:
                return s2 + jax.lax.dot(
                    sblk.astype(jnp.float32), wblk.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                ).astype(jnp.int32)

            def rows(j, acc):
                spk = jax.lax.dynamic_slice_in_dim(sblk, j, 1, axis=1)
                row = jax.lax.dynamic_slice_in_dim(wblk, j, 1, axis=0)
                return acc + spk * row

            return jax.lax.fori_loop(0, block_src, rows, s2)

        syn = jax.lax.fori_loop(0, n_rec_blocks, _rchunk, syn)
        v_new, s_new = decay_and_fire(
            vout_ref[...], syn,
            decay_kind=decay_kind, decay_rate=decay_rate,
            decay_raw=decay_raw, threshold_raw=threshold_raw,
            reset_mode=reset_mode,
        )
        # masked-slot contract (== SpikeEngine._masked_chunk_scan): an
        # inactive (step, example) keeps its carry and emits zero spikes.
        act_k = jax.lax.dynamic_slice_in_dim(active, k, 1, axis=0)
        keep = act_k.reshape(Bb, 1) != 0
        vout_ref[...] = jnp.where(keep, v_new, vout_ref[...])
        emitted = jnp.where(keep, s_new, 0)
        rast_ref[pl.ds(k, 1)] = emitted[None]
        spkc_ref[...] = jnp.where(keep, s_new, spkc_ref[...])
        return 0

    jax.lax.fori_loop(0, K, _step, 0)


def build_spike_timestep_fused(
    batch: int,
    n_ext: int,
    n_phys: int,
    fuse_steps: int,
    *,
    decay_rate: float = 0.0,
    threshold_raw: int,
    reset_mode: str,
    decay_kind: str = "shift",
    decay_raw: int = 0,
    block_batch: int = 8,
    block_src: int = 128,
    use_mxu: bool = False,
    interpret: bool = False,
):
    """Build the K-step fused timestep:
    ``fn(activity, ext_packed, w_ext, w_rec, v, spikes_prev, active)
    -> (v_out, spikes_carry, raster)``.

    Shapes (pre-padded by ops.py; lanes = n_ext // 32):
      activity:   (batch//block_batch, n_ext//block_src) int32, window-OR
      ext_packed: (fuse_steps, batch, lanes) uint32 bitpacked ext spikes
      w_ext:      (n_ext, n_phys) int32 — external SRAM rows (HBM-resident)
      w_rec:      (n_phys, n_phys) int32 — recurrent SRAM rows
      v, spikes_prev: (batch, n_phys) int32 carries at window entry
      active:     (fuse_steps, batch) int32 per-(step, example) mask
    Returns v/spikes carries at window exit plus the
    (fuse_steps, batch, n_phys) emitted raster.
    """
    validate_decay(decay_kind, decay_rate, decay_raw)
    if fuse_steps < 1:
        raise ValueError(f"fuse_steps must be >= 1, got {fuse_steps}")
    if batch % block_batch or n_ext % block_src:
        raise ValueError("shapes must be pre-padded to block multiples")
    if block_src % 32:
        raise ValueError("block_src must be a multiple of the 32-bit lane")
    if n_phys % 128 or n_phys % block_src:
        raise ValueError(
            "n_phys must be a multiple of 128 and of block_src "
            "(the recurrent accumulate chunks at block_src rows)"
        )
    nb = batch // block_batch
    ns_ext = n_ext // block_src
    n_lanes = n_ext // 32
    kernel = functools.partial(
        spike_timestep_fused_kernel,
        fuse_steps=fuse_steps,
        block_src=block_src,
        decay_kind=decay_kind,
        decay_rate=decay_rate,
        decay_raw=decay_raw,
        threshold_raw=threshold_raw,
        reset_mode=reset_mode,
        use_mxu=use_mxu,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((fuse_steps, block_batch, n_lanes),
                         lambda b, act: (0, b, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # w_ext stays in HBM
            pl.BlockSpec((n_phys, n_phys), lambda b, act: (0, 0)),
            pl.BlockSpec((block_batch, n_phys), lambda b, act: (b, 0)),
            pl.BlockSpec((block_batch, n_phys), lambda b, act: (b, 0)),
            pl.BlockSpec((fuse_steps, block_batch), lambda b, act: (0, b)),
        ],
        out_specs=[
            pl.BlockSpec((block_batch, n_phys), lambda b, act: (b, 0)),
            pl.BlockSpec((block_batch, n_phys), lambda b, act: (b, 0)),
            pl.BlockSpec((fuse_steps, block_batch, n_phys),
                         lambda b, act: (0, b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, block_src, n_phys), jnp.int32),
            pltpu.VMEM((fuse_steps * block_batch, n_phys), jnp.int32),
            pltpu.SMEM((max(ns_ext, 1),), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((batch, n_phys), jnp.int32),
            jax.ShapeDtypeStruct((batch, n_phys), jnp.int32),
            jax.ShapeDtypeStruct((fuse_steps, batch, n_phys), jnp.int32),
        ],
        interpret=interpret,
    )
