"""Pallas TPU kernel: fused, cluster-gated Cerebra-H timestep.

This is the paper's core mechanism re-architected for TPU (DESIGN.md §2):

  ASIC                              TPU kernel
  ----                              ----------
  per-group weight SRAM row fetch   VMEM weight block (Sb x P), streamed
  incoming-forwarder event gating   @pl.when on a prefetched per-(batch-
                                    tile, source-block) activity scalar —
                                    silent source blocks are SKIPPED
  accumulator unit (32-wide row)    row-broadcast masked adds on the VPU
                                    (exact int32), or f32 MXU dot in
                                    high-throughput mode
  PDU + potential adder             fused shift-decay LIF epilogue on the
                                    final source block

Grid: (batch_tiles, source_tiles); source innermost so the int32
accumulator scratch completes before the LIF epilogue fires. The physical
neuron axis P (default 1024 = 8x128) stays whole inside a block — the
entire neuron array is one VPU tile set, mirroring "all clusters step in
parallel".

The event gate is the load-bearing adaptation: like Cerebra-H's resolver
only fetching rows for spiking sources, the kernel skips both the compute
and (on TPU, where `when` guards the pipeline stage) the DMA of weight
blocks whose source block carries no spike in this batch tile. Sparse SNN
activity (the paper's workloads are <10% active) turns directly into
skipped HBM traffic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.epilogue import decay_and_fire, validate_decay

__all__ = ["spike_timestep_kernel", "build_spike_timestep"]


def spike_timestep_kernel(
    act_ref,      # scalar-prefetch: (nb, ns) int32 block activity
    src_ref,      # (Bb, Sb) int32 spikes
    w_ref,        # (Sb, P) int32 SRAM image block
    v_ref,        # (Bb, P) int32 membrane potential
    vout_ref,     # (Bb, P) int32
    spk_ref,      # (Bb, P) int32
    acc_ref,      # scratch (Bb, P) int32
    *,
    decay_kind: str,
    decay_rate: float,
    decay_raw: int,
    threshold_raw: int,
    reset_mode: str,
    use_mxu: bool,
):
    b = pl.program_id(0)
    s = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(act_ref[b, s] > 0)  # event gate: skip silent source blocks
    def _accumulate():
        src = src_ref[...]
        w = w_ref[...]
        if use_mxu:
            # High-throughput mode: f32 MXU dot. Exact while partial sums
            # stay below 2^24 (documented tolerance in ops.py).
            acc_ref[...] += jax.lax.dot(
                src.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            ).astype(jnp.int32)
        else:
            # Exact event-serial mode: one weight row per source, delivered
            # 1024-wide — the VPU analogue of the SRAM row broadcast.
            def body(j, acc):
                spk = jax.lax.dynamic_slice_in_dim(src, j, 1, axis=1)  # (Bb,1)
                row = jax.lax.dynamic_slice_in_dim(w, j, 1, axis=0)    # (1,P)
                return acc + spk * row
            acc_ref[...] = jax.lax.fori_loop(
                0, src.shape[1], body, acc_ref[...]
            )

    @pl.when(s == ns - 1)  # LIF epilogue once accumulation is complete
    def _fire():
        vout, spikes = decay_and_fire(
            v_ref[...], acc_ref[...],
            decay_kind=decay_kind, decay_rate=decay_rate,
            decay_raw=decay_raw, threshold_raw=threshold_raw,
            reset_mode=reset_mode,
        )
        vout_ref[...] = vout
        spk_ref[...] = spikes


def build_spike_timestep(
    batch: int,
    n_sources: int,
    n_phys: int,
    *,
    decay_rate: float = 0.0,
    threshold_raw: int,
    reset_mode: str,
    decay_kind: str = "shift",
    decay_raw: int = 0,
    block_batch: int = 8,
    block_src: int = 128,
    use_mxu: bool = False,
    interpret: bool = False,
):
    """Build fn(activity, sources, weights, v) -> (v_out, spikes).

    ``decay_kind='shift'`` uses the Cerebra-H shift decay (``decay_rate``);
    ``decay_kind='mul'`` uses the Cerebra-S fixed-point multiply by the raw
    Q16.16 retain factor ``decay_raw``.

    Shapes (pre-padded by ops.py):
      activity: (batch//block_batch, n_sources//block_src) int32
      sources:  (batch, n_sources) int32 {0,1}
      weights:  (n_sources, n_phys) int32
      v:        (batch, n_phys) int32
    """
    validate_decay(decay_kind, decay_rate, decay_raw)
    if batch % block_batch or n_sources % block_src:
        raise ValueError("shapes must be pre-padded to block multiples")
    if n_phys % 128:
        raise ValueError("n_phys must be a multiple of 128 (VPU lanes)")
    nb = batch // block_batch
    ns = n_sources // block_src
    kernel = functools.partial(
        spike_timestep_kernel,
        decay_kind=decay_kind,
        decay_rate=decay_rate,
        decay_raw=decay_raw,
        threshold_raw=threshold_raw,
        reset_mode=reset_mode,
        use_mxu=use_mxu,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, ns),
        in_specs=[
            pl.BlockSpec((block_batch, block_src), lambda b, s, act: (b, s)),
            pl.BlockSpec((block_src, n_phys), lambda b, s, act: (s, 0)),
            pl.BlockSpec((block_batch, n_phys), lambda b, s, act: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_batch, n_phys), lambda b, s, act: (b, 0)),
            pl.BlockSpec((block_batch, n_phys), lambda b, s, act: (b, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((block_batch, n_phys), jnp.int32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((batch, n_phys), jnp.int32),
            jax.ShapeDtypeStruct((batch, n_phys), jnp.int32),
        ],
        interpret=interpret,
    )
