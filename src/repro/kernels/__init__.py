"""Pallas TPU kernels for the Cerebra-H hot path.

  lif_step       — fused decay+integrate+fire+reset (one HBM pass over V)
  spike_timestep — cluster-gated accumulate + LIF epilogue (the paper's
                   event-driven row fetch, re-architected for VMEM/VPU)
  poisson_encode — counter-hash rate encoder (the SoC coding unit)
  ops            — public jitted wrappers (padding, activity bitmap,
                   platform dispatch); use these, not pallas_call directly
  ref            — pure-jnp oracles; tests assert bit-exactness
"""

from repro.kernels import ops, ref  # noqa: F401
