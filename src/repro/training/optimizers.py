"""Optimizers, implemented from scratch on pytrees (no external deps).

All optimizers follow the (init, update) pair convention:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

States are pytrees of arrays with the same tree structure as the params, so
they shard under pjit exactly like the params do (ZeRO-1 falls out of the
partition rules in repro.distributed).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "sgd",
    "adam",
    "adamw",
    "apply_updates",
    "clip_by_global_norm",
    "global_norm",
    "chain_clip",
]


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


# --------------------------------------------------------------------------
def sgd(lr: float | Callable, momentum: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mu = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
              if momentum else None)
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        del params
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mu"], grads)
            if nesterov:
                upd = jax.tree.map(
                    lambda m, g: -lr_t * (momentum * m + g), mu, grads)
            else:
                upd = jax.tree.map(lambda m: -lr_t * m, mu)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree.map(lambda g: -lr_t * g, grads)
        return upd, {"step": step, "mu": None}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
def adam(lr: float | Callable, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    return _adam_impl(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr: float | Callable, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    return _adam_impl(lr, b1, b2, eps, weight_decay=weight_decay)


def _adam_impl(lr, b1, b2, eps, weight_decay) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        stepf = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** stepf
        bc2 = 1.0 - b2 ** stepf
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_
            + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)

        def upd(m_, v_, p=None):
            u = -(lr_t) * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if weight_decay and params is not None:
            updates = jax.tree.map(upd, m, v, params)
        else:
            updates = jax.tree.map(lambda m_, v_: upd(m_, v_), m, v)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
def chain_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    """Global-norm clipping composed in front of any optimizer."""

    def update(grads, state, params=None):
        grads, _ = clip_by_global_norm(grads, max_norm)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)


# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Schedules:
    """LR schedules as step -> lr callables."""

    @staticmethod
    def constant(lr: float):
        return lambda step: jnp.float32(lr)

    @staticmethod
    def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                      floor: float = 0.0):
        def fn(step):
            step = step.astype(jnp.float32) if hasattr(step, "astype") else (
                jnp.float32(step))
            warm = peak_lr * step / max(warmup_steps, 1)
            prog = jnp.clip((step - warmup_steps)
                            / max(total_steps - warmup_steps, 1), 0.0, 1.0)
            cos = floor + (peak_lr - floor) * 0.5 * (
                1.0 + jnp.cos(jnp.pi * prog))
            return jnp.where(step < warmup_steps, warm, cos)
        return fn

    @staticmethod
    def linear_decay(peak_lr: float, total_steps: int):
        def fn(step):
            s = step.astype(jnp.float32) if hasattr(step, "astype") else (
                jnp.float32(step))
            return peak_lr * jnp.clip(1.0 - s / total_steps, 0.0, 1.0)
        return fn
