"""Fault-tolerant training loop.

Composes the substrate pieces into the production loop contract:

  * resume-exact restart: state = (params, opt_state, step); the stateless
    data pipeline replays from any step; PRNG keys are fold_in(step), so a
    preempted-and-restarted run produces the SAME parameter trajectory
    (verified by tests/test_training.py::test_preemption_resume).
  * periodic + final checkpoints through AsyncCheckpointer (atomic,
    CRC-verified, keep-k).
  * straggler observation hooks (per-host step times -> detector ->
    rebalance callback).
  * optional simulated-failure injection for testing (raise at step k,
    restart from latest checkpoint).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.distributed.straggler import StragglerDetector

__all__ = ["LoopConfig", "run_loop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    checkpoint_dir: str | None = None
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 50
    num_hosts: int = 1               # for the straggler detector
    fail_at_step: int | None = None  # test hook: simulate preemption


def run_loop(
    config: LoopConfig,
    state: dict,
    step_fn: Callable,               # (state, batch) -> (state, metrics)
    batch_fn: Callable,              # step -> batch
    *,
    log_fn: Callable = print,
    on_straggler: Callable | None = None,
) -> dict:
    """Run (or resume) the training loop. ``state`` must contain 'step'."""
    saver = (ckpt.AsyncCheckpointer(config.checkpoint_dir,
                                    config.keep_checkpoints)
             if config.checkpoint_dir else None)
    detector = StragglerDetector(config.num_hosts)

    start = int(state["step"])
    if saver and (latest := ckpt.latest_step(config.checkpoint_dir)) is not None:
        if latest >= start:
            restored, meta = ckpt.load(
                config.checkpoint_dir, latest, like=state)
            state = restored
            start = int(state["step"])
            log_fn(f"[loop] resumed from checkpoint step {start}")

    metrics = {}
    for step in range(start, config.total_steps):
        if config.fail_at_step is not None and step == config.fail_at_step:
            if saver:
                saver.wait()
            raise RuntimeError(f"simulated preemption at step {step}")
        batch = batch_fn(step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(jax.tree.leaves(state)[0])
        dt = time.perf_counter() - t0
        state["step"] = step + 1

        flagged = detector.observe(np.full(config.num_hosts, dt))
        if flagged.any() and on_straggler is not None:
            on_straggler(flagged)

        if config.log_every and step % config.log_every == 0:
            msg = " ".join(f"{k}={float(v):.4f}" for k, v in metrics.items()
                           if np.ndim(v) == 0)
            log_fn(f"[loop] step {step}: {msg} ({dt*1e3:.0f} ms)")
        if saver and (step + 1) % config.checkpoint_every == 0:
            saver.save(step + 1, state, {"wall_time": time.time()})
    if saver:
        saver.save(config.total_steps, state, {"final": True})
        saver.wait()
    return state
