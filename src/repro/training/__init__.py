"""Training substrate: optimizers, schedules, compression, loop."""

from repro.training import compression, loop, optimizers  # noqa: F401
