"""Gradient compression for bandwidth-constrained all-reduce.

At 1000+ node scale the data-parallel all-reduce of full-precision
gradients dominates step time for small-FLOP models (exactly the paper's
memory-dominates-compute observation, transplanted to collectives). Two
standard schemes, both with correctness guarantees under tests:

  * top-k sparsification with **error feedback** (memory of the residual is
    carried to the next step, so the compressed SGD converges; Stich et al.)
  * int8 quantization with per-tensor scale and stochastic rounding.

These wrap the gradient pytree BEFORE the psum; the all-reduce then moves
k values + indices (or int8) instead of f32. On the CPU container we
validate semantics; the bytes-on-the-wire savings are accounted in the
roofline collective term.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["TopKCompressor", "Int8Compressor"]


@dataclasses.dataclass(frozen=True)
class TopKCompressor:
    """Keep the k largest-magnitude entries per tensor; residual feedback."""

    fraction: float = 0.01  # keep top 1% by default

    def init_error(self, params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def compress(self, grads, error):
        """-> (sparse {values, indices, shape}, new_error) per leaf."""

        def one(g, e):
            g = g.astype(jnp.float32) + e
            flat = g.reshape(-1)
            k = max(1, int(flat.shape[0] * self.fraction))
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            vals = flat[idx]
            new_e = flat.at[idx].set(0.0).reshape(g.shape)
            return {"values": vals, "indices": idx,
                    "size": flat.shape[0]}, new_e

        pairs = jax.tree.map(one, grads, error,
                             is_leaf=lambda x: isinstance(x, jnp.ndarray))
        sparse = jax.tree.map(lambda t: t[0], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return sparse, new_err

    def decompress(self, sparse, shapes):
        def one(s, shape):
            flat = jnp.zeros((s["size"],), jnp.float32)
            flat = flat.at[s["indices"]].add(s["values"])
            return flat.reshape(shape)

        return jax.tree.map(
            one, sparse, shapes,
            is_leaf=lambda x: isinstance(x, dict) and "values" in x)

    def wire_bytes(self, sparse) -> int:
        """Bytes this representation puts on the interconnect."""
        total = 0
        for leaf in jax.tree.leaves(
                sparse,
                is_leaf=lambda x: isinstance(x, dict) and "values" in x):
            if isinstance(leaf, dict):
                total += int(leaf["values"].size) * 4 * 2  # f32 + i32 index
        return total


@dataclasses.dataclass(frozen=True)
class Int8Compressor:
    """Per-tensor absmax int8 quantization with stochastic rounding."""

    def compress(self, grads, key):
        keys = _tree_keys(key, grads)

        def one(g, k):
            g = g.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            scaled = g / scale
            noise = jax.random.uniform(k, g.shape, minval=-0.5, maxval=0.5)
            q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
            return {"q": q, "scale": scale}

        return jax.tree.map(one, grads, keys)

    def decompress(self, comp):
        return jax.tree.map(
            lambda c: c["q"].astype(jnp.float32) * c["scale"],
            comp, is_leaf=lambda x: isinstance(x, dict) and "q" in x)

    def wire_bytes(self, comp) -> int:
        total = 0
        for leaf in jax.tree.leaves(
                comp, is_leaf=lambda x: isinstance(x, dict) and "q" in x):
            if isinstance(leaf, dict):
                total += int(leaf["q"].size) + 4
        return total


def _tree_keys(key, tree) -> Any:
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))
