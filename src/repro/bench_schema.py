"""The BENCH_*.json schema contract, importable from the package.

``benchmarks/common.py`` stamps every benchmark document with a schema
version and fills every record's cross-bench axes; ``serve_snn
--json-summary`` embeds the same version + axes in its ``meta`` block so
run summaries join the ``BENCH_*.json`` trajectory in
``scripts/bench_compare.py``. The benchmarks tree is not importable from
the serving launcher (it runs with ``PYTHONPATH=src`` only), so the
shared constants live HERE and ``benchmarks/common.py`` re-imports them
— one definition, two consumers.

SCHEMA_VERSION history:

1. implicit axes: records carried only the fields their bench passed, so
   consumers had to existence-check every axis (a record with the
   default gate simply had no ``"gate"`` key).
2. every record carries ALL of :data:`AXIS_DEFAULTS` unconditionally —
   absent axes are filled with their defaults at emit time, so grouping
   by ``(backend, gate, batch, devices, fuse_steps)`` never KeyErrors.
   Schema-1 documents are normalized on load by applying the same
   defaults (:func:`scripts.bench_compare.normalize_record`).
"""

from __future__ import annotations

__all__ = ["AXIS_DEFAULTS", "SCHEMA_VERSION"]

SCHEMA_VERSION = 2

# The cross-bench axes and the value a record has when its bench did not
# set one ("gate": None = not an engine record / gate not applicable;
# "devices": 1 = single device; "fuse_steps": 1 = unfused kernels).
AXIS_DEFAULTS: dict = {
    "backend": None,
    "gate": None,
    "batch": None,
    "devices": 1,
    "fuse_steps": 1,
}
