"""LMHarness: per-(arch x shape) step functions + ShapeDtypeStruct specs.

The harness owns everything dryrun/train/serve need:
  * ``param_shapes()``       — eval_shape of init (no allocation)
  * ``batch_shapes(shape)``  — ShapeDtypeStruct stand-ins for every input
  * ``train_step``           — microbatched grad-accumulation + AdamW
  * ``prefill_step``         — build + fill KV caches
  * ``decode_step``          — one token against the cache
  * ``shardings(...)``       — in/out shardings from the partitioner

Microbatching policy: global_batch is split so each data-shard row
processes ONE sequence per microbatch (n_micro = global_batch /
batch_shard_size); gradients accumulate in f32 across the lax.scan. This
is what bounds train-step activation memory at seq 4096 x batch 256 on
16 GB chips (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro import configs
from repro.configs.shapes import Shape
from repro.distributed import partition as part
from repro.training import optimizers

__all__ = ["LMHarness", "SkipCell"]


class SkipCell(Exception):
    """Raised when an (arch x shape) cell is N/A (documented skip)."""


@dataclasses.dataclass
class LMHarness:
    arch_id: str
    cfg: Any = None
    lr: float = 1e-4
    expert_parallel: bool = False   # §Perf lever (MoE EP vs TP)
    attn_tp: bool = True            # §Perf lever (replicate attn weights)
    micro_rows: int = 1             # sequences per data shard per microbatch

    def __post_init__(self):
        mod = configs.get_arch(self.arch_id)
        self.cfg = self.cfg or mod.CONFIG
        self.model = mod.build(self.cfg)
        self.is_whisper = self.arch_id == "whisper-large-v3"
        self.opt = optimizers.adamw(self.lr, weight_decay=0.01)

    # ------------------------------------------------------------------
    # shapes (no allocation anywhere)
    # ------------------------------------------------------------------
    def param_shapes(self):
        return jax.eval_shape(
            lambda: self.model.init(jax.random.key(0)))

    def opt_shapes(self):
        return jax.eval_shape(
            lambda: self.opt.init(self.param_shapes_zeros()))

    def param_shapes_zeros(self):
        # opt.init only reads shapes/dtypes; reuse eval_shape structs
        return self.param_shapes()

    def check_cell(self, shape: Shape) -> None:
        if shape.name == "long_500k" and not self.cfg.subquadratic:
            raise SkipCell(
                f"{self.arch_id} is pure full-attention; long_500k needs a "
                f"sub-quadratic arch (DESIGN.md §4)")

    def batch_shapes(self, shape: Shape) -> dict:
        """Inputs for train/prefill kinds (decode uses token_shapes)."""
        self.check_cell(shape)
        B, S = shape.global_batch, shape.seq_len
        cfg = self.cfg
        i32 = jnp.int32
        if self.is_whisper:
            half = S // 2
            return {
                "enc_embeds": jax.ShapeDtypeStruct((B, half, cfg.d_model),
                                                   cfg.dtype),
                "tokens": jax.ShapeDtypeStruct((B, half), i32),
                "targets": jax.ShapeDtypeStruct((B, half), i32),
            }
        if cfg.frontend == "embeddings":
            out = {
                "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               cfg.dtype),
                "targets": jax.ShapeDtypeStruct((B, S), i32),
            }
            if cfg.mrope:
                out["mrope_positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
            return out
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "targets": jax.ShapeDtypeStruct((B, S), i32),
        }

    def cache_shapes(self, shape: Shape):
        self.check_cell(shape)
        B, S = shape.global_batch, shape.seq_len
        if self.is_whisper:
            half = S // 2
            return jax.eval_shape(
                lambda: self.model.init_cache(B, half, half))
        return jax.eval_shape(lambda: self.model.init_cache(B, S))

    def token_shapes(self, shape: Shape) -> dict:
        """Decode-step inputs (one new token)."""
        B = shape.global_batch
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}

    # ------------------------------------------------------------------
    # steps
    # ------------------------------------------------------------------
    def n_microbatches(self, shape: Shape, mesh) -> int:
        rules = self.rules(mesh)
        shard = part._axis_size(mesh, tuple(rules.batch_axes))
        if shape.global_batch % shard != 0:
            return 1
        n = max(1, shape.global_batch // (shard * self.micro_rows))
        while shape.global_batch % (shard * n) != 0 and n > 1:
            n -= 1
        return max(1, n)

    def make_train_step(self, shape: Shape, mesh):
        n_micro = self.n_microbatches(shape, mesh)
        model, opt = self.model, self.opt
        rules = self.rules(mesh)
        p_shard = part.params_partition(self.param_shapes(), mesh, rules)

        n_shards = part._axis_size(mesh, tuple(rules.batch_axes))
        act_ctx = functools.partial(
            part.activation_sharding, rules.batch_axes,
            shape.global_batch, mesh)

        def train_step(params, opt_state, batch):
          with act_ctx():
            # Pre-split microbatches STRIDED across data shards: microbatch
            # m takes row m of every shard, so each microbatch stays fully
            # data-parallel AND the reshape never crosses the sharded dim
            # (a dynamic_slice along the sharded batch axis would force an
            # all-gather and replicate every activation).
            xs = jax.tree.map(
                lambda x: _strided_split(x, n_micro, n_shards), batch)

            def body(carry, mb):
                gsum, lsum = carry
                (loss, parts), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, mb, remat=True)
                del parts
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                # keep the f32 accumulator sharded like the params — left
                # to propagation it replicates (10 GB/dev for a 2.5B arch)
                gsum = jax.lax.with_sharding_constraint(gsum, p_shard)
                return (gsum, lsum + loss), None

            gsum = jax.lax.with_sharding_constraint(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params),
                p_shard)
            (gsum, lsum), _ = jax.lax.scan(
                body, (gsum, jnp.zeros((), jnp.float32)), xs)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            grads, gnorm = optimizers.clip_by_global_norm(grads, 1.0)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = optimizers.apply_updates(params, updates)
            return params, opt_state, {"loss": lsum / n_micro,
                                       "grad_norm": gnorm}

        return train_step

    def make_prefill_step(self, shape: Shape, mesh=None):
        B, S = shape.global_batch, shape.seq_len
        model = self.model
        act_ctx = (functools.partial(
            part.activation_sharding, self.rules(mesh).batch_axes, B, mesh)
            if mesh is not None else _null_ctx)

        if self.is_whisper:
            half = S // 2

            def prefill(params, batch):
                with act_ctx():
                    cache = model.init_cache(B, half, half)
                    return model.prefill(params, batch, cache)

            return prefill

        def prefill(params, batch):
            with act_ctx():
                cache = model.init_cache(B, S)
                return model.prefill(params, batch, cache)

        return prefill

    def make_decode_step(self, shape: Shape):
        model = self.model
        cfg = self.cfg
        seq_len = shape.seq_len

        def decode(params, cache, token_in, pos):
            tin = dict(token_in)
            if cfg.mrope:
                B = token_in["tokens"].shape[0]
                tin["mrope_positions"] = jnp.broadcast_to(
                    jnp.asarray(pos, jnp.int32), (3, B, 1))
            logits, cache = model.decode_step(params, tin, pos, cache)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return next_tok[:, None], cache

        del seq_len
        return decode

    # ------------------------------------------------------------------
    # shardings
    # ------------------------------------------------------------------
    def rules(self, mesh) -> part.PartitionRules:
        return part.PartitionRules.default(
            mesh, expert_parallel=self.expert_parallel,
            attn_tp=self.attn_tp)

    def shardings(self, shape: Shape, mesh, kind: str):
        """Returns (in_shardings, out_shardings, example_args) for jit."""
        rules = self.rules(mesh)
        replicated = NamedSharding(mesh, PartitionSpec())
        p_shapes = self.param_shapes()
        p_shard = part.params_partition(p_shapes, mesh, rules)
        if kind == "train":
            o_shapes = jax.eval_shape(self.opt.init, p_shapes)
            o_shard = part.opt_partition(o_shapes, p_shard, mesh)
            b_shapes = self.batch_shapes(shape)
            b_shard = part.batch_partition(b_shapes, mesh, rules)
            in_shardings = (p_shard, o_shard, b_shard)
            out_shardings = (p_shard, o_shard, replicated)
            args = (p_shapes, o_shapes, b_shapes)
        elif kind == "prefill":
            b_shapes = self.batch_shapes(shape)
            b_shard = part.batch_partition(b_shapes, mesh, rules)
            c_shapes = self.cache_shapes(shape)
            c_shard = part.cache_partition(c_shapes, mesh, rules)
            in_shardings = (p_shard, b_shard)
            out_shardings = (replicated, c_shard)
            args = (p_shapes, b_shapes)
        elif kind == "decode":
            c_shapes = self.cache_shapes(shape)
            c_shard = part.cache_partition(c_shapes, mesh, rules)
            t_shapes = self.token_shapes(shape)
            t_shard = part.batch_partition(t_shapes, mesh, rules)
            pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
            in_shardings = (p_shard, c_shard, t_shard, replicated)
            out_shardings = (replicated, c_shard)
            args = (p_shapes, c_shapes, t_shapes, pos_shape)
        else:
            raise ValueError(kind)
        return in_shardings, out_shardings, args

    def step_fn(self, shape: Shape, mesh, kind: str):
        if kind == "train":
            return self.make_train_step(shape, mesh)
        if kind == "prefill":
            return self.make_prefill_step(shape, mesh)
        if kind == "decode":
            return self.make_decode_step(shape)
        raise ValueError(kind)


import contextlib


@contextlib.contextmanager
def _null_ctx():
    yield


def _strided_split(x, n_micro, n_shards):
    """(B, ...) -> (n_micro, B/n_micro, ...) with microbatches strided
    across data shards. B = n_shards * n_micro * r; the sharded major dim
    is preserved through the reshape. mrope (3, B, S) splits on axis 1."""
    batch_axis = 1 if (x.ndim >= 2 and x.shape[0] == 3) else 0
    B = x.shape[batch_axis]
    if B % n_micro != 0:
        raise ValueError(f"batch {B} not divisible by n_micro {n_micro}")
    if B % (n_shards * n_micro) == 0:
        r = B // (n_shards * n_micro)
        split = (n_shards, n_micro, r)
    else:  # batch not shardable anyway (e.g. long_500k B=1): plain split
        split = (1, n_micro, B // n_micro)
    pre = x.shape[:batch_axis]
    post = x.shape[batch_axis + 1:]
    y = x.reshape(pre + split + post)
    # (..., D, M, r, ...) -> (M, ..., D*r, ...): scan axis leads
    d_ax = batch_axis
    y = jnp.moveaxis(y, d_ax + 1, 0)  # M to front
    y = y.reshape((n_micro,) + pre + (split[0] * split[2],) + post)
    return y
