"""LM serving launcher: ``python -m repro.launch.serve --arch <id>``.

Brings up a BatchServer over the arch registry and drives synthetic
request traffic through the scheduler: length-bucketed admission, batched
prefill, fixed-slot greedy decode. Reports tokens/s and per-batch latency.
(The production-mesh versions of these step functions are what the
``decode_32k`` / ``long_500k`` dry-run cells lower.)
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.launch.steps import LMHarness
from repro.serving import BatchServer, Request, Scheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    choices=configs.list_archs())
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=96)
    args = ap.parse_args()

    mod = configs.get_arch(args.arch)
    cfg = mod.CONFIG if args.full else mod.REDUCED
    if cfg.frontend != "tokens" or args.arch == "whisper-large-v3":
        raise SystemExit(f"{args.arch} needs a modality frontend; serve "
                         f"demo supports token-frontend archs")
    h = LMHarness(args.arch, cfg=cfg)
    params = h.model.init(jax.random.key(0))
    server = BatchServer(h.model, params, max_seq=args.max_seq)
    sched = Scheduler(max_batch=args.max_batch)

    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        plen = int(rng.integers(4, args.max_seq - args.max_new - 1))
        prompt = rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        sched.submit(Request(uid=uid, prompt=prompt,
                             max_new_tokens=args.max_new))

    total_tokens, batches = 0, 0
    import time
    t0 = time.perf_counter()
    while True:
        batch = sched.next_batch()
        if not batch:
            break
        comps = server.serve(batch)
        stats = server.throughput_stats(comps)
        batches += 1
        total_tokens += stats["generated_tokens"]
        print(f"[serve] batch {batches}: {len(batch)} reqs "
              f"prompt_lens={[c.prompt_len for c in comps]} "
              f"-> {stats['generated_tokens']} toks "
              f"@ {stats['tokens_per_s']:.1f} tok/s")
    dt = time.perf_counter() - t0
    print(f"[serve] {args.requests} requests, {total_tokens} tokens in "
          f"{dt:.1f}s ({total_tokens / dt:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
