"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization; tests and
benches must keep seeing 1 CPU device).

Topology (TPU v5e-256 pods): a pod is a 16x16 ICI torus -> mesh (16, 16)
("data", "model"): the model axis maps onto one torus dimension (fast ICI
ring for TP collectives), the data axis onto the other (FSDP/DP). The
multi-pod mesh (2, 16, 16) adds a "pod" axis over DCI — only
batch/gradient collectives cross it (DESIGN.md §5), mirroring the paper's
L1 (intra-cluster) vs L2 (inter-cluster) NoC hierarchy.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))
