"""Launchers: mesh construction, dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` sets XLA_FLAGS at import — import it only
as an entry point (``python -m repro.launch.dryrun``), never from tests.
"""

from repro.launch import mesh, steps  # noqa: F401
