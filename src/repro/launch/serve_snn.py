"""Streaming SNN serving launcher: ``python -m repro.launch.serve_snn``.

Brings up an :class:`~repro.core.session.AcceleratorSession`, deploys one
or more co-resident SNN models, and drives synthetic Poisson request
traffic through the streaming server (``session.serve``): streams arrive
with exponential inter-arrival gaps, wait FIFO for a batch slot, push
their Poisson-encoded stimulus in fixed-size chunks through ONE compiled
slot-batch step, and detach. Reports aggregate steps/s and per-stream
latency percentiles — the "many concurrent stateful streams over one
engine" shape of the heavy-traffic north star.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import coding
from repro.core.engine import BACKENDS
from repro.core.lif import LIFParams
from repro.core.network import SNNetwork
from repro.core.session import AcceleratorSession


def make_net(rng, n_in: int, n_neurons: int, *, density: float = 0.25,
             out: int = 10) -> SNNetwork:
    """Small random recurrent SNN with an output population."""
    W = ((rng.random((n_in + n_neurons, n_neurons)) < density)
         * rng.normal(0.0, 0.5, (n_in + n_neurons, n_neurons)))
    return SNNetwork(
        n_inputs=n_in, n_neurons=n_neurons,
        weights=W.astype(np.float32),
        params=LIFParams(decay_rate=0.25, threshold=1.0, reset_mode="zero"),
        output_slice=(n_neurons - out, n_neurons))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=24,
                    help="total streams to serve")
    ap.add_argument("--n-slots", type=int, default=8,
                    help="batch slots (concurrent streams)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="timesteps pushed per feed() call")
    ap.add_argument("--steps-per-stream", type=int, default=48,
                    help="inference timesteps each stream requests")
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="Poisson arrivals per chunk-round")
    ap.add_argument("--backend", choices=list(BACKENDS), default="reference")
    ap.add_argument("--models", type=int, default=2,
                    help="co-resident models sharing the fused engine")
    ap.add_argument("--n-inputs", type=int, default=24)
    ap.add_argument("--n-neurons", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.arrival_rate <= 0:
        raise SystemExit("--arrival-rate must be > 0 (expected arrivals "
                         "per round; the arrival plan cannot make progress "
                         "at rate 0)")

    rng = np.random.default_rng(args.seed)
    sess = AcceleratorSession(backend=args.backend)
    names = [f"snn{i}" for i in range(args.models)]
    for name in names:
        sess.deploy(name, make_net(rng, args.n_inputs, args.n_neurons))
    # serve AFTER all deploys: deploying invalidates the fused layout
    views = {name: sess.serve(name, n_slots=args.n_slots,
                              chunk_steps=args.chunk) for name in names}
    server = next(iter(views.values())).server
    assert all(v.server is server for v in views.values()), \
        "co-resident models must share one fused-engine server"
    print(f"[serve-snn] {args.models} co-resident model(s) on one fused "
          f"engine ({server.engine.n_sources} sources x "
          f"{server.engine.n_phys} neurons), backend={args.backend}, "
          f"{args.n_slots} slots x {args.chunk}-step chunks")

    # synthetic request plan: stream i -> (model, Poisson-encoded stimulus)
    key = jax.random.key(args.seed)
    requests = []
    for uid in range(args.streams):
        key, k = jax.random.split(key)
        name = names[uid % len(names)]
        intensity = rng.random((1, args.n_inputs)).astype(np.float32)
        spikes = np.asarray(coding.poisson_encode(
            k, intensity, args.steps_per_stream, dtype=np.int32))[:, 0]
        requests.append((uid, name, spikes))

    # Poisson arrivals: number of new requests per chunk-round
    arrivals: list[list] = []
    i = 0
    while i < len(requests):
        n = int(rng.poisson(args.arrival_rate))
        arrivals.append(requests[i:i + n])
        i += n

    live: dict = {}           # uid -> [name, cursor]
    t_arrive: dict = {}
    t_done: dict = {}
    t0 = time.perf_counter()
    round_i = 0
    while arrivals or live or server.scheduler.waiting:
        now = time.perf_counter()
        if arrivals:
            for uid, name, spikes in arrivals.pop(0):
                views[name].attach(uid)
                live[uid] = [name, spikes, 0]
                t_arrive[uid] = now
        # ONE batched dispatch per round: every admitted stream's chunk —
        # across models — embeds into the fused layout and steps together
        done = []
        fused_inputs = {}
        for uid, (name, spikes, cur) in live.items():
            if server.slot_of(uid) is None:
                continue  # still waiting for a slot
            n = min(args.chunk, len(spikes) - cur)
            fused_inputs[uid] = views[name].embed(spikes[cur:cur + n])
            live[uid][2] = cur + n
            if cur + n >= len(spikes):
                done.append(uid)
        if fused_inputs:
            server.feed(fused_inputs)
        for uid in done:
            name = live.pop(uid)[0]
            views[name].detach(uid)
            t_done[uid] = time.perf_counter()
        round_i += 1
    wall = time.perf_counter() - t0

    lats = np.asarray([t_done[u] - t_arrive[u] for u in t_done])
    steps = server.total_steps
    print(f"[serve-snn] {len(t_done)} streams, {steps} stream-timesteps in "
          f"{wall:.2f}s over {round_i} rounds -> {steps / wall:.0f} steps/s")
    print(f"[serve-snn] per-stream latency: mean {lats.mean() * 1e3:.1f} ms, "
          f"p50 {np.percentile(lats, 50) * 1e3:.1f} ms, "
          f"p95 {np.percentile(lats, 95) * 1e3:.1f} ms "
          f"(queueing under {args.n_slots} slots)")


if __name__ == "__main__":
    main()
