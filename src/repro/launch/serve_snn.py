"""Streaming SNN serving launcher: ``python -m repro.launch.serve_snn``.

Brings up an :class:`~repro.core.session.AcceleratorSession`, deploys one
or more co-resident SNN models, and drives synthetic Poisson request
traffic through the streaming server (``session.serve``): streams arrive
with exponential inter-arrival gaps, wait FIFO for a batch slot, push
their Poisson-encoded stimulus in fixed-size chunks through ONE compiled
slot-batch step, and detach. Reports aggregate steps/s and per-stream
latency percentiles — the "many concurrent stateful streams over one
engine" shape of the heavy-traffic north star.

``--devices N`` (with optional ``--mesh KNxKB``) runs the whole fused
server mesh-sharded (``AcceleratorSession(mesh=...)``): neuron shards
hold their SRAM slice and the slot batch shards over the ``batch`` axis —
byte-identical outputs, scale-out throughput. A
:class:`~repro.distributed.straggler.StragglerDetector` watches per-chunk
step times attributed to batch shards by their live-slot load (FIFO slot
reuse can concentrate live streams on one shard); flagged shards get a
``rebalance_shards`` slot-redistribution suggestion in the summary.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import coding
from repro.core.engine import BACKENDS, GATES
from repro.core.lif import LIFParams
from repro.core.network import SNNetwork
from repro.core.session import AcceleratorSession
from repro.distributed.spike_mesh import (ensure_host_devices,
                                          make_spike_mesh, parse_mesh_spec)
from repro.distributed.straggler import StragglerDetector, rebalance_shards
from repro.serving.frontend import BACKPRESSURE, FrontendConfig


def make_net(rng, n_in: int, n_neurons: int, *, density: float = 0.25,
             out: int = 10) -> SNNetwork:
    """Small random recurrent SNN with an output population."""
    W = ((rng.random((n_in + n_neurons, n_neurons)) < density)
         * rng.normal(0.0, 0.5, (n_in + n_neurons, n_neurons)))
    return SNNetwork(
        n_inputs=n_in, n_neurons=n_neurons,
        weights=W.astype(np.float32),
        params=LIFParams(decay_rate=0.25, threshold=1.0, reset_mode="zero"),
        output_slice=(n_neurons - out, n_neurons))


class ShardLoadWatch:
    """Straggler watch over the serving loop's synchronous dispatches.

    A single-controller SPMD step yields ONE host-observed wall time per
    chunk; true per-shard times need multi-controller timing. What IS
    observable per batch shard is its live-slot load, so each dispatch's
    time is attributed to shards proportionally to the live slots they
    own (slots map to batch shards contiguously, `slot // slots_per
    _shard`). A shard that persistently carries more live streams than
    the fleet — which FIFO slot reuse can produce — accumulates strikes
    and earns a ``rebalance_shards`` suggestion.
    """

    # a shard earns a rebalance suggestion only when flagged in at least
    # this fraction of dispatches (and at least twice): a transient
    # 3-chunk imbalance at admission time should not brand the whole run.
    PERSISTENT_FRACTION = 0.1

    def __init__(self, n_shards: int, n_slots: int):
        self.n_shards = int(n_shards)
        self.n_slots = int(n_slots)
        padded = -(-n_slots // n_shards) * n_shards
        self.slots_per_shard = padded // n_shards
        self.detector = StragglerDetector(num_hosts=n_shards,
                                          warmup_steps=3, patience=3)
        self.flag_counts = np.zeros(n_shards, np.int64)
        self.chunk_times: list[float] = []

    def observe(self, dt: float, live_slots) -> None:
        self.chunk_times.append(dt)
        load = np.zeros(self.n_shards)
        for slot in live_slots:
            load[slot // self.slots_per_shard] += 1
        mean = load.mean()
        attributed = dt * load / mean if mean > 0 else np.full(
            self.n_shards, dt)
        self.flag_counts += self.detector.observe(attributed)

    def persistent_flags(self) -> np.ndarray:
        """Shards flagged persistently enough to act on (bool mask)."""
        return self.flag_counts >= max(
            2, int(self.PERSISTENT_FRACTION * max(len(self.chunk_times), 1)))

    def summary(self) -> list[str]:
        if not self.chunk_times:
            return []
        ct = np.asarray(self.chunk_times) * 1e3
        if self.n_shards <= 1:
            # unsharded run: no shards to attribute or rebalance — report
            # the dispatch-time distribution only
            return [
                f"[serve-snn] {len(ct)} chunk dispatches: "
                f"p50 {np.percentile(ct, 50):.1f} ms, "
                f"p95 {np.percentile(ct, 95):.1f} ms"
            ]
        stats = self.detector.stats
        lines = [
            f"[serve-snn] straggler watch over {len(ct)} chunk dispatches "
            f"x {self.n_shards} batch shards: load-attributed step time "
            f"mean {float(stats['mean'].mean()):.4f}s "
            f"(dispatch p50 {np.percentile(ct, 50):.1f} ms, "
            f"p95 {np.percentile(ct, 95):.1f} ms), per-shard flag counts "
            f"{self.flag_counts.tolist()}"
        ]
        persistent = self.persistent_flags()
        if persistent.any() and not persistent.all():
            sizes = rebalance_shards(self.n_slots, persistent)
            lines.append(
                f"[serve-snn] persistently overloaded shard(s) "
                f"{np.where(persistent)[0].tolist()} -> suggested slot "
                f"rebalance {sizes.tolist()} (of {self.n_slots} slots)")
        elif persistent.all():
            lines.append(
                "[serve-snn] all shards flagged together (fleet-wide "
                "step-time stretch, not a per-shard straggler); slot "
                "split unchanged "
                f"{rebalance_shards(self.n_slots, persistent).tolist()}")
        else:
            lines.append(
                "[serve-snn] no persistently overloaded shards; slot "
                "split stays uniform "
                f"{rebalance_shards(self.n_slots, persistent).tolist()}")
        return lines


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=24,
                    help="total streams to serve")
    ap.add_argument("--n-slots", type=int, default=8,
                    help="batch slots (concurrent streams)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="timesteps pushed per feed() call")
    ap.add_argument("--steps-per-stream", type=int, default=48,
                    help="inference timesteps each stream requests")
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="Poisson arrivals per chunk-round (sync mode) or "
                         "per SECOND, open-loop (--async): async arrivals "
                         "happen on the wall clock whether or not the step "
                         "loop keeps up, so overload is observable")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="drive traffic through the AsyncSpikeFrontend "
                         "request queue (admission decoupled from the "
                         "step loop) instead of the synchronous loop")
    ap.add_argument("--backpressure", choices=list(BACKPRESSURE),
                    default="reject",
                    help="frontend policy when the request queue is full "
                         "(--async only): reject the new request, block "
                         "the submitter, or drop the oldest queued one")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline (--async only): requests "
                         "past it are expired — refused while queued, "
                         "evicted mid-stream with the slot carry zeroed")
    ap.add_argument("--queue-capacity", type=int, default=32,
                    help="bounded frontend request queue (--async only); "
                         "backpressure engages beyond it")
    ap.add_argument("--backend", choices=list(BACKENDS), default="reference")
    ap.add_argument("--gate", choices=list(GATES), default=None,
                    help="event-gate granularity of the serving engine "
                         "(per-example = the batch-tile=1 serving mode)")
    ap.add_argument("--fuse-steps", type=int, default=1,
                    help="K timesteps per fused kernel window on the "
                         "serving engine (Pallas backends; weight blocks "
                         "fetched once per window, outputs byte-identical "
                         "for any K)")
    ap.add_argument("--models", type=int, default=2,
                    help="co-resident models sharing the fused engine")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the fused server over N devices "
                         "(faked host devices on CPU)")
    ap.add_argument("--mesh", default=None, metavar="KNxKB",
                    help="neuron x batch mesh split for --devices "
                         "(default: 2 x N/2 when N allows)")
    ap.add_argument("--connector", default=None, metavar="DIR",
                    help="root a FILE-backed stream-state carry connector "
                         "at DIR (default: in-memory): redeploy drains, "
                         "shard rebalances, and async deadline spills park "
                         "carries there, and parked snapshots survive the "
                         "process (crash recovery)")
    ap.add_argument("--drain", type=int, default=None, metavar="ROUND",
                    help="rolling redeploy drill (sync mode): after ROUND "
                         "chunk-rounds, hot-deploy one extra model — live "
                         "streams are drained to the connector and "
                         "restored into the new fused server mid-flight "
                         "(byte-identical continuation)")
    ap.add_argument("--n-inputs", type=int, default=24)
    ap.add_argument("--n-neurons", type=int, default=48)
    ap.add_argument("--intensity", type=float, default=0.25,
                    help="stimulus intensity scale (Poisson spike rate "
                         "cap); event workloads live well below 1.0")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _fmt_lat(stats: dict) -> str:
    """'mean X ms, p50 Y ms, p95 Z ms' from a latency_percentiles dict."""
    if stats["mean"] is None:
        return "n/a (no samples)"
    return (f"mean {stats['mean'] * 1e3:.1f} ms, "
            f"p50 {stats['p50'] * 1e3:.1f} ms, "
            f"p95 {stats['p95'] * 1e3:.1f} ms")


def run_async(args, server, views, requests, rng) -> None:
    """Open-loop async serving: arrivals on the wall clock, not the loop.

    Requests are submitted at precomputed Poisson arrival TIMES (rate =
    ``--arrival-rate`` per second) whether or not the pump loop has kept
    up — the decoupling that makes overload observable: when arrivals
    outpace the service rate the queue depth grows until backpressure
    (reject / block / drop-oldest) or ``--deadline-ms`` expiry sheds
    load, and the wait/service/total percentiles split cleanly. The loop
    always terminates: every pump round retires, admits, or expires work,
    and the request plan is finite (no deadlock under any overload).
    """
    fe = next(iter(views.values())).frontend
    assert fe is not None and all(v.frontend is fe for v in views.values()), \
        "co-resident views must share one frontend queue"
    if args.devices > 1 or args.gate:
        print("[serve-snn] note: the straggler watch and event-sparsity "
              "summaries are sync-mode only; the async run reports the "
              "front-door metrics below (the engine itself is still "
              "sharded/gated as requested)")
    arrive_at = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                          len(requests)))
    handles: list = []
    resumed: set = set()
    i = 0
    t0 = time.perf_counter()
    while i < len(requests) or not fe.idle or any(
            h.state == "parked" for h in handles):
        now = time.perf_counter() - t0
        while i < len(requests) and arrive_at[i] <= now:
            uid, name, spikes = requests[i]
            handles.append(views[name].submit(spikes))
            i += 1
        # spill-on-evict (deadline + connector): a parked request's carry
        # sits in the connector; give each ONE resume — it continues
        # where it left off, byte-identically — then shed it for good
        for h in handles:
            if h.state == "parked":
                if h.rid in resumed or not fe.resume(
                        h, deadline_ms=args.deadline_ms):
                    h.cancel()
                else:
                    resumed.add(h.rid)
        if fe.idle:
            # nothing queued or running: open-loop means we wait for the
            # next ARRIVAL, not spin the step loop
            if i < len(requests):
                time.sleep(min(0.05, max(
                    0.0, arrive_at[i] - (time.perf_counter() - t0))))
            continue
        fe.pump()
    wall = time.perf_counter() - t0

    m = fe.metrics()
    c = m["counts"]
    steps = server.total_steps
    offered = len(requests) / arrive_at[-1]
    print(f"[serve-snn] async front door: {len(requests)} requests offered "
          f"open-loop at {offered:.1f}/s (policy={args.backpressure}, "
          f"queue capacity {fe.queue_capacity}, deadline "
          f"{args.deadline_ms} ms), served in {wall:.2f}s over "
          f"{m['rounds']} pump rounds")
    print(f"[serve-snn] outcomes: {c.get('done', 0)} done, "
          f"{c.get('rejected', 0)} rejected, {c.get('dropped', 0)} "
          f"dropped, {c.get('expired', 0)} expired "
          f"({c.get('expired_queued', 0)} queued / "
          f"{c.get('expired_running', 0)} mid-stream), "
          f"{c.get('cancelled', 0)} cancelled; "
          f"{steps} stream-timesteps -> {steps / wall:.0f} steps/s")
    if c.get("parked", 0):
        print(f"[serve-snn] spill-on-evict: {c['parked']} mid-stream "
              f"expiries parked their carry in the connector, "
              f"{c.get('resumed', 0)} resumed bit-clean (one retry each)")
    print(f"[serve-snn] queue depth: max {m['queue_depth']['max']}, "
          f"mean {m['queue_depth']['mean']:.1f} "
          f"(capacity {fe.queue_capacity})")
    print(f"[serve-snn] queue-wait: {_fmt_lat(m['queue_wait'])}")
    print(f"[serve-snn] service:    {_fmt_lat(m['service'])}")
    print(f"[serve-snn] total:      {_fmt_lat(m['total'])}")


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    if args.arrival_rate <= 0:
        raise SystemExit("--arrival-rate must be > 0 (arrivals per "
                         "chunk-round in sync mode, per second with "
                         "--async; the arrival plan cannot make progress "
                         "at rate 0)")
    if args.mesh and args.devices <= 1:
        raise SystemExit("--mesh requires --devices N (N > 1); without it "
                         "the server would silently run unsharded")
    if args.drain is not None and args.async_mode:
        raise SystemExit("--drain is a sync-mode drill (the async frontend "
                         "is rebuilt by the redeploy; resubmit instead)")
    if args.drain is not None and args.drain < 1:
        raise SystemExit("--drain must be >= 1 (chunk-rounds before the "
                         "hot redeploy)")

    mesh = None
    if args.devices > 1:
        # before the first jax device use, so faked CPU devices can land
        ensure_host_devices(args.devices)
        try:
            kn, kb = parse_mesh_spec(args.devices, args.mesh)
        except ValueError as e:
            raise SystemExit(str(e))
        mesh = make_spike_mesh(neuron=kn, batch=kb)

    rng = np.random.default_rng(args.seed)
    connector = None
    if args.connector is not None:
        from repro.serving.connector import FileCarryConnector
        connector = FileCarryConnector(args.connector)
    sess = AcceleratorSession(backend=args.backend, mesh=mesh,
                              fuse_steps=args.fuse_steps,
                              connector=connector)
    names = [f"snn{i}" for i in range(args.models)]
    for name in names:
        sess.deploy(name, make_net(rng, args.n_inputs, args.n_neurons))
    # serve AFTER all deploys: deploying invalidates the fused layout
    frontend_cfg = None
    if args.async_mode:
        frontend_cfg = FrontendConfig(
            queue_capacity=args.queue_capacity,
            backpressure=args.backpressure,
            deadline_ms=args.deadline_ms,
            # with a deadline, spill mid-stream expiries to the session
            # connector and resume each once instead of restarting
            spill=args.deadline_ms is not None)
    views = {name: sess.serve(name, n_slots=args.n_slots,
                              chunk_steps=args.chunk, gate=args.gate,
                              frontend=frontend_cfg)
             for name in names}
    server = next(iter(views.values())).server
    assert all(v.server is server for v in views.values()), \
        "co-resident models must share one fused-engine server"
    n_shards = 1 if mesh is None else int(mesh.shape["batch"])
    mesh_note = "" if mesh is None else (
        f", mesh {mesh.shape['neuron']}x{mesh.shape['batch']} "
        f"(neuron x batch) over {mesh.size} devices")
    print(f"[serve-snn] {args.models} co-resident model(s) on one fused "
          f"engine ({server.engine.n_sources} sources x "
          f"{server.engine.n_phys} neurons), backend={args.backend}, "
          f"{args.n_slots} slots x {args.chunk}-step chunks{mesh_note}")

    watch = ShardLoadWatch(n_shards, args.n_slots)

    # synthetic request plan: stream i -> (model, Poisson-encoded stimulus)
    key = jax.random.key(args.seed)
    requests = []
    for uid in range(args.streams):
        key, k = jax.random.split(key)
        name = names[uid % len(names)]
        intensity = (args.intensity
                     * rng.random((1, args.n_inputs)).astype(np.float32))
        spikes = np.asarray(coding.poisson_encode(
            k, intensity, args.steps_per_stream, dtype=np.int32))[:, 0]
        requests.append((uid, name, spikes))

    if args.async_mode:
        run_async(args, server, views, requests, rng)
        return

    # Poisson arrivals: number of new requests per chunk-round
    arrivals: list[list] = []
    i = 0
    while i < len(requests):
        n = int(rng.poisson(args.arrival_rate))
        arrivals.append(requests[i:i + n])
        i += n

    live: dict = {}           # uid -> [name, cursor]
    out_chunks: dict = {uid: [] for uid, _, _ in requests}  # fused rasters
    t_arrive: dict = {}
    t_done: dict = {}
    rebalanced = False
    steps_base = 0            # stream-timesteps served by drained servers
    t0 = time.perf_counter()
    round_i = 0
    while arrivals or live or server.scheduler.waiting:
        now = time.perf_counter()
        if (args.drain is not None and round_i >= args.drain
                and "hotswap" not in sess.models):
            # rolling-redeploy drill: a NEW model lands mid-run; live
            # streams are drained to the connector by deploy() and
            # restored into the new fused server by the re-serve —
            # their rasters continue byte-identically
            n_live = len(server.scheduler.active)
            steps_base += server.total_steps  # the old server's work
            sess.deploy("hotswap",
                        make_net(rng, args.n_inputs, args.n_neurons))
            views = {name: sess.serve(name, n_slots=args.n_slots,
                                      chunk_steps=args.chunk,
                                      gate=args.gate)
                     for name in names}
            server = next(iter(views.values())).server
            print(f"[serve-snn] --drain: hot-deployed 1 extra model after "
                  f"round {round_i}; {n_live} live stream(s) migrated "
                  f"mid-flight through the "
                  f"{'file' if args.connector else 'in-memory'} connector")
        if arrivals:
            for uid, name, spikes in arrivals.pop(0):
                views[name].attach(uid)
                live[uid] = [name, spikes, 0]
                t_arrive[uid] = now
        # ONE batched dispatch per round: every admitted stream's chunk —
        # across models — embeds into the fused layout and steps together
        done = []
        fused_inputs = {}
        live_slots = []
        for uid, (name, spikes, cur) in live.items():
            slot = server.slot_of(uid)
            if slot is None:
                continue  # still waiting for a slot
            live_slots.append(slot)
            n = min(args.chunk, len(spikes) - cur)
            fused_inputs[uid] = views[name].embed(spikes[cur:cur + n])
            live[uid][2] = cur + n
            if cur + n >= len(spikes):
                done.append(uid)
        if fused_inputs:
            t_chunk0 = time.perf_counter()
            res = server.feed(fused_inputs)
            watch.observe(time.perf_counter() - t_chunk0, live_slots)
            for uid, r in res.items():
                out_chunks[uid].append(r["spikes"])
        if n_shards > 1 and not rebalanced:
            flags = watch.persistent_flags()
            if flags.any() and not flags.all():
                from repro.serving.connector import rebalance_streams
                moves = rebalance_streams(
                    server, flags, slots_per_shard=watch.slots_per_shard)
                if moves:
                    rebalanced = True
                    print(f"[serve-snn] straggler rebalance: migrated "
                          f"{len(moves)} live stream(s) off flagged "
                          f"shard(s) {np.where(flags)[0].tolist()} onto "
                          f"donor-shard slots "
                          f"{[(u, f, t) for u, f, t in moves]} "
                          f"(uid, from, to) — carries moved bit-for-bit")
        for uid in done:
            name = live.pop(uid)[0]
            views[name].detach(uid)
            t_done[uid] = time.perf_counter()
        round_i += 1
    wall = time.perf_counter() - t0

    lats = np.asarray([t_done[u] - t_arrive[u] for u in t_done])
    steps = steps_base + server.total_steps
    print(f"[serve-snn] {len(t_done)} streams, {steps} stream-timesteps in "
          f"{wall:.2f}s over {round_i} rounds -> {steps / wall:.0f} steps/s")
    print(f"[serve-snn] per-stream latency: mean {lats.mean() * 1e3:.1f} ms, "
          f"p50 {np.percentile(lats, 50) * 1e3:.1f} ms, "
          f"p95 {np.percentile(lats, 95) * 1e3:.1f} ms "
          f"(queueing under {args.n_slots} slots)")
    for line in watch.summary():
        print(line)

    # event accounting over the streams actually served: per-stream spike
    # sparsity, and the weight-block traffic the event gate would fetch
    # on these rasters — per-example (batch-tile=1, what a gated serving
    # engine skips per slot) vs the batch-tile OR — from events.trace.
    from repro.core.engine import sources_raster
    from repro.events.trace import block_traffic

    in_sp = np.asarray([spikes.mean() for _, _, spikes in requests])
    ext_stack = np.stack([views[name].embed(spikes)
                          for _, name, spikes in requests], axis=1)
    out_stack = np.stack([np.concatenate(out_chunks[uid], axis=0)
                          for uid, _, _ in requests], axis=1)
    out_sp = out_stack.mean(axis=(0, 2))
    # the same boundary-capture convention the kernel gate sees
    sources = np.asarray(sources_raster(ext_stack, out_stack))
    gated, dense = block_traffic(sources, tile_batch=1)
    tiled, tiled_dense = block_traffic(sources, tile_batch=8)
    print(f"[serve-snn] stream spike sparsity: input mean "
          f"{100 * in_sp.mean():.2f}% (p50 "
          f"{100 * np.percentile(in_sp, 50):.2f}%), output mean "
          f"{100 * out_sp.mean():.2f}%")
    print(f"[serve-snn] event gate on served rasters: per-example "
          f"{gated}/{dense} weight blocks ({100 * gated / dense:.1f}% of "
          f"dense -> {dense / max(gated, 1):.1f}x traffic reduction; "
          f"batch-tile OR fetches {100 * tiled / tiled_dense:.1f}% of its "
          f"dense)"
          + (f" [serving gate: {args.gate}]" if args.gate else ""))


if __name__ == "__main__":
    main()
