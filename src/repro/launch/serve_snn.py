"""Streaming SNN serving launcher: ``python -m repro.launch.serve_snn``.

Brings up an :class:`~repro.core.session.AcceleratorSession`, deploys one
or more co-resident SNN models, and drives synthetic Poisson request
traffic through the streaming server (``session.serve``): streams arrive
with exponential inter-arrival gaps, wait FIFO for a batch slot, push
their Poisson-encoded stimulus in fixed-size chunks through ONE compiled
slot-batch step, and detach. Reports aggregate steps/s and per-stream
latency percentiles — the "many concurrent stateful streams over one
engine" shape of the heavy-traffic north star.

``--devices N`` (with optional ``--mesh KNxKB``) runs the whole fused
server mesh-sharded (``AcceleratorSession(mesh=...)``): neuron shards
hold their SRAM slice and the slot batch shards over the ``batch`` axis —
byte-identical outputs, scale-out throughput. A
:class:`~repro.distributed.straggler.StragglerDetector` watches per-chunk
step times attributed to batch shards by their live-slot load (FIFO slot
reuse can concentrate live streams on one shard); flagged shards get a
``rebalance_shards`` slot-redistribution suggestion in the summary.
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import pathlib
import subprocess
import sys
import time

import jax
import numpy as np

from repro.bench_schema import SCHEMA_VERSION
from repro.core import coding
from repro.core.energy import EnergyModel, counts_from_registry
from repro.core.engine import BACKENDS, GATES
from repro.core.lif import LIFParams
from repro.core.network import SNNetwork
from repro.core.session import AcceleratorSession
from repro.distributed.spike_mesh import (ensure_host_devices,
                                          make_spike_mesh, parse_mesh_spec)
from repro.distributed.straggler import (StragglerDetector,
                                         observe_from_registry,
                                         rebalance_shards)
from repro.obs import (FlightRecorder, MetricsRegistry, SLObjective,
                       SLOWatchdog, SpanTracer, set_registry)
from repro.obs.tracing import profile_trace
from repro.serving.frontend import BACKPRESSURE, FrontendConfig
from repro.serving.qos import QoSClass, QoSPolicy


def make_net(rng, n_in: int, n_neurons: int, *, density: float = 0.25,
             out: int = 10) -> SNNetwork:
    """Small random recurrent SNN with an output population."""
    W = ((rng.random((n_in + n_neurons, n_neurons)) < density)
         * rng.normal(0.0, 0.5, (n_in + n_neurons, n_neurons)))
    return SNNetwork(
        n_inputs=n_in, n_neurons=n_neurons,
        weights=W.astype(np.float32),
        params=LIFParams(decay_rate=0.25, threshold=1.0, reset_mode="zero"),
        output_slice=(n_neurons - out, n_neurons))


class ShardLoadWatch:
    """Straggler watch over the serving loop's synchronous dispatches.

    A single-controller SPMD step yields ONE host-observed wall time per
    chunk; true per-shard times need multi-controller timing. What IS
    observable per batch shard is its live-slot load, so each dispatch's
    time is attributed to shards proportionally to the live slots they
    own (slots map to batch shards contiguously, `slot // slots_per
    _shard`). A shard that persistently carries more live streams than
    the fleet — which FIFO slot reuse can produce — accumulates strikes
    and earns a ``rebalance_shards`` suggestion.
    """

    # a shard earns a rebalance suggestion only when flagged in at least
    # this fraction of dispatches (and at least twice): a transient
    # 3-chunk imbalance at admission time should not brand the whole run.
    PERSISTENT_FRACTION = 0.1

    def __init__(self, n_shards: int, n_slots: int, registry=None,
                 tracer=None):
        self.n_shards = int(n_shards)
        self.n_slots = int(n_slots)
        padded = -(-n_slots // n_shards) * n_shards
        self.slots_per_shard = padded // n_shards
        self.detector = StragglerDetector(num_hosts=n_shards,
                                          warmup_steps=3, patience=3)
        #: optional MetricsRegistry: each dispatch publishes the
        #: attributed per-shard times as ``snn_shard_step_seconds``
        #: gauges and the detector step runs THROUGH the registry
        #: (straggler.observe_from_registry), so the exported timings are
        #: exactly what the flags were computed from.
        self.registry = registry
        #: optional SpanTracer: each dispatch records one ``shard_step``
        #: span (per-shard attributed times + the flags they produced) —
        #: the mesh-lane record repro.obs.timeline folds into a
        #: per-device barrier breakdown and replay-verifies against a
        #: fresh detector.
        self.tracer = tracer
        self.flag_counts = np.zeros(n_shards, np.int64)
        self.chunk_times: list[float] = []

    def observe(self, dt: float, live_slots) -> None:
        self.chunk_times.append(dt)
        load = np.zeros(self.n_shards)
        for slot in live_slots:
            load[slot // self.slots_per_shard] += 1
        mean = load.mean()
        attributed = dt * load / mean if mean > 0 else np.full(
            self.n_shards, dt)
        if self.registry is not None:
            fam = self.registry.gauge("snn_shard_step_seconds")
            for shard, t in enumerate(attributed):
                fam.labels(shard=shard).set(float(t))
            flags = observe_from_registry(self.detector, self.registry,
                                          tracer=self.tracer)
        else:
            flags = self.detector.observe(attributed)
            if self.tracer is not None:
                self.tracer.event("shard_step", None,
                                  times=[float(t) for t in attributed],
                                  flags=[int(f) for f in flags])
        self.flag_counts += flags

    def persistent_flags(self) -> np.ndarray:
        """Shards flagged persistently enough to act on (bool mask)."""
        return self.flag_counts >= max(
            2, int(self.PERSISTENT_FRACTION * max(len(self.chunk_times), 1)))

    def report(self) -> dict | None:
        """Structured straggler-watch summary (None before any dispatch)."""
        if not self.chunk_times:
            return None
        ct = np.asarray(self.chunk_times) * 1e3
        rep = {
            "dispatches": len(ct),
            "n_shards": self.n_shards,
            "dispatch_ms": {"p50": float(np.percentile(ct, 50)),
                            "p95": float(np.percentile(ct, 95))},
        }
        if self.n_shards > 1:
            persistent = self.persistent_flags()
            rep.update({
                "attributed_mean_s": float(self.detector.stats["mean"]
                                           .mean()),
                "flag_counts": self.flag_counts.tolist(),
                "persistent": np.where(persistent)[0].tolist(),
                "all_flagged": bool(persistent.all()),
                "suggested_slot_split": rebalance_shards(
                    self.n_slots, persistent).tolist(),
            })
        return rep


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=24,
                    help="total streams to serve")
    ap.add_argument("--n-slots", type=int, default=8,
                    help="batch slots (concurrent streams)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="timesteps pushed per feed() call")
    ap.add_argument("--steps-per-stream", type=int, default=48,
                    help="inference timesteps each stream requests")
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="Poisson arrivals per chunk-round (sync mode) or "
                         "per SECOND, open-loop (--async): async arrivals "
                         "happen on the wall clock whether or not the step "
                         "loop keeps up, so overload is observable")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="drive traffic through the AsyncSpikeFrontend "
                         "request queue (admission decoupled from the "
                         "step loop) instead of the synchronous loop")
    ap.add_argument("--backpressure", choices=list(BACKPRESSURE),
                    default="reject",
                    help="frontend policy when the request queue is full "
                         "(--async only): reject the new request, block "
                         "the submitter, or drop the oldest queued one")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline (--async only): requests "
                         "past it are expired — refused while queued, "
                         "evicted mid-stream with the slot carry zeroed")
    ap.add_argument("--queue-capacity", type=int, default=32,
                    help="bounded frontend request queue (--async only); "
                         "backpressure engages beyond it")
    ap.add_argument("--qos", default=None, metavar="SPEC",
                    help="multi-tenant QoS admission (--async only): a "
                         "comma list of NAME=PRIO:WEIGHT[:QUOTA[:RATE"
                         "[:BURST]]] tenant classes (strict priority "
                         "strata, weighted fair queueing inside one, "
                         "optional concurrent-slot quota and token-bucket "
                         "rate limit). Requests are assigned tenants "
                         "round-robin over the classes; omit for the "
                         "plain FIFO front door")
    ap.add_argument("--qos-preempt", action="store_true",
                    help="SLO-aware eviction (--qos only): under overload "
                         "a queued request whose class strictly outranks "
                         "a running stream sheds the lowest-priority "
                         "running stream — its carry is PARKED through "
                         "the connector and resumes bit-clean, never "
                         "dropped")
    ap.add_argument("--burst", default=None, metavar="NAME",
                    help="adversarial traffic mix (--async only): the "
                         "NAME tenant's requests abandon the Poisson plan "
                         "and arrive as one dense burst at --burst-at, "
                         "spaced by --burst-rate, on top of the "
                         "background load — the overload that makes "
                         "per-class isolation measurable")
    ap.add_argument("--burst-rate", type=float, default=None,
                    help="arrivals per second inside the burst "
                         "(default: 10x --arrival-rate)")
    ap.add_argument("--burst-at", type=float, default=None,
                    help="burst start time in seconds (default: 25%% "
                         "into the background arrival span)")
    ap.add_argument("--slo-p99-ms", type=float, default=None,
                    help="SLO objective (--async only): p99 total "
                         "(submit-to-retire) latency must stay under this "
                         "many ms on the rolling window; breaches count "
                         "in the summary and trip the flight recorder")
    ap.add_argument("--slo-miss-ratio", type=float, default=None,
                    help="SLO objective (--async only): deadline "
                         "misses / (misses + dones) must stay under this "
                         "ratio on the rolling window")
    ap.add_argument("--slo-queue-depth", type=int, default=None,
                    help="SLO objective (--async only): the admission "
                         "queue must stay at or under this depth on the "
                         "rolling window")
    ap.add_argument("--slo-window-s", type=float, default=60.0,
                    help="rolling window (seconds) the --slo-* objectives "
                         "are evaluated over (burn rate = observed value "
                         "over threshold on this window)")
    ap.add_argument("--flight", default=None, metavar="FILE",
                    help="arm a bounded flight recorder (last-N lifecycle "
                         "spans + metric deltas): dumps a post-mortem "
                         "JSON to FILE on any crash or --slo-* breach")
    ap.add_argument("--backend", choices=list(BACKENDS), default="reference")
    ap.add_argument("--gate", choices=list(GATES), default=None,
                    help="event-gate granularity of the serving engine "
                         "(per-example = the batch-tile=1 serving mode)")
    ap.add_argument("--fuse-steps", type=int, default=1,
                    help="K timesteps per fused kernel window on the "
                         "serving engine (Pallas backends; weight blocks "
                         "fetched once per window, outputs byte-identical "
                         "for any K)")
    ap.add_argument("--models", type=int, default=2,
                    help="co-resident models sharing the fused engine")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the fused server over N devices "
                         "(faked host devices on CPU)")
    ap.add_argument("--mesh", default=None, metavar="KNxKB",
                    help="neuron x batch mesh split for --devices "
                         "(default: 2 x N/2 when N allows)")
    ap.add_argument("--connector", default=None, metavar="DIR",
                    help="root a FILE-backed stream-state carry connector "
                         "at DIR (default: in-memory): redeploy drains, "
                         "shard rebalances, and async deadline spills park "
                         "carries there, and parked snapshots survive the "
                         "process (crash recovery)")
    ap.add_argument("--drain", type=int, default=None, metavar="ROUND",
                    help="rolling redeploy drill (sync mode): after ROUND "
                         "chunk-rounds, hot-deploy one extra model — live "
                         "streams are drained to the connector and "
                         "restored into the new fused server mid-flight "
                         "(byte-identical continuation)")
    ap.add_argument("--metrics", default=None, metavar="FILE|-",
                    help="write the run's final Prometheus text exposition "
                         "(every metric in repro.obs.METRIC_SPECS) to FILE, "
                         "or '-' for stdout")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="export the stream-lifecycle span log (queued -> "
                         "admitted -> chunk_step -> parked/migrated -> "
                         "retired) as JSONL to FILE")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the serving loop "
                         "into DIR, with lifecycle spans mirrored as trace "
                         "annotations")
    ap.add_argument("--json-summary", nargs="?", const="-", default=None,
                    metavar="FILE",
                    help="also emit the structured run summary as one JSON "
                         "object (machine-readable run report; same data "
                         "the human-readable lines are formatted from) — "
                         "to stdout, or to FILE when given, ready to feed "
                         "into scripts/bench_compare.py")
    ap.add_argument("--n-inputs", type=int, default=24)
    ap.add_argument("--n-neurons", type=int, default=48)
    ap.add_argument("--intensity", type=float, default=0.25,
                    help="stimulus intensity scale (Poisson spike rate "
                         "cap); event workloads live well below 1.0")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _parse_qos(spec: str, *, preempt: bool = False) -> QoSPolicy:
    """``NAME=PRIO:WEIGHT[:QUOTA[:RATE[:BURST]]],...`` -> QoSPolicy.

    Empty optional fields keep their defaults, e.g.
    ``hi=2:4,bg=0:1:2:0.5`` is a 2-stratum policy whose background class
    is capped at 2 slots and 0.5 admissions/s.
    """
    classes = {}
    for entry in spec.split(","):
        entry = entry.strip()
        name, eq, rest = entry.partition("=")
        parts = rest.split(":")
        if not name or not eq or len(parts) < 2 or len(parts) > 5:
            raise SystemExit(
                f"--qos entry {entry!r} is not "
                f"NAME=PRIO:WEIGHT[:QUOTA[:RATE[:BURST]]]")
        try:
            classes[name.strip()] = QoSClass(
                priority=int(parts[0]),
                weight=int(parts[1]),
                max_slots=(int(parts[2])
                           if len(parts) > 2 and parts[2] else None),
                rate_per_s=(float(parts[3])
                            if len(parts) > 3 and parts[3] else None),
                burst=(int(parts[4])
                       if len(parts) > 4 and parts[4] else 1),
            )
        except ValueError as e:
            raise SystemExit(f"--qos entry {entry!r}: {e}")
    return QoSPolicy(classes=classes, preempt=preempt)


def _assign_tenants(args, qos: QoSPolicy | None, n: int) -> list[str]:
    """Deterministic tenant labels for the synthetic request plan:
    round-robin over the QoS classes (declaration order), or over
    {burst tenant, "default"} when only --burst shapes the traffic —
    the FIFO baseline then offers the SAME per-tenant load a QoS run
    does, so the two runs' per-class percentiles compare directly."""
    if qos is not None and qos.classes:
        names = list(qos.classes)
    elif args.burst:
        names = [args.burst, "default"]
    else:
        return ["default"] * n
    return [names[i % len(names)] for i in range(n)]


def _fmt_lat(stats: dict) -> str:
    """'mean X ms, p50 Y ms, p95 Z ms' from a latency_percentiles dict."""
    if stats["mean"] is None:
        return "n/a (no samples)"
    return (f"mean {stats['mean'] * 1e3:.1f} ms, "
            f"p50 {stats['p50'] * 1e3:.1f} ms, "
            f"p95 {stats['p95'] * 1e3:.1f} ms")


# ---------------------------------------------------------------------
# Run summary: ONE structured dict built from the registry snapshot (plus
# the loop's host-side timings), rendered by ONE formatter. The
# human-readable "[serve-snn] ..." lines and the --json-summary object
# are two views of the same data — there is no third accounting.
# ---------------------------------------------------------------------
def _server_report(registry: MetricsRegistry) -> dict:
    """The instrumented SpikeServer's measured-work counters."""
    c = registry.counter
    ev = c("snn_server_source_events_total")
    return {
        "chunks": int(c("snn_server_chunks_total").value),
        "steps": int(c("snn_server_steps_total").value),
        "spikes": int(c("snn_server_spikes_total").value),
        "source_events": {
            "external": int(ev.labels(kind="external").value),
            "recurrent": int(ev.labels(kind="recurrent").value),
        },
        "sops": int(c("snn_server_sops_total").value),
        "row_fetches": int(c("snn_server_row_fetches_total").value),
        "weight_blocks": {
            "fetched": int(c("snn_server_weight_blocks_fetched_total")
                           .value),
            "dense": int(c("snn_server_weight_blocks_dense_total").value),
        },
    }


def _energy_report(registry: MetricsRegistry) -> dict | None:
    """Price the live run with the Table-V-calibrated model (None until
    the server has measured any SOPs)."""
    counts = counts_from_registry(registry)
    if counts.sops == 0:
        return None
    model = EnergyModel.calibrated()
    return {
        "sops": counts.sops,
        "row_fetches": counts.row_fetches,
        "cycles_ref_duty": counts.cycles,
        "breakdown_mw": model.breakdown_mw(counts),
        "energy_uj": model.energy_uj(counts),
    }


def _render_summary(s: dict) -> list[str]:
    """The human-readable lines for a run-summary dict."""
    lines = []
    if s["mode"] == "async":
        fe, c = s["frontend"], s["frontend"]["counts"]
        lines.append(
            f"[serve-snn] async front door: {s['requests']} requests "
            f"offered open-loop at {s['offered_rate_per_s']:.1f}/s "
            f"(policy={s['policy']}, queue capacity {s['queue_capacity']}, "
            f"deadline {s['deadline_ms']} ms), served in "
            f"{s['wall_s']:.2f}s over {fe['rounds']} pump rounds")
        lines.append(
            f"[serve-snn] outcomes: {c['done']} done, {c['rejected']} "
            f"rejected, {c['dropped']} dropped, {c['expired']} expired "
            f"({c['expired_queued']} queued / {c['expired_running']} "
            f"mid-stream), {c['cancelled']} cancelled; {s['steps']} "
            f"stream-timesteps -> {s['steps_per_s']:.0f} steps/s")
        if c["parked"]:
            lines.append(
                f"[serve-snn] spill-on-evict: {c['parked']} mid-stream "
                f"evictions ({c['evicted']} QoS preemptions) parked "
                f"their carry in the connector, {c['resumed']} resumed "
                f"bit-clean")
        lines.append(
            f"[serve-snn] queue depth: max {fe['queue_depth']['max']}, "
            f"mean {fe['queue_depth']['mean']:.1f} "
            f"(capacity {s['queue_capacity']})")
        lines.append(f"[serve-snn] queue-wait: {_fmt_lat(fe['queue_wait'])}")
        lines.append(f"[serve-snn] service:    {_fmt_lat(fe['service'])}")
        lines.append(f"[serve-snn] total:      {_fmt_lat(fe['total'])}")
        if s.get("qos"):
            q = s["qos"]
            lines.append(
                f"[serve-snn] qos: {len(q['classes'])} tenant classes "
                f"(quantum {q['quantum']}, preempt "
                f"{'on' if q['preempt'] else 'off'})"
                + (f"; burst tenant {s['burst']['tenant']!r}: "
                   f"{s['burst']['requests']} requests at "
                   f"{s['burst']['rate_per_s']:.1f}/s from "
                   f"t={s['burst']['at_s']:.2f}s" if s.get("burst")
                   else ""))
        by_cls = fe.get("by_class") or {}
        if not s.get("qos") and len(by_cls) < 2:
            by_cls = {}          # single-tenant FIFO: the global lines say it all
        for cls in sorted(by_cls):
            d = by_cls[cls]
            cc, tot = d["counts"], d["total"]
            lat = ("total n/a (no samples)" if tot["p50"] is None else
                   f"total p50 {tot['p50'] * 1e3:.1f} ms, "
                   f"p95 {tot['p95'] * 1e3:.1f} ms, "
                   f"p99 {tot['p99'] * 1e3:.1f} ms")
            lines.append(
                f"[serve-snn] class {cls}: {cc['done']} done, "
                f"{cc['rejected'] + cc['dropped']} shed, "
                f"{cc['expired']} expired, {cc['evicted']} preempted; "
                f"{lat}")
        if s.get("slo"):
            parts = [f"{o['name']} burn {o['burn_rate']:.2f}"
                     + (" BREACHING" if o["breached"] else "")
                     for o in s["slo"]["objectives"]]
            lines.append(f"[serve-snn] SLO: {'; '.join(parts)} "
                         f"(breach onsets {s['slo']['breaches']})")
    else:
        lines.append(
            f"[serve-snn] {s['streams_done']} streams, {s['steps']} "
            f"stream-timesteps in {s['wall_s']:.2f}s over {s['rounds']} "
            f"rounds -> {s['steps_per_s']:.0f} steps/s")
        lat = s["stream_latency_ms"]
        if lat is not None:
            lines.append(
                f"[serve-snn] per-stream latency: mean {lat['mean']:.1f} "
                f"ms, p50 {lat['p50']:.1f} ms, p95 {lat['p95']:.1f} ms "
                f"(queueing under {s['n_slots']} slots)")
        lines.extend(_render_straggler(s["straggler"], s["n_slots"]))
        sp, eg = s["sparsity"], s["event_gate"]
        lines.append(
            f"[serve-snn] stream spike sparsity: input mean "
            f"{sp['input_mean_pct']:.2f}% (p50 {sp['input_p50_pct']:.2f}%), "
            f"output mean {sp['output_mean_pct']:.2f}%")
        lines.append(
            f"[serve-snn] event gate on served rasters: per-example "
            f"{eg['gated']}/{eg['dense']} weight blocks "
            f"({100 * eg['gated'] / eg['dense']:.1f}% of dense -> "
            f"{eg['dense'] / max(eg['gated'], 1):.1f}x traffic reduction; "
            f"batch-tile OR fetches "
            f"{100 * eg['tiled'] / eg['tiled_dense']:.1f}% of its dense)"
            + (f" [serving gate: {eg['serving_gate']}]"
               if eg["serving_gate"] else ""))
    en = s.get("energy")
    if en is not None:
        bk, uj = en["breakdown_mw"], en["energy_uj"]
        lines.append(
            f"[serve-snn] live energy (Table-V reference duty): "
            f"{en['sops']:.0f} measured SOPs, {en['row_fetches']:.0f} row "
            f"fetches -> {uj['total_uj']:.1f} uJ at {bk['total_mw']:.0f} mW "
            f"avg ({bk['weight_memory_pct']:.1f}% weight memory)")
    return lines


def _render_straggler(rep: dict | None, n_slots: int) -> list[str]:
    if rep is None:
        return []
    d = rep["dispatch_ms"]
    if rep["n_shards"] <= 1:
        # unsharded run: no shards to attribute or rebalance — report
        # the dispatch-time distribution only
        return [
            f"[serve-snn] {rep['dispatches']} chunk dispatches: "
            f"p50 {d['p50']:.1f} ms, p95 {d['p95']:.1f} ms"
        ]
    lines = [
        f"[serve-snn] straggler watch over {rep['dispatches']} chunk "
        f"dispatches x {rep['n_shards']} batch shards: load-attributed "
        f"step time mean {rep['attributed_mean_s']:.4f}s "
        f"(dispatch p50 {d['p50']:.1f} ms, p95 {d['p95']:.1f} ms), "
        f"per-shard flag counts {rep['flag_counts']}"
    ]
    if rep["persistent"] and not rep["all_flagged"]:
        lines.append(
            f"[serve-snn] persistently overloaded shard(s) "
            f"{rep['persistent']} -> suggested slot rebalance "
            f"{rep['suggested_slot_split']} (of {n_slots} slots)")
    elif rep["all_flagged"]:
        lines.append(
            "[serve-snn] all shards flagged together (fleet-wide "
            "step-time stretch, not a per-shard straggler); slot "
            f"split unchanged {rep['suggested_slot_split']}")
    else:
        lines.append(
            "[serve-snn] no persistently overloaded shards; slot "
            f"split stays uniform {rep['suggested_slot_split']}")
    return lines


def _git_commit() -> str | None:
    """The repo's HEAD commit (None outside a git checkout)."""
    try:
        r = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, cwd=pathlib.Path(__file__).resolve().parent)
        return r.stdout.strip() if r.returncode == 0 else None
    except Exception:
        return None


def _summary_meta(args) -> dict:
    """Provenance block joining a run summary to the BENCH_*.json
    trajectory: the git commit it ran at, the bench schema version its
    axes follow, and the run's values on the cross-bench axes (the join
    key scripts/bench_compare.py groups on)."""
    return {
        "git_commit": _git_commit(),
        "bench_schema": SCHEMA_VERSION,
        "axes": {
            "backend": args.backend,
            "gate": args.gate,
            "batch": args.n_slots,
            "devices": args.devices,
            "fuse_steps": args.fuse_steps,
        },
    }


def emit_summary(args, summary: dict, metrics: MetricsRegistry,
                 tracer: SpanTracer) -> None:
    """The single summary emitter: render the structured summary, then
    honor --json-summary / --metrics / --trace."""
    summary.setdefault("meta", _summary_meta(args))
    for line in _render_summary(summary):
        print(line)
    if args.json_summary is not None:
        text = json.dumps(summary, indent=2, sort_keys=True, default=float)
        if args.json_summary == "-":
            print(text)
        else:
            with open(args.json_summary, "w") as f:
                f.write(text + "\n")
    if args.metrics is not None:
        text = metrics.to_prometheus()
        if args.metrics == "-":
            sys.stdout.write(text)
        else:
            with open(args.metrics, "w") as f:
                f.write(text)
    if args.trace is not None:
        n = tracer.export_jsonl(args.trace)
        print(f"[serve-snn] wrote {n} lifecycle spans to {args.trace}")


def run_async(args, server, views, requests, rng, metrics,
              recorder=None) -> dict:
    """Open-loop async serving: arrivals on the wall clock, not the loop.

    Requests are submitted at precomputed Poisson arrival TIMES (rate =
    ``--arrival-rate`` per second) whether or not the pump loop has kept
    up — the decoupling that makes overload observable: when arrivals
    outpace the service rate the queue depth grows until backpressure
    (reject / block / drop-oldest) or ``--deadline-ms`` expiry sheds
    load, and the wait/service/total percentiles split cleanly. The loop
    always terminates: every pump round retires, admits, or expires work,
    and the request plan is finite (no deadlock under any overload).
    """
    fe = next(iter(views.values())).frontend
    assert fe is not None and all(v.frontend is fe for v in views.values()), \
        "co-resident views must share one frontend queue"
    if args.devices > 1 or args.gate:
        print("[serve-snn] note: the straggler watch and event-sparsity "
              "summaries are sync-mode only; the async run reports the "
              "front-door metrics below (the engine itself is still "
              "sharded/gated as requested)")
    tenants = _assign_tenants(args, fe.qos, len(requests))
    arrive_at = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                          len(requests)))
    burst_plan = None
    if args.burst:
        # the burst tenant abandons the Poisson plan: its requests land
        # as one dense train on top of the background load
        burst_idx = [i for i, t in enumerate(tenants) if t == args.burst]
        if not burst_idx:
            raise SystemExit(
                f"--burst {args.burst!r} matches no tenant (classes: "
                f"{sorted(set(tenants))})")
        at = (args.burst_at if args.burst_at is not None
              else 0.25 * float(arrive_at[-1]))
        rate = (args.burst_rate if args.burst_rate is not None
                else 10.0 * args.arrival_rate)
        for j, i in enumerate(burst_idx):
            arrive_at[i] = at + j / rate
        burst_plan = {"tenant": args.burst, "at_s": at,
                      "rate_per_s": rate, "requests": len(burst_idx)}
    # submissions happen in arrival-time order (the burst reorders it)
    order = np.argsort(arrive_at, kind="stable")
    plan = [(float(arrive_at[k]), requests[k][1], requests[k][2],
             tenants[k]) for k in order]
    handles: list = []
    resumed: set = set()
    i = 0
    t0 = time.perf_counter()
    while i < len(plan) or not fe.idle or any(
            h.state == "parked" for h in handles):
        now = time.perf_counter() - t0
        while i < len(plan) and plan[i][0] <= now:
            _, name, spikes, tenant = plan[i]
            handles.append(views[name].submit(spikes, tenant=tenant))
            i += 1
        # spill-on-evict (deadline + connector): a parked request's carry
        # sits in the connector; give each ONE resume — it continues
        # where it left off, byte-identically — then shed it for good
        for h in handles:
            if h.state == "parked":
                if h.rid in resumed or not fe.resume(
                        h, deadline_ms=args.deadline_ms):
                    h.cancel()
                else:
                    resumed.add(h.rid)
        if fe.idle:
            # nothing queued or running: open-loop means we wait for the
            # next ARRIVAL, not spin the step loop
            if i < len(plan):
                time.sleep(min(0.05, max(
                    0.0, plan[i][0] - (time.perf_counter() - t0))))
            continue
        fe.pump()
        if recorder is not None:
            recorder.note_metrics(metrics)
    wall = time.perf_counter() - t0

    steps = server.total_steps
    return {
        "mode": "async",
        "requests": len(requests),
        "offered_rate_per_s": len(requests) / plan[-1][0],
        "policy": args.backpressure,
        "queue_capacity": fe.queue_capacity,
        "deadline_ms": args.deadline_ms,
        "qos": None if fe.qos is None else {
            "classes": {name: dataclasses.asdict(spec)
                        for name, spec in fe.qos.classes.items()},
            "quantum": fe.qos.quantum,
            "preempt": fe.qos.preempt,
        },
        "burst": burst_plan,
        "wall_s": wall,
        "steps": int(steps),
        "steps_per_s": steps / wall,
        "frontend": fe.metrics(),
        "slo": None if fe.slo is None else fe.slo.report(),
        "server": _server_report(metrics),
        "energy": _energy_report(metrics),
    }


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    if args.arrival_rate <= 0:
        raise SystemExit("--arrival-rate must be > 0 (arrivals per "
                         "chunk-round in sync mode, per second with "
                         "--async; the arrival plan cannot make progress "
                         "at rate 0)")
    if args.mesh and args.devices <= 1:
        raise SystemExit("--mesh requires --devices N (N > 1); without it "
                         "the server would silently run unsharded")
    if args.drain is not None and args.async_mode:
        raise SystemExit("--drain is a sync-mode drill (the async frontend "
                         "is rebuilt by the redeploy; resubmit instead)")
    if args.drain is not None and args.drain < 1:
        raise SystemExit("--drain must be >= 1 (chunk-rounds before the "
                         "hot redeploy)")
    slo_flags = (args.slo_p99_ms, args.slo_miss_ratio, args.slo_queue_depth)
    if any(v is not None for v in slo_flags) and not args.async_mode:
        raise SystemExit("--slo-* objectives are --async only (the "
                         "frontend pump feeds the watchdog; the sync loop "
                         "has no request deadlines or admission queue)")
    if ((args.qos or args.qos_preempt or args.burst
         or args.burst_rate is not None or args.burst_at is not None)
            and not args.async_mode):
        raise SystemExit("--qos/--qos-preempt/--burst* shape the async "
                         "admission queue; they require --async (the "
                         "sync loop has no front door to arbitrate)")
    if args.qos_preempt and not args.qos:
        raise SystemExit("--qos-preempt needs a --qos policy: preemption "
                         "is ranked by the tenant classes it declares")
    if ((args.burst_rate is not None or args.burst_at is not None)
            and not args.burst):
        raise SystemExit("--burst-rate/--burst-at shape the --burst "
                         "tenant's arrival train; name it with --burst")
    qos_policy = (None if args.qos is None
                  else _parse_qos(args.qos, preempt=args.qos_preempt))
    if (args.burst and qos_policy is not None
            and args.burst not in qos_policy.classes):
        raise SystemExit(f"--burst {args.burst!r} is not a --qos class "
                         f"({sorted(qos_policy.classes)}); the burst "
                         f"tenant must be one the policy ranks")

    mesh = None
    if args.devices > 1:
        # before the first jax device use, so faked CPU devices can land
        ensure_host_devices(args.devices)
        try:
            kn, kb = parse_mesh_spec(args.devices, args.mesh)
        except ValueError as e:
            raise SystemExit(str(e))
        mesh = make_spike_mesh(neuron=kn, batch=kb)

    rng = np.random.default_rng(args.seed)
    connector = None
    if args.connector is not None:
        from repro.serving.connector import FileCarryConnector
        connector = FileCarryConnector(args.connector)
    # one registry + tracer for the whole run: the session threads them
    # through the server, frontend, and connector it builds. Also
    # installed as the process-wide default so tools can export it.
    metrics = MetricsRegistry()
    # --flight: the recorder rides the tracer's sink protocol, so the
    # ring always holds the freshest spans with no second recording path
    recorder = None if args.flight is None else FlightRecorder(
        path=args.flight)
    tracer = SpanTracer(annotate=args.profile is not None, sink=recorder)
    set_registry(metrics)
    objectives = []
    if args.slo_p99_ms is not None:
        objectives.append(SLObjective("latency_p99", "latency_p99",
                                      args.slo_p99_ms / 1e3,
                                      window_s=args.slo_window_s))
    if args.slo_miss_ratio is not None:
        objectives.append(SLObjective("miss_ratio", "miss_ratio",
                                      args.slo_miss_ratio,
                                      window_s=args.slo_window_s))
    if args.slo_queue_depth is not None:
        objectives.append(SLObjective("queue_depth", "queue_depth",
                                      float(args.slo_queue_depth),
                                      window_s=args.slo_window_s))
    slo = None if not objectives else SLOWatchdog(
        objectives, registry=metrics,
        on_breach=(recorder.on_breach,) if recorder is not None else ())
    sess = AcceleratorSession(backend=args.backend, mesh=mesh,
                              fuse_steps=args.fuse_steps,
                              connector=connector,
                              metrics=metrics, tracer=tracer)
    names = [f"snn{i}" for i in range(args.models)]
    for name in names:
        sess.deploy(name, make_net(rng, args.n_inputs, args.n_neurons))
    # serve AFTER all deploys: deploying invalidates the fused layout
    frontend_cfg = None
    if args.async_mode:
        frontend_cfg = FrontendConfig(
            queue_capacity=args.queue_capacity,
            backpressure=args.backpressure,
            deadline_ms=args.deadline_ms,
            # with a deadline, spill mid-stream expiries to the session
            # connector and resume each once instead of restarting
            # (qos preemption wires the connector through qos.preempt)
            spill=args.deadline_ms is not None,
            slo=slo, qos=qos_policy)
    views = {name: sess.serve(name, n_slots=args.n_slots,
                              chunk_steps=args.chunk, gate=args.gate,
                              frontend=frontend_cfg)
             for name in names}
    server = next(iter(views.values())).server
    assert all(v.server is server for v in views.values()), \
        "co-resident models must share one fused-engine server"
    n_shards = 1 if mesh is None else int(mesh.shape["batch"])
    mesh_note = "" if mesh is None else (
        f", mesh {mesh.shape['neuron']}x{mesh.shape['batch']} "
        f"(neuron x batch) over {mesh.size} devices")
    print(f"[serve-snn] {args.models} co-resident model(s) on one fused "
          f"engine ({server.engine.n_sources} sources x "
          f"{server.engine.n_phys} neurons), backend={args.backend}, "
          f"{args.n_slots} slots x {args.chunk}-step chunks{mesh_note}")

    watch = ShardLoadWatch(n_shards, args.n_slots, registry=metrics,
                           tracer=tracer)

    # synthetic request plan: stream i -> (model, Poisson-encoded stimulus)
    key = jax.random.key(args.seed)
    requests = []
    for uid in range(args.streams):
        key, k = jax.random.split(key)
        name = names[uid % len(names)]
        intensity = (args.intensity
                     * rng.random((1, args.n_inputs)).astype(np.float32))
        spikes = np.asarray(coding.poisson_encode(
            k, intensity, args.steps_per_stream, dtype=np.int32))[:, 0]
        requests.append((uid, name, spikes))

    crash_net = (recorder.armed() if recorder is not None
                 else contextlib.nullcontext())

    if args.async_mode:
        with crash_net, profile_trace(args.profile):
            summary = run_async(args, server, views, requests, rng, metrics,
                                recorder=recorder)
        emit_summary(args, summary, metrics, tracer)
        return

    # Poisson arrivals: number of new requests per chunk-round
    arrivals: list[list] = []
    i = 0
    while i < len(requests):
        n = int(rng.poisson(args.arrival_rate))
        arrivals.append(requests[i:i + n])
        i += n

    live: dict = {}           # uid -> [name, cursor]
    out_chunks: dict = {uid: [] for uid, _, _ in requests}  # fused rasters
    t_arrive: dict = {}
    t_done: dict = {}
    rebalanced = False
    steps_base = 0            # stream-timesteps served by drained servers
    profile_ctx = profile_trace(args.profile)
    profile_ctx.__enter__()
    t0 = time.perf_counter()
    round_i = 0
    with crash_net:
        while arrivals or live or server.scheduler.waiting:
            now = time.perf_counter()
            if (args.drain is not None and round_i >= args.drain
                    and "hotswap" not in sess.models):
                # rolling-redeploy drill: a NEW model lands mid-run; live
                # streams are drained to the connector by deploy() and
                # restored into the new fused server by the re-serve —
                # their rasters continue byte-identically
                n_live = len(server.scheduler.active)
                steps_base += server.total_steps  # the old server's work
                sess.deploy("hotswap",
                            make_net(rng, args.n_inputs, args.n_neurons))
                views = {name: sess.serve(name, n_slots=args.n_slots,
                                          chunk_steps=args.chunk,
                                          gate=args.gate)
                         for name in names}
                server = next(iter(views.values())).server
                print(f"[serve-snn] --drain: hot-deployed 1 extra model after "
                      f"round {round_i}; {n_live} live stream(s) migrated "
                      f"mid-flight through the "
                      f"{'file' if args.connector else 'in-memory'} connector")
            if arrivals:
                for uid, name, spikes in arrivals.pop(0):
                    views[name].attach(uid)
                    live[uid] = [name, spikes, 0]
                    t_arrive[uid] = now
            # ONE batched dispatch per round: every admitted stream's chunk —
            # across models — embeds into the fused layout and steps together
            done = []
            fused_inputs = {}
            live_slots = []
            for uid, (name, spikes, cur) in live.items():
                slot = server.slot_of(uid)
                if slot is None:
                    continue  # still waiting for a slot
                live_slots.append(slot)
                n = min(args.chunk, len(spikes) - cur)
                fused_inputs[uid] = views[name].embed(spikes[cur:cur + n])
                live[uid][2] = cur + n
                if cur + n >= len(spikes):
                    done.append(uid)
            if fused_inputs:
                t_chunk0 = time.perf_counter()
                res = server.feed(fused_inputs)
                watch.observe(time.perf_counter() - t_chunk0, live_slots)
                for uid, r in res.items():
                    out_chunks[uid].append(r["spikes"])
            if n_shards > 1 and not rebalanced:
                flags = watch.persistent_flags()
                if flags.any() and not flags.all():
                    from repro.serving.connector import rebalance_streams
                    moves = rebalance_streams(
                        server, flags, slots_per_shard=watch.slots_per_shard)
                    if moves:
                        rebalanced = True
                        print(f"[serve-snn] straggler rebalance: migrated "
                              f"{len(moves)} live stream(s) off flagged "
                              f"shard(s) {np.where(flags)[0].tolist()} onto "
                              f"donor-shard slots "
                              f"{[(u, f, t) for u, f, t in moves]} "
                              f"(uid, from, to) — carries moved bit-for-bit")
            for uid in done:
                name = live.pop(uid)[0]
                views[name].detach(uid, reason="done")
                t_done[uid] = time.perf_counter()
            round_i += 1
            if recorder is not None:
                recorder.note_metrics(metrics)
    wall = time.perf_counter() - t0
    profile_ctx.__exit__(None, None, None)

    lats = np.asarray([t_done[u] - t_arrive[u] for u in t_done])
    steps = steps_base + server.total_steps

    # event accounting over the streams actually served: per-stream spike
    # sparsity, and the weight-block traffic the event gate would fetch
    # on these rasters — per-example (batch-tile=1, what a gated serving
    # engine skips per slot) vs the batch-tile OR — from events.trace.
    from repro.core.engine import sources_raster
    from repro.events.trace import block_traffic

    in_sp = np.asarray([spikes.mean() for _, _, spikes in requests])
    ext_stack = np.stack([views[name].embed(spikes)
                          for _, name, spikes in requests], axis=1)
    out_stack = np.stack([np.concatenate(out_chunks[uid], axis=0)
                          for uid, _, _ in requests], axis=1)
    out_sp = out_stack.mean(axis=(0, 2))
    # the same boundary-capture convention the kernel gate sees
    sources = np.asarray(sources_raster(ext_stack, out_stack))
    gated, dense = block_traffic(sources, tile_batch=1)
    tiled, tiled_dense = block_traffic(sources, tile_batch=8)

    summary = {
        "mode": "sync",
        "streams_done": len(t_done),
        "steps": int(steps),
        "wall_s": wall,
        "rounds": round_i,
        "steps_per_s": steps / wall,
        "n_slots": args.n_slots,
        "stream_latency_ms": None if not len(lats) else {
            "mean": float(lats.mean() * 1e3),
            "p50": float(np.percentile(lats, 50) * 1e3),
            "p95": float(np.percentile(lats, 95) * 1e3),
        },
        "straggler": watch.report(),
        "sparsity": {
            "input_mean_pct": float(100 * in_sp.mean()),
            "input_p50_pct": float(100 * np.percentile(in_sp, 50)),
            "output_mean_pct": float(100 * out_sp.mean()),
        },
        "event_gate": {
            "gated": int(gated), "dense": int(dense),
            "tiled": int(tiled), "tiled_dense": int(tiled_dense),
            "serving_gate": args.gate,
        },
        "server": _server_report(metrics),
        "energy": _energy_report(metrics),
    }
    emit_summary(args, summary, metrics, tracer)


if __name__ == "__main__":
    main()
