import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a module entry point (``python -m repro.launch.dryrun``);
the XLA_FLAGS line above executes before ANY jax import so 512 host
devices exist when jax locks the device count.

For each cell it records:
  * compile success (the deliverable: the distribution config is coherent)
  * memory_analysis()  — per-device argument/temp/output bytes (fits proof)
  * cost_analysis()    — per-device HLO flops + bytes (roofline terms)
  * collective bytes   — parsed from the post-SPMD HLO text per collective
    kind (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute)
  * the three roofline terms in seconds + the dominant one.

Results append to a JSON file consumed by benchmarks/roofline.py and
EXPERIMENTS.md.
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro import configs
from repro.configs.shapes import SHAPES
from repro.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import LMHarness, SkipCell
from repro.roofline import roofline_terms

KINDS = {"train": "train", "prefill": "prefill", "decode": "decode"}


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             expert_parallel: bool = False, variant: str | None = None,
             verbose: bool = True) -> dict:
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(len(mesh.devices.flatten()))
    cfg = None
    harness_kw = {}
    if variant:  # §Perf variants: TransformerConfig or harness overrides
        import dataclasses
        base = configs.get_arch(arch_id).CONFIG
        overrides = {}
        for item in variant.split(","):
            k, _, v = item.partition("=")
            val = (v == "" or v.lower() == "true") if v.lower() in (
                "", "true", "false") else (int(v) if v.isdigit() else v)
            if k in ("attn_tp", "micro_rows"):
                harness_kw[k] = val
            else:
                overrides[k] = val
        if overrides:
            cfg = dataclasses.replace(base, **overrides)
    harness = LMHarness(arch_id, cfg=cfg, expert_parallel=expert_parallel,
                        **harness_kw)
    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "chips": n_chips,
        "kind": shape.kind,
        "expert_parallel": expert_parallel,
        "variant": variant,
        "status": "ok",
    }
    try:
        harness.check_cell(shape)
    except SkipCell as e:
        rec["status"] = "skip"
        rec["reason"] = str(e)
        return rec
    t0 = time.time()
    try:
        in_sh, out_sh, args = harness.shardings(shape, mesh, shape.kind)
        step = harness.step_fn(shape, mesh, shape.kind)
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # newer jax returns [dict], older a dict
            ca = ca[0] if ca else {}
        hlo = compiled.as_text()
        # loop-aware totals: cost_analysis() counts while bodies ONCE, so
        # scanned layers/microbatches undercount by 32..832x (DESIGN.md §7;
        # repro.hlo_analysis multiplies by known_trip_count).
        cost = analyze_hlo(hlo)
        coll = cost.as_dict()
        cfg = harness.cfg
        n_micro = (harness.n_microbatches(shape, mesh)
                   if shape.kind == "train" else 1)
        terms = roofline_terms(
            flops_per_device=cost.flops,
            bytes_per_device=cost.bytes_accessed,
            collective_bytes_per_device=coll["total_bytes"],
            cfg=cfg, shape=shape, n_chips=n_chips, n_micro=n_micro,
        )
        rec.update({
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "total_bytes": (ma.argument_size_in_bytes
                                + ma.output_size_in_bytes
                                + ma.temp_size_in_bytes),
            },
            "cost": {
                "flops_per_device": cost.flops,
                "bytes_per_device": cost.bytes_accessed,
                # raw body-once numbers kept for reference
                "xla_flops_body_once": float(ca.get("flops", 0.0)),
                "xla_bytes_body_once": float(ca.get("bytes accessed", 0.0)),
            },
            "collectives": coll,
            "roofline": terms,
        })
        if verbose:
            mem_gb = rec["memory"]["total_bytes"] / 2**30
            print(f"  ok  mem={mem_gb:6.2f} GiB/dev  "
                  f"compute={terms['compute_s']:.3e}s "
                  f"memory={terms['memory_s']:.3e}s "
                  f"collective={terms['collective_s']:.3e}s "
                  f"dominant={terms['dominant']} "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
                  flush=True)
    except SkipCell as e:
        rec["status"] = "skip"
        rec["reason"] = str(e)
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"  FAIL {type(e).__name__}: {str(e)[:200]}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="comma-separated TransformerConfig overrides for "
                         "§Perf variants, e.g. 'seq_parallel_attn=true'")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = configs.list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    records = []
    if os.path.exists(args.out):
        records = json.load(open(args.out))

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, mp, args.expert_parallel, args.variant)
                print(f"[dryrun] {arch} x {shape} x "
                      f"{'multi' if mp else 'single'}"
                      f"{' EP' if args.expert_parallel else ''}"
                      f"{' [' + args.variant + ']' if args.variant else ''}",
                      flush=True)
                rec = run_cell(arch, shape, multi_pod=mp,
                               expert_parallel=args.expert_parallel,
                               variant=args.variant)
                records = [r for r in records
                           if (r["arch"], r["shape"],
                               r["mesh"].startswith("multi"),
                               r.get("expert_parallel", False),
                               r.get("variant")) != key]
                records.append(rec)
                json.dump(records, open(args.out, "w"), indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skip" for r in records)
    n_fail = sum(r["status"] == "fail" for r in records)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_fail} fail "
          f"-> {args.out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
