"""LM training launcher: ``python -m repro.launch.train --arch <id>``.

End-to-end driver over the full substrate: arch registry -> model ->
AdamW -> stateless token pipeline -> fault-tolerant loop (atomic
checkpoints, resume-exact restart, straggler hooks). On this CPU container
the default is each arch's REDUCED config scaled to ~CPU size; pass
``--full`` on real hardware (the production mesh path is exercised by
``repro.launch.dryrun`` — this driver runs on whatever devices exist).

Fault tolerance demo: run with ``--fail-at-step K``, then re-run the same
command — the loop resumes from the latest checkpoint and reproduces the
uninterrupted trajectory (tests/test_system.py pins this).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import lm
from repro.launch.steps import LMHarness
from repro.training import optimizers
from repro.training.loop import LoopConfig, run_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    choices=configs.list_archs())
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="simulate preemption (restart resumes)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    mod = configs.get_arch(args.arch)
    cfg = mod.CONFIG if args.full else mod.REDUCED
    h = LMHarness(args.arch, cfg=cfg, lr=args.lr)
    model = h.model
    opt = optimizers.adamw(
        optimizers.Schedules.warmup_cosine(args.lr, args.steps // 10,
                                           args.steps))
    n_params = sum(
        int(np.prod(s.shape)) for s in jax.tree.leaves(h.param_shapes()))
    print(f"[train] arch={args.arch} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    params = model.init(jax.random.key(0))
    state = {"params": params, "opt": opt.init(params),
             "step": np.asarray(0)}

    @jax.jit
    def step_impl(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        grads, gnorm = optimizers.clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optimizers.apply_updates(params, updates)
        return params, opt_state, loss, gnorm

    def step_fn(state, batch):
        p, o, loss, gnorm = step_impl(state["params"], state["opt"], batch)
        return dict(state, params=p, opt=o), {
            "loss": loss, "grad_norm": gnorm}

    stream = lm.TokenStream(cfg.vocab_size, seed=0)

    def batch_fn(step):
        toks = stream.sample(args.batch, args.seq, step)
        return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "targets": jnp.asarray(toks[:, 1:], jnp.int32)}

    loop_cfg = LoopConfig(
        total_steps=args.steps,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        log_every=args.log_every,
        fail_at_step=args.fail_at_step,
    )
    state = run_loop(loop_cfg, state, step_fn, batch_fn)
    print(f"[train] done at step {int(state['step'])}")


if __name__ == "__main__":
    main()
