"""Surrogate-gradient training for the spiking MLPs (paper §VI-A role).

The paper trains in PyTorch/snnTorch offline, then deploys to hardware.
Here the trainer is JAX end-to-end: rate-encode -> BPTT with fast-sigmoid
surrogate -> Adam; optionally data-parallel under pjit (batch over the
``data`` mesh axis; the model is tiny so params replicate).

Evaluation runs BOTH arithmetic paths on identical spike trains:
  software: float32, exact trained decay;
  hardware: the bit-exact Cerebra-H model (quantized weights, snapped
            shift decay) via repro.core.cerebra_h.
Their accuracy difference is the paper's Table IV deviation.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cerebra_h, coding, software
from repro.snn.model import SNNModelConfig, forward, init_params, to_snnetwork
from repro.training import optimizers

__all__ = ["TrainConfig", "make_train_step", "train", "evaluate_dual"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    model: SNNModelConfig = dataclasses.field(default_factory=SNNModelConfig)
    num_steps_time: int = 25          # T during training
    lr: float = 2e-3
    batch_size: int = 128
    train_steps: int = 300
    rate_reg: float = 1e-6            # hidden-rate regularizer
    grad_clip: float = 1.0
    seed: int = 0


def loss_fn(params, spikes, labels, config: TrainConfig):
    out = forward(params, spikes, config.model)
    counts = out["output_counts"]
    # spike-count cross entropy (snnTorch's ce_rate_loss)
    logits = counts
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    reg = config.rate_reg * out["hidden_spike_total"] / spikes.shape[1]
    acc = jnp.mean((jnp.argmax(counts, -1) == labels).astype(jnp.float32))
    return ce + reg, {"loss": ce, "acc": acc}


def make_train_step(config: TrainConfig, opt: optimizers.Optimizer):
    @jax.jit
    def train_step(params, opt_state, key, images, labels):
        spikes = coding.poisson_encode(
            key, images, config.num_steps_time)
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, spikes, labels, config)
        grads, gnorm = optimizers.clip_by_global_norm(
            grads, config.grad_clip)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optimizers.apply_updates(params, updates)
        # hardware-deployability constraint: clip weights into Q16.16-safe
        # range (also keeps the kernel MXU mode exact)
        clip = config.model.weight_clip
        params = [jnp.clip(w, -clip, clip) for w in params]
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def train(config: TrainConfig, data_iter, *, params=None, opt_state=None,
          start_step: int = 0, log_every: int = 50, log_fn=print):
    """Train; resumable via (params, opt_state, start_step)."""
    # split deterministically BEFORE the init branch so a resumed run (params
    # supplied) folds the same per-step keys as the original run did —
    # resume-exactness depends on it (tests/test_snn_train.py).
    k0, key = jax.random.split(jax.random.key(config.seed))
    if params is None:
        params = init_params(k0, config.model)
    opt = optimizers.adam(config.lr)
    if opt_state is None:
        opt_state = opt.init(params)
    step_fn = make_train_step(config, opt)
    metrics = {}
    for step, images, labels in data_iter:
        key_t = jax.random.fold_in(key, step)
        params, opt_state, metrics = step_fn(
            params, opt_state, key_t, jnp.asarray(images),
            jnp.asarray(labels))
        if log_every and step % log_every == 0:
            log_fn(f"step {step}: loss={float(metrics['loss']):.4f} "
                   f"acc={float(metrics['acc']):.3f}")
    return params, opt_state, metrics


# --------------------------------------------------------------------------
def evaluate_dual(params, config: SNNModelConfig, images, labels, *,
                  num_steps_time: int, seed: int = 0,
                  h_config: cerebra_h.CerebraHConfig | None = None,
                  backend: str = "reference") -> dict:
    """Software vs hardware accuracy on identical spike trains.

    ``backend`` selects the SpikeEngine backend for the hardware model.
    Returns {'software_acc', 'hardware_acc', 'deviation_pct', 'agreement'}.
    """
    net = to_snnetwork(params, config)
    key = jax.random.key(seed)
    spikes = coding.poisson_encode(
        key, jnp.asarray(images), num_steps_time, dtype=jnp.int32)
    labels = np.asarray(labels)

    sw = software.run_software(net, spikes.astype(jnp.float32))
    sw_pred = np.asarray(jnp.argmax(sw["output_counts"], -1))

    program = cerebra_h.compile_network(net, h_config)
    hw = cerebra_h.run(program, spikes, backend=backend)
    hw_pred = np.asarray(jnp.argmax(hw["output_counts"], -1))

    sw_acc = float((sw_pred == labels).mean())
    hw_acc = float((hw_pred == labels).mean())
    return {
        "software_acc": sw_acc,
        "hardware_acc": hw_acc,
        "deviation_pct": (hw_acc - sw_acc) * 100.0,
        "agreement": float((sw_pred == hw_pred).mean()),
        "hw_counts": hw,
    }


# --------------------------------------------------------------------------
# Data-parallel variant (used by examples + distributed tests): batch is
# sharded over the 'data' axis; params replicated; psum happens inside
# jit via sharding constraints — pure pjit, no pmap.
# --------------------------------------------------------------------------

def make_sharded_train_step(config: TrainConfig, opt: optimizers.Optimizer,
                            mesh, data_axis: str = "data"):
    from jax.sharding import NamedSharding, PartitionSpec as P

    batch_sharding = NamedSharding(mesh, P(data_axis))
    replicated = NamedSharding(mesh, P())

    base = make_train_step(config, opt)

    @functools.partial(
        jax.jit,
        in_shardings=(replicated, replicated, replicated,
                      batch_sharding, batch_sharding),
        out_shardings=(replicated, replicated, replicated),
    )
    def step(params, opt_state, key, images, labels):
        return base.__wrapped__(params, opt_state, key, images, labels)

    return step
