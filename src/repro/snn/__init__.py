"""SNN training substrate: surrogate-gradient spiking MLPs + dual eval."""

from repro.snn import model, train  # noqa: F401
