"""Trainable spiking MLP — the software models of the paper's Table IV.

Feed-forward LIF networks (784 -> H -> 10, H in {16..256}) trained with
surrogate-gradient BPTT (fast-sigmoid, as in snnTorch) on rate-coded
inputs. No biases: the Cerebra neurons integrate weighted spikes only, so
a bias-free network deploys 1:1 onto the accelerator.

``to_snnetwork`` converts trained params into the logical network the
mapping compiler consumes — the software model and the deployed hardware
model are THE SAME weights, which is what makes the paper's HW-vs-SW
deviation measurement meaningful.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lif import LIFParams, lif_step_train
from repro.core.network import SNNetwork, feedforward

__all__ = ["SNNModelConfig", "init_params", "forward", "to_snnetwork"]


@dataclasses.dataclass(frozen=True)
class SNNModelConfig:
    layer_sizes: tuple[int, ...] = (784, 128, 10)
    params: LIFParams = dataclasses.field(
        default_factory=lambda: LIFParams(
            decay_rate=0.1, threshold=1.0, reset_mode="zero"))
    surrogate_slope: float = 25.0
    weight_clip: float = 1.0  # keeps Q16.16 + MXU-mode exactness bounds


def init_params(key, config: SNNModelConfig) -> list[jnp.ndarray]:
    sizes = config.layer_sizes
    keys = jax.random.split(key, len(sizes) - 1)
    ws = []
    for k, (fan_in, fan_out) in zip(keys, zip(sizes[:-1], sizes[1:])):
        std = 1.0 / np.sqrt(fan_in)
        ws.append(jax.random.normal(k, (fan_in, fan_out)) * std * 3.0)
    return ws


def forward(params: Sequence[jnp.ndarray], spikes,
            config: SNNModelConfig):
    """Run the spiking MLP over a spike train.

    spikes: (T, B, D_in) float {0,1}. Returns dict with output spike
    counts (B, n_out) and total hidden spike count (for rate regularizers).
    """
    lif = config.params
    T, B = spikes.shape[0], spikes.shape[1]
    del T
    n_layers = len(params)
    v0 = [jnp.zeros((B, w.shape[1])) for w in params]

    def step(carry, x_t):
        vs, _ = carry
        new_vs = []
        spk = x_t
        layer_spikes = []
        for i in range(n_layers):
            syn = spk @ params[i]
            state, spk = lif_step_train(
                {"v": vs[i]}, syn, lif, config.surrogate_slope)
            new_vs.append(state["v"])
            layer_spikes.append(spk)
        hidden_count = sum(jnp.sum(s) for s in layer_spikes[:-1])
        return (new_vs, None), (layer_spikes[-1], hidden_count)

    (_, _), (out_spikes, hidden_counts) = jax.lax.scan(
        step, (v0, None), spikes)
    return {
        "output_counts": jnp.sum(out_spikes, axis=0),      # (B, n_out)
        "output_spikes": out_spikes,                        # (T, B, n_out)
        "hidden_spike_total": jnp.sum(hidden_counts),
    }


def to_snnetwork(params: Sequence[jnp.ndarray],
                 config: SNNModelConfig) -> SNNetwork:
    """Freeze trained params into the logical network for deployment."""
    ws = [np.clip(np.asarray(w, np.float32),
                  -config.weight_clip, config.weight_clip) for w in params]
    return feedforward(ws, config.params)
