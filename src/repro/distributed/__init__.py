"""Distribution: partition rules, mesh-sharded spike engine, straggler
mitigation, elastic helpers."""

from repro.distributed import partition, spike_mesh, straggler  # noqa: F401
