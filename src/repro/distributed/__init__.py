"""Distribution: partition rules, straggler mitigation, elastic helpers."""

from repro.distributed import partition, straggler  # noqa: F401
