"""Logical-axis -> mesh partitioning with divisibility fallbacks.

Sharding policy (DESIGN.md §5), in the spirit of the paper's Cerebra-H
memory organization — weights live distributed, close to compute:

  vocab  -> model          (tensor-parallel unembedding/embedding)
  embed  -> data           (ZeRO/FSDP: params + optimizer sharded over the
                            data axis, all-gathered per layer by SPMD)
  heads  -> model          (Megatron-style attention TP)
  ffn    -> model          (Megatron-style MLP TP)
  expert -> None (baseline: TP inside each expert) | model (EP variant)
  batch  -> (pod, data)
  cache_seq -> model       (decode context parallelism; kv heads rarely
                            divide a 16-way axis)

Every rule is subject to a divisibility check against the actual dim; on
failure the dim replicates (e.g. minicpm3's 40 heads, granite-3's 49155
vocab). This is what makes ALL 40 (arch x shape) cells lower+compile.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

__all__ = ["PartitionRules", "params_partition", "batch_partition",
           "cache_partition", "spec_for"]


@dataclasses.dataclass(frozen=True)
class PartitionRules:
    """Logical axis name -> mesh axis (or tuple of mesh axes)."""

    rules: dict[str, Any] = dataclasses.field(default_factory=dict)
    batch_axes: tuple[str, ...] = ("data",)

    @classmethod
    def default(cls, mesh, *, expert_parallel: bool = False,
                attn_tp: bool = True) -> "PartitionRules":
        multi_pod = "pod" in mesh.axis_names
        rules = {
            "vocab": "model",
            "embed": "data",
            # attn_tp=False replicates attention projections — §Perf lever
            # when n_heads doesn't divide the model axis (llama4: 40 on 16)
            # and GSPMD's partial-head resharding dominates collectives.
            "heads": "model" if attn_tp else None,
            "kv": "model" if attn_tp else None,
            "ffn": "model" if not expert_parallel else None,
            # (hypothesis A5 — expert-dim ZeRO over data — REFUTED: GSPMD
            # re-gathers the full expert stacks per use; see §Perf log)
            "expert": "model" if expert_parallel else None,
            "layers": None,
            "cache_seq": "model",
            "cache_batch": ("pod", "data") if multi_pod else ("data",),
        }
        return cls(rules=rules,
                   batch_axes=("pod", "data") if multi_pod else ("data",))


def _axis_size(mesh, mesh_axes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    return int(np.prod([mesh.shape[a] for a in mesh_axes]))


def spec_for(logical_axes, shape, mesh, rules: PartitionRules
             ) -> PartitionSpec:
    """Resolve logical axes to a PartitionSpec with divisibility checks."""
    out = []
    used: set[str] = set()
    for ax, dim in zip(logical_axes, shape):
        mesh_axes = rules.rules.get(ax) if ax is not None else None
        if mesh_axes is None:
            out.append(None)
            continue
        names = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(
            mesh_axes)
        if any(n in used for n in names):
            out.append(None)
            continue
        size = _axis_size(mesh, names)
        if size <= 1 or dim % size != 0:
            out.append(None)
            continue
        used.update(names)
        out.append(mesh_axes if isinstance(mesh_axes, str) else names)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def params_partition(param_shapes, mesh, rules: PartitionRules):
    """Pytree of ShapeDtypeStruct -> pytree of NamedSharding."""
    # deferred: models.transformer imports this module for constrain_batch
    from repro.models.common import axes_of

    def one(path, leaf):
        key = "/".join(_pstr(p) for p in path)
        axes = axes_of(key, leaf)
        return NamedSharding(mesh, spec_for(axes, leaf.shape, mesh, rules))

    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def _pstr(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    return str(entry)


# --------------------------------------------------------------------------
# Activation-sharding context: models call constrain_batch() at the few
# points where GSPMD propagation is known to drop the batch sharding (the
# unembed projection's cotangent replicates a (B,S,V) f32 buffer without
# it). The harness sets the context while tracing; outside any context the
# helpers are no-ops, so model code stays mesh-agnostic.
# --------------------------------------------------------------------------

import contextlib as _contextlib
import contextvars as _contextvars

_ACT_CTX: "_contextvars.ContextVar[tuple | None]" = _contextvars.ContextVar(
    "repro_activation_sharding", default=None)


@_contextlib.contextmanager
def activation_sharding(batch_axes: tuple[str, ...], batch_size: int,
                        mesh):
    size = _axis_size(mesh, tuple(batch_axes))
    tok = _ACT_CTX.set((tuple(batch_axes), size, batch_size))
    try:
        yield
    finally:
        _ACT_CTX.reset(tok)


def constrain_batch(x, batch_axis: int = 0):
    """Constrain x's batch dim to the ambient batch mesh axes (no-op
    outside an activation_sharding context or on non-divisible dims)."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    axes, size, _ = ctx
    if size <= 1 or x.shape[batch_axis] % size != 0:
        return x
    spec = [None] * x.ndim
    spec[batch_axis] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


def constrain_seq(x, seq_axis: int = 1, batch_axis: int | None = 0,
                  mesh_axis: str = "model"):
    """Context-parallel constraint: shard x's sequence dim over ``model``.

    §Perf lever for archs whose head count does not divide the model axis
    (llama4's 40 heads on a 16-way axis): attention math is token-parallel
    in the query dim, so sharding S instead of heads avoids the partial-
    head resharding all-reduces GSPMD otherwise inserts. No-op outside an
    activation context or when S doesn't divide.
    """
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    axes, bsize, _ = ctx
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or mesh_axis not in mesh.axis_names:
        return x
    msize = mesh.shape[mesh_axis]
    if msize <= 1 or x.shape[seq_axis] % msize != 0:
        return x
    spec = [None] * x.ndim
    spec[seq_axis] = mesh_axis
    if (batch_axis is not None and bsize > 1
            and x.shape[batch_axis] % bsize == 0):
        spec[batch_axis] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


def opt_partition(opt_shapes, params_shard, mesh):
    """Adam-style state: {'step', 'm': <params>, 'v': <params>} — moments
    shard exactly like the params (ZeRO-1 falls out of embed->data)."""
    replicated = NamedSharding(mesh, PartitionSpec())
    out = {}
    for key, sub in opt_shapes.items():
        if key in ("m", "v", "mu") and sub is not None:
            out[key] = params_shard
        else:
            out[key] = jax.tree.map(lambda _: replicated, sub)
    return out


# --------------------------------------------------------------------------
# Batch / cache shardings (name-pattern based)
# --------------------------------------------------------------------------

_BATCH_AXES_BY_KEY: dict[str, tuple[str | None, ...]] = {
    # (leading axes per rank); "B" = batch, "S" = sequence (replicated for
    # inputs — sequence parallelism for activations is a §Perf lever)
    "tokens": ("B", None),
    "targets": ("B", None),
    "embeds": ("B", None, None),
    "enc_embeds": ("B", None, None),
    "mrope_positions": (None, "B", None),
    "positions": ("B", None),
}


def batch_partition(batch_shapes, mesh, rules: PartitionRules):
    def one(path, leaf):
        key = _pstr(path[-1]) if path else ""
        axes = _BATCH_AXES_BY_KEY.get(key, (None,) * leaf.ndim)
        spec = []
        for ax, dim in zip(axes, leaf.shape):
            if ax == "B":
                size = _axis_size(mesh, rules.batch_axes)
                spec.append(tuple(rules.batch_axes)
                            if dim % size == 0 and size > 1 else None)
            else:
                spec.append(None)
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, PartitionSpec(*spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


_CACHE_AXES_BY_KEY: dict[str, tuple[str | None, ...]] = {
    # per-layer-stacked cache leaves (leading "layers" dim)
    "k": ("layers", "cache_batch", "cache_seq", "kv", None),
    "v": ("layers", "cache_batch", "cache_seq", "kv", None),
    "slot_pos": ("layers", "cache_seq"),
    "ckv": ("layers", "cache_batch", "cache_seq", None),
    "k_rope": ("layers", "cache_batch", "cache_seq", None),
    "ssm": ("layers", "cache_batch", "ffn", None, None),
    "conv": ("layers", "cache_batch", None, "ffn"),
    "state": ("layers", "cache_batch", "heads", None, None),
    "x_att": ("layers", "cache_batch", "embed"),
    "x_ffn": ("layers", "cache_batch", "embed"),
}


def cache_partition(cache_shapes, mesh, rules: PartitionRules):
    """Shardings for (stacked) decode caches by leaf name."""

    def one(path, leaf):
        key = _pstr(path[-1])
        axes = _CACHE_AXES_BY_KEY.get(key, ("layers",) + (None,) *
                                      (leaf.ndim - 1))
        if leaf.ndim > len(axes):
            # split-cache layouts prepend group dims: (G[, nloc], ...)
            axes = (None,) * (leaf.ndim - len(axes)) + tuple(axes)
        axes = axes[-leaf.ndim:] if leaf.ndim < len(axes) else axes
        return NamedSharding(mesh, spec_for(axes, leaf.shape, mesh, rules))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])
