"""Straggler detection & mitigation hooks.

At pod scale, a single slow host (thermal throttling, ECC retry storms,
network flaps) stretches every synchronous step. The detector keeps an
EWMA + variance of per-host step times and flags hosts whose time exceeds
``mean + threshold_sigma * std`` for ``patience`` consecutive steps.

Mitigations are pluggable callbacks; built in:
  * ``rebalance``: shrink the flagged host's data shard (returns a new
    shard-size vector; the stateless data pipeline makes re-sharding a
    pure re-parameterization — no data movement),
  * ``evict``: mark the host for exclusion at the next checkpoint restart
    (elastic scale-down; checkpoints are mesh-agnostic so restart on N-1
    hosts is a load with a different mesh).

The logic is pure and unit-tested with synthetic timings; the wall-clock
plumbing lives in training.loop.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["StragglerDetector", "donor_shards", "observe_from_registry",
           "rebalance_shards"]


@dataclasses.dataclass
class StragglerDetector:
    num_hosts: int
    alpha: float = 0.1              # EWMA coefficient
    threshold_sigma: float = 3.0
    patience: int = 5
    warmup_steps: int = 10

    def __post_init__(self):
        self._mean = np.zeros(self.num_hosts)
        self._var = np.zeros(self.num_hosts)
        self._strikes = np.zeros(self.num_hosts, np.int64)
        self._steps = 0

    def observe(self, step_times: np.ndarray) -> np.ndarray:
        """Feed per-host step times; returns bool mask of flagged hosts."""
        t = np.asarray(step_times, np.float64)
        if t.shape != (self.num_hosts,):
            raise ValueError(f"expected ({self.num_hosts},), got {t.shape}")
        self._steps += 1
        if self._steps == 1:
            self._mean[:] = t
        delta = t - self._mean
        self._mean += self.alpha * delta
        self._var = (1 - self.alpha) * (self._var + self.alpha * delta**2)
        if self._steps <= self.warmup_steps:
            return np.zeros(self.num_hosts, bool)
        fleet_mean = self._mean.mean()
        fleet_std = max(np.sqrt(self._var.mean()), 1e-9)
        slow = t > fleet_mean + self.threshold_sigma * fleet_std
        self._strikes = np.where(slow, self._strikes + 1, 0)
        return self._strikes >= self.patience

    @property
    def stats(self) -> dict:
        return {
            "mean": self._mean.copy(),
            "std": np.sqrt(self._var),
            "strikes": self._strikes.copy(),
        }


def observe_from_registry(detector: StragglerDetector, registry,
                          *, metric: str = "snn_shard_step_seconds",
                          tracer=None) -> np.ndarray:
    """One detector step driven by the registry's per-shard gauges.

    Reads the most recent ``metric`` gauge value for every shard label
    ``0..num_hosts-1`` (an instrumented dispatch loop — serve_snn's
    ShardLoadWatch — sets them each round), feeds the vector to
    :meth:`StragglerDetector.observe`, and mirrors the resulting flags
    back into the ``snn_shard_straggler_flagged`` gauges so the flags are
    exportable alongside the timings. Returns the bool flag mask —
    identical to calling ``observe`` on the same vector directly (pinned
    by tests/test_straggler_obs.py).

    With a ``tracer``, each call also records one ``shard_step`` span
    carrying the per-shard time vector and the flags it produced — the
    mesh-lane record ``repro.obs.timeline.mesh_lanes`` folds into a
    per-device barrier breakdown, and
    ``repro.obs.timeline.verify_shard_lanes`` replays through a fresh
    detector to pin that this registry-transported path and the pure
    ``observe`` agree exactly."""
    fam = registry.gauge(metric)
    times = np.asarray(
        [fam.labels(shard=s).value for s in range(detector.num_hosts)],
        np.float64)
    flags = detector.observe(times)
    flag_fam = registry.gauge("snn_shard_straggler_flagged")
    for shard, f in enumerate(flags):
        flag_fam.labels(shard=shard).set(int(f))
    if tracer is not None:
        tracer.event("shard_step", None,
                     times=[float(t) for t in times],
                     flags=[int(f) for f in flags])
    return flags


def donor_shards(flagged: np.ndarray) -> np.ndarray:
    """The detector's donor list: indices of UNflagged hosts/shards, the
    candidates to receive migrated work. Serving-side live migration
    (``repro.serving.connector.rebalance_streams``) walks streams off
    flagged batch shards onto these."""
    flagged = np.asarray(flagged, bool)
    return np.where(~flagged)[0]


def rebalance_shards(batch_size: int, flagged: np.ndarray,
                     relief: float = 0.5) -> np.ndarray:
    """Shrink flagged hosts' shards by ``relief``, redistribute to the rest.

    Returns per-host shard sizes summing to batch_size.
    """
    n = len(flagged)
    base = batch_size // n
    sizes = np.full(n, base, np.int64)
    sizes[: batch_size - base * n] += 1  # distribute remainder
    if not flagged.any() or flagged.all():
        return sizes
    taken = 0
    for i in np.where(flagged)[0]:
        cut = int(sizes[i] * relief)
        sizes[i] -= cut
        taken += cut
    healthy = np.where(~flagged)[0]
    for j, i in enumerate(healthy):
        sizes[i] += taken // len(healthy) + (1 if j < taken % len(healthy)
                                             else 0)
    assert sizes.sum() == batch_size
    return sizes
