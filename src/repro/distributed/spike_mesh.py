"""Mesh-sharded spike engine — multi-device scale-out for fused SNN inference.

SNAP-V's Cerebra-H breaks the memory–processor bottleneck by distributing
neurons and their weight SRAM across parallel nodes and exchanging spikes
over a hierarchical NoC. This module is the software analogue over a
``jax.sharding.Mesh``:

  Cerebra-H hardware                    mesh engine
  ------------------                    -----------
  node-local weight SRAM slice          weight image partitioned COLUMN-wise
                                        over the ``neuron`` mesh axis — each
                                        device holds only its neurons' rows
                                        of the SRAM image
  neurons assigned to nodes             physical-neuron axis (cluster
                                        ranges) sharded over ``neuron``
  L2 NoC spike broadcast                per-timestep ``all_gather`` of the
                                        boundary spike raster inside the
                                        ``shard_map``-ped scan body
  independent stimulus streams          batch axis sharded over ``batch``
                                        (no communication)

:class:`MeshSpikeEngine` implements the exact timestep contract of
:class:`~repro.core.engine.SpikeEngine` (same ``fire_reset`` epilogue, same
``init_carry`` semantics, same backend set) and is a drop-in replacement:
``run``/``step``/``step_chunk`` take and return the same logical shapes.
Bit-exactness falls out of the partitioning: every output column's int32
accumulate happens entirely on the device that owns the column, over the
FULL all-gathered source vector, so no sum is ever split across devices.

Non-divisible shapes are handled by zero-padding (pad neurons have
all-zero weight rows *and* columns, so they can never perturb a real
neuron even if a degenerate threshold makes them fire; pad batch rows are
sliced off). Padding and un-padding live inside the jitted call, so XLA
fuses them with the scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.engine import SpikeEngine
from repro.distributed.partition import PartitionRules, spec_for

__all__ = [
    "BATCH_AXIS",
    "NEURON_AXIS",
    "SNN_RULES",
    "MeshSpikeEngine",
    "ensure_host_devices",
    "make_spike_mesh",
    "parse_mesh_spec",
]

NEURON_AXIS = "neuron"
BATCH_AXIS = "batch"

# Logical-axis -> mesh-axis rules for SNN arrays, resolved through the same
# spec machinery the LM stack uses (divisibility fallbacks included):
#   neuron -> "neuron"  (physical-neuron / cluster-range axis; weight
#                        columns + carries + rasters)
#   batch  -> "batch"   (independent streams / examples)
# Source and time axes are never sharded: every device consumes the full
# all-gathered source vector, mirroring the NoC broadcast.
SNN_RULES = PartitionRules(
    rules={"neuron": NEURON_AXIS, "batch": BATCH_AXIS},
    batch_axes=(BATCH_AXIS,),
)


def ensure_host_devices(n: int) -> None:
    """Force ``n`` faked host-platform devices (CPU scale-out testing).

    Must run before JAX initializes its backends; an existing
    device-count flag with a smaller count is rewritten. Raises if the
    backend is already up with fewer devices (the env flag can no longer
    take effect then).
    """
    import os
    import re

    if n <= 1:
        return
    flag = f"--xla_force_host_platform_device_count={n}"
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        flags = re.sub(r"--xla_force_host_platform_device_count=\d+",
                       flag, flags)
    else:
        flags = (flags + " " + flag).strip()
    os.environ["XLA_FLAGS"] = flags
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"requested {n} devices but JAX is running with "
            f"{len(jax.devices())}; the backend initialized before "
            f"XLA_FLAGS={flag!r} could take effect — call "
            f"ensure_host_devices() before the first jax device use"
        )


def parse_mesh_spec(devices: int, spec: str | None) -> tuple[int, int]:
    """``'KNxKB'`` -> (neuron, batch) shard counts covering ``devices``.

    ``spec=None`` picks a default split: a 2-way neuron axis when the
    device count allows (e.g. 2x4 on 8), else all-batch. Shared by every
    launcher/bench ``--devices/--mesh`` flag pair.
    """
    if spec:
        kn_s, sep, kb_s = spec.lower().partition("x")
        try:
            if not sep:
                raise ValueError
            kn, kb = int(kn_s), int(kb_s)
            if kn < 1 or kb < 1:
                raise ValueError
        except ValueError:
            raise ValueError(
                f"--mesh must look like 'KNxKB' (e.g. 2x4), got {spec!r}"
            ) from None
    else:
        kn = 2 if devices % 2 == 0 and devices >= 4 else 1
        kb = devices // kn
    if kn * kb != devices:
        raise ValueError(
            f"--mesh {kn}x{kb} does not cover --devices {devices}")
    return kn, kb


def make_spike_mesh(neuron: int = 1, batch: int | None = None,
                    devices=None) -> Mesh:
    """A ``(neuron, batch)`` mesh over ``devices`` (default: all).

    ``batch=None`` spreads every remaining device over the batch axis.
    """
    devices = list(jax.devices() if devices is None else devices)
    if neuron < 1:
        raise ValueError(f"neuron axis must be >= 1, got {neuron}")
    if batch is None:
        batch = max(1, len(devices) // neuron)
    if batch < 1:
        raise ValueError(f"batch axis must be >= 1, got {batch}")
    if neuron * batch > len(devices):
        raise ValueError(
            f"mesh {neuron}x{batch} needs {neuron * batch} devices; "
            f"only {len(devices)} available"
        )
    devs = np.asarray(devices[: neuron * batch]).reshape(neuron, batch)
    return Mesh(devs, (NEURON_AXIS, BATCH_AXIS))


def _pad_up(n: int, k: int) -> int:
    return -(-n // k) * k


class MeshSpikeEngine(SpikeEngine):
    """A :class:`SpikeEngine` sharded over a ``(neuron, batch)`` mesh.

    Each device holds the weight-image columns of its neuron shard (the
    node-local SRAM slice); the scan body all-gathers the previous step's
    boundary spikes across the ``neuron`` axis — the only per-timestep
    communication — and the batch axis shards streams with no communication
    at all. Outputs, carries, and the ``step_chunk`` masked-slot semantics
    are byte-identical to the single-device engine (pinned by
    tests/test_spike_mesh.py).

    ``fuse_steps`` is carried (and preserved by ``from_engine`` /
    ``with_gate``, so to_mesh round-trips keep K), but the mesh scan
    EXECUTES per step regardless: the cross-device boundary-spike exchange
    is mandatory every timestep, so a K-step window cannot be fused across
    the NoC. Outputs stay byte-identical to the fused single-device engine
    by the fusion exactness contract.
    """

    def __init__(self, weights_raw, n_inputs: int, *, mesh: Mesh,
                 decay, threshold_raw: int, reset_mode: str,
                 backend: str = "reference", interpret: bool | None = None,
                 gate: str = "batch-tile", fuse_steps: int = 1):
        super().__init__(
            weights_raw, n_inputs, decay=decay, threshold_raw=threshold_raw,
            reset_mode=reset_mode, backend=backend, interpret=interpret,
            gate=gate, fuse_steps=fuse_steps,
        )
        missing = {NEURON_AXIS, BATCH_AXIS} - set(mesh.axis_names)
        if missing:
            raise ValueError(
                f"mesh must name axes {NEURON_AXIS!r} and {BATCH_AXIS!r} "
                f"(got {mesh.axis_names}); use make_spike_mesh()"
            )
        self.mesh = mesh
        self._kn = int(mesh.shape[NEURON_AXIS])
        self._kb = int(mesh.shape[BATCH_AXIS])
        # pad the physical axis so each device owns an equal neuron shard;
        # the source axis grows with it (recurrent feedback stays square).
        self._pp = _pad_up(self.n_phys, self._kn)
        sp = self.n_inputs + self._pp
        w = np.zeros((sp, self._pp), np.int32)
        w[: self.n_inputs, : self.n_phys] = np.asarray(
            self.weights_raw[: self.n_inputs])
        w[self.n_inputs: self.n_inputs + self.n_phys, : self.n_phys] = (
            np.asarray(self.weights_raw[self.n_inputs:]))
        self._w_spec = spec_for(("source", "neuron"), (sp, self._pp),
                                mesh, SNN_RULES)
        # column-wise: each device materializes only its SRAM image slice
        self._weights_sharded = jax.device_put(
            jnp.asarray(w), NamedSharding(mesh, self._w_spec))

    @classmethod
    def from_engine(cls, engine: SpikeEngine, mesh: Mesh
                    ) -> "MeshSpikeEngine":
        """Re-host an existing engine's program on a mesh (same semantics)."""
        return cls(
            engine.weights_raw, engine.n_inputs, mesh=mesh,
            decay=engine.decay, threshold_raw=engine.threshold_raw,
            reset_mode=engine.reset_mode, backend=engine.backend,
            interpret=engine.interpret, gate=engine.gate,
            fuse_steps=engine.fuse_steps,
        )

    def with_gate(self, gate: str) -> "MeshSpikeEngine":
        """Gate re-host that KEEPS the mesh (the base implementation would
        silently fall back to a single-device engine)."""
        if gate == self.gate:
            return self
        return MeshSpikeEngine(
            self.weights_raw, self.n_inputs, mesh=self.mesh,
            decay=self.decay, threshold_raw=self.threshold_raw,
            reset_mode=self.reset_mode, backend=self.backend,
            interpret=self.interpret, gate=gate,
            fuse_steps=self.fuse_steps,
        )

    def with_fuse_steps(self, fuse_steps: int) -> "MeshSpikeEngine":
        """Fusion re-host that KEEPS the mesh (the base implementation
        would silently fall back to a single-device engine)."""
        if int(fuse_steps) == self.fuse_steps:
            return self
        return MeshSpikeEngine(
            self.weights_raw, self.n_inputs, mesh=self.mesh,
            decay=self.decay, threshold_raw=self.threshold_raw,
            reset_mode=self.reset_mode, backend=self.backend,
            interpret=self.interpret, gate=self.gate,
            fuse_steps=fuse_steps,
        )

    @property
    def device_count(self) -> int:
        return self._kn * self._kb

    # ------------------------------------------------------------------
    def _scan_weights(self):
        return self._weights_sharded

    def _specs(self, batch_padded: int, steps: int):
        """PartitionSpecs for one padded (T, B, ...) dispatch."""
        carry = spec_for(("batch", "neuron"), (batch_padded, self._pp),
                         self.mesh, SNN_RULES)
        ext = spec_for(("time", "batch", "source"),
                       (steps, batch_padded, self.n_inputs),
                       self.mesh, SNN_RULES)
        raster = spec_for(("time", "batch", "neuron"),
                          (steps, batch_padded, self._pp),
                          self.mesh, SNN_RULES)
        active = spec_for(("time", "batch"), (steps, batch_padded),
                          self.mesh, SNN_RULES)
        cdict = {"v": carry, "spikes": carry}
        return cdict, ext, raster, active

    def step(self, carry, ext_t):
        """Sharded single step (closed-loop callers): a T=1 chunk through
        the mesh path, so the column-sharded SRAM image and spike exchange
        are used — the inherited ``step`` would silently compute on the
        full replicated weights."""
        final, spikes = self.step_chunk(carry, ext_t[None])
        return final, spikes[0]

    def _exchange_step(self, weights_local, carry_local, ext_t):
        """One timestep on a neuron shard: NoC exchange + local step.

        The all-gather reassembles the full previous-boundary spike raster
        (the L2 broadcast); everything after it is the unmodified
        single-device step on this device's weight columns, so the shared
        LIF epilogue (and any backend kernel) runs untouched.
        """
        spikes_full = jax.lax.all_gather(
            carry_local["spikes"], NEURON_AXIS, axis=1, tiled=True)
        return self._step(
            weights_local,
            {"v": carry_local["v"], "spikes": spikes_full},
            ext_t,
        )

    # ------------------------------------------------------------------
    def _run_impl(self, weights, ext_spikes):
        T, B = ext_spikes.shape[0], ext_spikes.shape[1]
        bp = _pad_up(B, self._kb)
        ext_p = jnp.pad(ext_spikes, ((0, 0), (0, bp - B), (0, 0)))
        carry = {
            "v": jnp.zeros((bp, self._pp), jnp.int32),
            "spikes": jnp.zeros((bp, self._pp), jnp.int32),
        }
        cspec, espec, rspec, _ = self._specs(bp, T)

        def local(weights_l, carry_l, ext_l):
            step = lambda c, x: self._exchange_step(weights_l, c, x)
            return jax.lax.scan(step, carry_l, ext_l)

        final, spikes = shard_map(
            local, mesh=self.mesh,
            in_specs=(self._w_spec, cspec, espec),
            out_specs=(cspec, rspec),
            check_rep=False,
        )(weights, carry, ext_p)
        return {
            "spikes": spikes[:, :B, : self.n_phys],
            "v_final": final["v"][:B, : self.n_phys],
        }

    # ------------------------------------------------------------------
    def _chunk_impl(self, weights, carry, ext, active):
        T, B = ext.shape[0], ext.shape[1]
        bp = _pad_up(B, self._kb)
        ext_p = jnp.pad(ext, ((0, 0), (0, bp - B), (0, 0)))
        active_p = jnp.pad(active, ((0, 0), (0, bp - B)))  # pad slots idle
        pad2 = ((0, bp - B), (0, self._pp - self.n_phys))
        carry_p = {
            "v": jnp.pad(carry["v"], pad2),
            "spikes": jnp.pad(carry["spikes"], pad2),
        }
        cspec, espec, rspec, aspec = self._specs(bp, T)

        def local(weights_l, carry_l, ext_l, active_l):
            step = lambda c, x: self._exchange_step(weights_l, c, x)
            return self._masked_chunk_scan(step, carry_l, ext_l, active_l)

        final, spikes = shard_map(
            local, mesh=self.mesh,
            in_specs=(self._w_spec, cspec, espec, aspec),
            out_specs=(cspec, rspec),
            check_rep=False,
        )(weights, carry_p, ext_p, active_p)
        final = {
            "v": final["v"][:B, : self.n_phys],
            "spikes": final["spikes"][:B, : self.n_phys],
        }
        return final, spikes[:, :B, : self.n_phys]
