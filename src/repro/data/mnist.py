"""MNIST-compatible data pipeline.

This container is offline. The loader first looks for real MNIST IDX files
(``MNIST_DIR`` env var or ``data/mnist/``); when absent it falls back to
**procedural MNIST**: 28x28 digit glyphs rendered from per-class stroke
skeletons with random affine jitter (shift/scale/rotate), stroke-width
variation and pixel noise — a drop-in, deterministic, infinitely large
10-class dataset with the same shape/range contract as MNIST. The paper's
HW-vs-SW deviation study needs *identical spike trains through two
arithmetic paths*, which is dataset-agnostic; absolute accuracies are
analogous, and EXPERIMENTS.md flags which dataset produced them.

Everything is generated from ``(seed, index)`` counters: batches are
reproducible, shardable across hosts, and resumable by step number with no
iterator state to checkpoint.
"""

from __future__ import annotations

import gzip
import os
import struct

import numpy as np

__all__ = ["load_or_generate", "batches", "render_digits", "GLYPHS"]

# --------------------------------------------------------------------------
# Per-class stroke skeletons in the unit square (x right, y down).
# Polylines; rendered with gaussian falloff around each segment.
# --------------------------------------------------------------------------
GLYPHS: dict[int, list[list[tuple[float, float]]]] = {
    0: [[(0.5, 0.12), (0.76, 0.3), (0.76, 0.7), (0.5, 0.88),
         (0.24, 0.7), (0.24, 0.3), (0.5, 0.12)]],
    1: [[(0.35, 0.3), (0.55, 0.12), (0.55, 0.88)],
        [(0.35, 0.88), (0.72, 0.88)]],
    2: [[(0.26, 0.3), (0.4, 0.14), (0.64, 0.14), (0.74, 0.32),
         (0.62, 0.52), (0.3, 0.74), (0.26, 0.86)],
        [(0.26, 0.86), (0.76, 0.86)]],
    3: [[(0.28, 0.18), (0.6, 0.14), (0.72, 0.3), (0.55, 0.47)],
        [(0.42, 0.47), (0.72, 0.52), (0.72, 0.72), (0.55, 0.88),
         (0.28, 0.82)]],
    4: [[(0.62, 0.88), (0.62, 0.12), (0.26, 0.62), (0.78, 0.62)]],
    5: [[(0.72, 0.14), (0.3, 0.14), (0.28, 0.48), (0.6, 0.44),
         (0.74, 0.6), (0.68, 0.82), (0.3, 0.86)]],
    6: [[(0.66, 0.14), (0.38, 0.36), (0.28, 0.62), (0.4, 0.84),
         (0.64, 0.84), (0.72, 0.64), (0.58, 0.5), (0.32, 0.56)]],
    7: [[(0.26, 0.14), (0.76, 0.14), (0.48, 0.88)],
        [(0.36, 0.5), (0.66, 0.5)]],
    8: [[(0.5, 0.14), (0.7, 0.26), (0.62, 0.46), (0.5, 0.5),
         (0.38, 0.46), (0.3, 0.26), (0.5, 0.14)],
        [(0.5, 0.5), (0.72, 0.62), (0.64, 0.84), (0.5, 0.88),
         (0.36, 0.84), (0.28, 0.62), (0.5, 0.5)]],
    9: [[(0.68, 0.44), (0.42, 0.5), (0.28, 0.36), (0.36, 0.16),
         (0.6, 0.12), (0.72, 0.3), (0.68, 0.44), (0.62, 0.88)]],
}


def _segment_distance(px, py, ax, ay, bx, by):
    """Vectorized point-to-segment distance."""
    abx, aby = bx - ax, by - ay
    apx, apy = px - ax, py - ay
    denom = abx * abx + aby * aby + 1e-12
    t = np.clip((apx * abx + apy * aby) / denom, 0.0, 1.0)
    cx, cy = ax + t * abx, ay + t * aby
    return np.sqrt((px - cx) ** 2 + (py - cy) ** 2)


def render_digits(labels: np.ndarray, seed: int, size: int = 28,
                  jitter: bool = True) -> np.ndarray:
    """Render a batch of digit images. labels: (B,) -> (B, size, size) f32."""
    rng = np.random.default_rng(seed)
    B = len(labels)
    ys, xs = np.mgrid[0:size, 0:size]
    xs = (xs + 0.5) / size
    ys = (ys + 0.5) / size
    out = np.zeros((B, size, size), np.float32)
    if jitter:
        theta = rng.uniform(-0.22, 0.22, B)
        scale = rng.uniform(0.85, 1.12, B)
        dx = rng.uniform(-0.1, 0.1, B)
        dy = rng.uniform(-0.1, 0.1, B)
        width = rng.uniform(0.035, 0.055, B)
    else:
        theta = np.zeros(B); scale = np.ones(B)
        dx = np.zeros(B); dy = np.zeros(B)
        width = np.full(B, 0.045)
    for i, lab in enumerate(np.asarray(labels)):
        c, s = np.cos(theta[i]), np.sin(theta[i])
        # inverse-transform pixel coords into glyph space
        gx = ((xs - 0.5 - dx[i]) * c + (ys - 0.5 - dy[i]) * s) / scale[i] + 0.5
        gy = (-(xs - 0.5 - dx[i]) * s + (ys - 0.5 - dy[i]) * c) / scale[i] + 0.5
        dist = np.full_like(gx, 1e9)
        for stroke in GLYPHS[int(lab)]:
            pts = np.asarray(stroke)
            for (ax, ay), (bx, by) in zip(pts[:-1], pts[1:]):
                dist = np.minimum(
                    dist, _segment_distance(gx, gy, ax, ay, bx, by))
        img = np.exp(-0.5 * (dist / width[i]) ** 2)
        if jitter:
            img = img + rng.normal(0, 0.02, img.shape)
        out[i] = np.clip(img, 0.0, 1.0)
    return out


# --------------------------------------------------------------------------
# Real-MNIST IDX loading (used transparently when files exist)
# --------------------------------------------------------------------------

def _read_idx(path: str) -> np.ndarray:
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rb") as f:
        magic, = struct.unpack(">I", f.read(4))
        ndim = magic & 0xFF
        shape = struct.unpack(">" + "I" * ndim, f.read(4 * ndim))
        return np.frombuffer(f.read(), np.uint8).reshape(shape)


def _find_mnist_dir() -> str | None:
    for cand in (os.environ.get("MNIST_DIR"), "data/mnist",
                 "/root/data/mnist"):
        if cand and os.path.isdir(cand):
            return cand
    return None


def load_or_generate(split: str, n: int, seed: int = 0
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Return (images (n,784) f32 in [0,1], labels (n,) i32)."""
    d = _find_mnist_dir()
    if d is not None:
        prefix = "train" if split == "train" else "t10k"
        try:
            imgs = _read_idx(_first(d, f"{prefix}-images-idx3-ubyte"))
            labs = _read_idx(_first(d, f"{prefix}-labels-idx1-ubyte"))
            imgs = imgs[:n].reshape(len(imgs[:n]), -1).astype(np.float32) / 255.0
            return imgs, labs[:n].astype(np.int32)
        except (FileNotFoundError, ValueError):
            pass
    base = 0 if split == "train" else 1_000_003
    rng = np.random.default_rng(seed + base)
    labels = rng.integers(0, 10, n).astype(np.int32)
    images = render_digits(labels, seed=seed + base + 7)
    return images.reshape(n, -1), labels


def _first(d: str, stem: str) -> str:
    for suffix in ("", ".gz"):
        p = os.path.join(d, stem + suffix)
        if os.path.exists(p):
            return p
    raise FileNotFoundError(stem)


def batches(split: str, batch_size: int, num_steps: int, *, seed: int = 0,
            start_step: int = 0, shard_index: int = 0, num_shards: int = 1):
    """Stateless batch generator: batch(step) is a pure function.

    Resumability: restart at any ``start_step`` and the stream continues
    exactly; sharding: each host renders only its shard (seed mixes in the
    shard index), no cross-host coordination needed.
    """
    for step in range(start_step, num_steps):
        s = seed * 1_000_000 + step * num_shards + shard_index
        base = 0 if split == "train" else 977
        rng = np.random.default_rng(s + base)
        labels = rng.integers(0, 10, batch_size).astype(np.int32)
        images = render_digits(labels, seed=s + base + 13)
        yield step, images.reshape(batch_size, -1), labels
