"""Synthetic event-camera gestures — a DVS-gesture-style sparse workload.

A dynamic-vision sensor emits an event only where log-intensity CHANGES:
a moving stimulus produces a thin rim of ON events at its leading edge
and OFF events at its trailing edge, and a static scene produces silence.
That is exactly the activity regime the event-gated datapath is built
for, so this module renders one procedurally: a Gaussian blob follows a
per-class trajectory (swipes, circles, diagonals) across a small sensor,
frames are differenced against a change threshold, and the resulting
ON/OFF events become the external spike raster — typically 1–5 % dense.

Same determinism contract as :mod:`repro.data.mnist`: everything derives
from ``(seed, split, index)`` counters — reproducible, shardable, no
iterator state. Channel layout is ``polarity * size^2 + y * size + x``
(ON block first), so ``n_channels = 2 * size * size``.
"""

from __future__ import annotations

import numpy as np

from repro.events.aer import AERStream, dense_to_aer

__all__ = ["GESTURES", "n_channels", "gesture_raster", "gesture_events"]

GESTURES: tuple[str, ...] = (
    "swipe_right", "swipe_left", "swipe_up", "swipe_down",
    "circle_cw", "circle_ccw", "diag_rise", "diag_fall",
)


def n_channels(size: int = 16) -> int:
    """External spike channels a ``size`` x ``size`` sensor produces."""
    return 2 * size * size


def _trajectory(label: int, u: np.ndarray, rng) -> tuple[np.ndarray, np.ndarray]:
    """Blob center (x, y) in [0,1]^2 along the class trajectory at
    progress ``u`` in [0,1], with per-sample jitter."""
    lo, hi = 0.18, 0.82
    phase = rng.uniform(0, 2 * np.pi)
    wobble = rng.uniform(0.0, 0.04)
    off = rng.uniform(-0.06, 0.06, 2)
    path = lo + (hi - lo) * u
    anti = hi - (hi - lo) * u
    mid = 0.5 + wobble * np.sin(2 * np.pi * u + phase)
    name = GESTURES[label]
    if name == "swipe_right":
        x, y = path, mid
    elif name == "swipe_left":
        x, y = anti, mid
    elif name == "swipe_up":
        x, y = mid, anti
    elif name == "swipe_down":
        x, y = mid, path
    elif name in ("circle_cw", "circle_ccw"):
        r = rng.uniform(0.2, 0.3)
        sign = -1.0 if name == "circle_cw" else 1.0
        ang = phase + sign * 2 * np.pi * u
        x, y = 0.5 + r * np.cos(ang), 0.5 + r * np.sin(ang)
    elif name == "diag_rise":
        x, y = path, anti
    else:  # diag_fall
        x, y = path, path
    return np.clip(x + off[0], 0, 1), np.clip(y + off[1], 0, 1)


def gesture_raster(split: str, n: int, *, steps: int = 32, size: int = 16,
                   seed: int = 0, threshold: float = 0.14,
                   noise: float = 5e-4) -> tuple[np.ndarray, np.ndarray]:
    """Render a batch of event-camera gesture clips.

    Returns:
      (events (steps, n, 2*size*size) int32 {0,1}, labels (n,) int32).
      Channel block 0 is ON (intensity rose past ``threshold``), block 1
      is OFF; step 0 differences against a dark sensor, so a clip opens
      with the blob's appearance burst — as a real sensor would.
    """
    base = 0 if split == "train" else 1_000_003
    rng = np.random.default_rng(seed + base)
    labels = rng.integers(0, len(GESTURES), n).astype(np.int32)
    ys, xs = np.mgrid[0:size, 0:size]
    xs = (xs + 0.5) / size
    ys = (ys + 0.5) / size
    u = np.linspace(0.0, 1.0, steps)
    out = np.zeros((steps, n, 2 * size * size), np.int32)
    for i, lab in enumerate(labels):
        srng = np.random.default_rng(seed + base + 7919 * (i + 1))
        cx, cy = _trajectory(int(lab), u, srng)
        sigma = srng.uniform(0.05, 0.08)
        frames = np.exp(
            -((xs[None] - cx[:, None, None]) ** 2
              + (ys[None] - cy[:, None, None]) ** 2) / (2 * sigma ** 2)
        )  # (T, size, size)
        diff = np.diff(frames, axis=0, prepend=np.zeros((1, size, size)))
        on = (diff > threshold).reshape(steps, -1)
        off = (diff < -threshold).reshape(steps, -1)
        ev = np.concatenate([on, off], axis=-1)
        if noise > 0:
            ev |= srng.random(ev.shape) < noise  # sensor background rate
        out[:, i] = ev.astype(np.int32)
    return out, labels


def gesture_events(split: str, n: int, *, steps: int = 32, size: int = 16,
                   seed: int = 0, capacity: int | None = None,
                   **kw) -> tuple[AERStream, np.ndarray]:
    """The same clips as :func:`gesture_raster`, in wire format: one AER
    stream addressing ``(steps, n, 2*size*size)``. ``capacity=None``
    sizes the stream exactly to the event count (no overflow possible);
    an explicit capacity keeps the strict "error" policy."""
    dense, labels = gesture_raster(split, n, steps=steps, size=size,
                                   seed=seed, **kw)
    if capacity is None:
        capacity = int(dense.sum())
    return dense_to_aer(dense, capacity), labels
