"""Synthetic LM token pipeline (offline container — no corpora on disk).

Generates deterministic, learnable token streams for the LM examples and
integration tests: a second-order Markov source over a Zipf-distributed
vocabulary (next token = mix(hash(prev, prev2), zipf noise)). Perplexity is
reducible by learning the transition structure, so train-loss curves are
meaningful; content is irrelevant for systems work.

Same stateless contract as the MNIST pipeline: ``batch(step)`` is a pure
function of (seed, step, shard) — resumable and shardable with no iterator
state.
"""

from __future__ import annotations

import numpy as np

__all__ = ["TokenStream", "lm_batches"]


class TokenStream:
    def __init__(self, vocab_size: int, seed: int = 0,
                 structure: float = 0.8):
        self.vocab_size = int(vocab_size)
        self.seed = seed
        self.structure = structure
        # Zipf weights over vocab (heavy head, long tail)
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        w = 1.0 / ranks ** 1.1
        self.probs = (w / w.sum()).astype(np.float64)

    def _hash_next(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        h = (a.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
             ^ b.astype(np.uint64) * np.uint64(0xC2B2AE3D27D4EB4F))
        h ^= h >> np.uint64(29)
        h *= np.uint64(0xBF58476D1CE4E5B9)
        h ^= h >> np.uint64(32)
        return (h % np.uint64(self.vocab_size)).astype(np.int64)

    def sample(self, batch: int, seq_len: int, step: int,
               shard_index: int = 0, num_shards: int = 1) -> np.ndarray:
        s = (self.seed * 2_000_003 + step * num_shards + shard_index)
        rng = np.random.default_rng(s)
        toks = np.empty((batch, seq_len + 1), np.int64)
        toks[:, 0] = rng.choice(self.vocab_size, batch, p=self.probs)
        toks[:, 1] = rng.choice(self.vocab_size, batch, p=self.probs)
        for t in range(2, seq_len + 1):
            structured = self._hash_next(toks[:, t - 1], toks[:, t - 2])
            noise = rng.choice(self.vocab_size, batch, p=self.probs)
            use = rng.random(batch) < self.structure
            toks[:, t] = np.where(use, structured, noise)
        return toks


def lm_batches(vocab_size: int, batch: int, seq_len: int, num_steps: int, *,
               seed: int = 0, start_step: int = 0, shard_index: int = 0,
               num_shards: int = 1):
    """Yields (step, tokens (B,S) i32, targets (B,S) i32)."""
    stream = TokenStream(vocab_size, seed)
    for step in range(start_step, num_steps):
        toks = stream.sample(batch, seq_len, step, shard_index, num_shards)
        yield step, toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
