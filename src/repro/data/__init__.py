"""Deterministic, stateless, shardable data pipelines.

  mnist  — procedural MNIST (or real IDX files when present)
  lm     — synthetic Markov/Zipf token streams for the LM archs
  events — synthetic event-camera (DVS-gesture-style) sparse spike clips
"""

from repro.data import events, lm, mnist  # noqa: F401
