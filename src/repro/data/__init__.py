"""Deterministic, stateless, shardable data pipelines.

  mnist — procedural MNIST (or real IDX files when present)
  lm    — synthetic Markov/Zipf token streams for the LM archs
"""

from repro.data import lm, mnist  # noqa: F401
