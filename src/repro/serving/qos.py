"""Multi-tenant QoS admission policy for the async front door.

SNAP-V's management core exists so many small SNN workloads can share
one accelerator; PR 5's :class:`~repro.serving.frontend.AsyncSpikeFrontend`
gave them a front door but admitted strictly FIFO — one bursty tenant
starves everyone behind it. This module is the admission *policy* layer
the frontend consults when built with ``qos=``:

  * :class:`QoSClass` — one tenant class: ``priority`` (strict strata,
    higher admits first), ``weight`` (fair share inside a stratum),
    ``max_slots`` (concurrent-slot quota), ``rate_per_s`` + ``burst``
    (token bucket on the frontend's injectable clock).
  * :class:`QoSPolicy` — the frozen bundle of classes plus the DRR
    ``quantum`` and the ``preempt`` switch (SLO-aware eviction: shed the
    lowest-priority running stream, parking its carry through the PR 7
    connector rather than dropping it).
  * :class:`WeightedFairQueue` — per-class FIFO queues scheduled by
    deficit round-robin inside the highest eligible priority stratum.
    Deficits are measured in *timesteps* (a request's cost is its
    ``steps_total``), so weights fair-share actual service demand the
    way classic DRR fair-shares bytes.
  * :func:`choose_victim` — the deterministic preemption rule: lowest
    priority first, newest request (highest rid) within it.

Determinism contract (pinned by tests/test_serving_qos.py): every
decision here — which class admits, which request within it, which
running stream is shed — is a pure function of the submit / cancel /
pump op sequence and the injected clock values. No wall time, no
randomness, no iteration over unordered containers. QoS never touches
the numerical path: it reorders WHEN requests run, never what they
compute (the frontend's exactness contract carries over unchanged).
"""

from __future__ import annotations

import collections
import dataclasses

__all__ = [
    "QoSClass",
    "QoSPolicy",
    "WeightedFairQueue",
    "choose_victim",
]


@dataclasses.dataclass(frozen=True)
class QoSClass:
    """Admission parameters for one tenant class.

    ``priority`` ranks strata (strictly higher admits first whenever it
    has eligible work); ``weight`` scales the DRR quantum inside a
    stratum (a weight-4 class is granted 4x the timestep deficit of a
    weight-1 peer per scheduling visit); ``max_slots`` caps the class's
    concurrently running streams (None = unlimited); ``rate_per_s`` +
    ``burst`` arm a token bucket on the frontend clock — each admission
    consumes one token, tokens refill at ``rate_per_s`` up to ``burst``
    (None rate = unlimited). A class blocked by quota or tokens yields
    its turn; lower strata may use the slot (work conservation).
    """

    priority: int = 0
    weight: int = 1
    max_slots: int | None = None
    rate_per_s: float | None = None
    burst: int = 1

    def __post_init__(self):
        if self.weight < 1:
            raise ValueError(f"weight must be >= 1, got {self.weight}")
        if self.max_slots is not None and self.max_slots < 1:
            raise ValueError(
                f"max_slots must be >= 1 or None, got {self.max_slots}")
        if self.rate_per_s is not None and self.rate_per_s <= 0:
            raise ValueError(
                f"rate_per_s must be positive or None, got "
                f"{self.rate_per_s}")
        if self.burst < 1:
            raise ValueError(f"burst must be >= 1, got {self.burst}")


@dataclasses.dataclass(frozen=True)
class QoSPolicy:
    """The knob bundle ``AsyncSpikeFrontend(qos=...)`` /
    ``FrontendConfig(qos=...)`` take.

    ``classes`` maps tenant name -> :class:`QoSClass`; a request's
    tenant (``submit(..., tenant=)``, defaulting to its view name) not
    in the map gets ``default``. ``quantum`` is the DRR base grant in
    timesteps per scheduling visit (multiplied by the class weight).
    ``preempt`` enables SLO-aware eviction: under overload, a queued
    request whose class strictly outranks a running stream sheds the
    lowest-priority running stream — its carry is parked through the
    frontend's connector (required when ``preempt`` is set) and the
    victim re-queues at the head of its class, continuing bit-clean
    once pressure clears.
    """

    classes: dict[str, QoSClass] = dataclasses.field(default_factory=dict)
    default: QoSClass = dataclasses.field(default_factory=QoSClass)
    quantum: int = 8
    preempt: bool = False

    def __post_init__(self):
        if self.quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {self.quantum}")
        for name, spec in self.classes.items():
            if not isinstance(spec, QoSClass):
                raise TypeError(
                    f"class {name!r} must be a QoSClass, got "
                    f"{type(spec).__name__}")

    def spec_of(self, tenant: str) -> QoSClass:
        return self.classes.get(tenant, self.default)


class WeightedFairQueue:
    """Per-class FIFO queues under strict priority + deficit round-robin.

    Drop-in for the frontend's single ``deque`` (``len`` / ``bool`` /
    iteration / ``append`` / ``appendleft`` / ``remove`` / ``index``
    all work), plus the scheduling verbs the pump uses:

      * :meth:`pop_admissible` — the next request the policy grants a
        slot (or None when every queued class is blocked by quota or
        tokens). Consumes one token and charges the class deficit.
      * :meth:`top_eligible_priority` — the highest stratum that could
        admit right now (the preemption trigger).
      * :meth:`drop_victim` — backpressure shedding: the oldest request
        of the lowest-priority non-empty class.
      * :meth:`note_released` — a running stream of the class finished /
        was evicted (quota bookkeeping).

    Iteration (and therefore ``index``, the handle's queue_position)
    runs priority-descending, then class first-seen order, then FIFO
    within the class — the order the scheduler itself favors.
    """

    def __init__(self, policy: QoSPolicy):
        self.policy = policy
        self._queues: dict[str, collections.deque] = {}
        self._order: list[str] = []            # first-seen ring order
        self._deficit: dict[str, float] = {}
        self._tokens: dict[str, float] = {}
        self._token_at: dict[str, float | None] = {}
        self.running = collections.Counter()   # class -> running streams
        # per-priority DRR cursor: the class currently holding the
        # grant, and whether its quantum for this visit is still owed
        self._drr: dict[int, dict] = {}
        # classes named by the policy exist from the start so quotas /
        # buckets / zero-filled gauges do not depend on traffic order
        for name in policy.classes:
            self._register(name)

    # -- class registry ----------------------------------------------------
    def _register(self, cls: str) -> None:
        if cls not in self._queues:
            self._queues[cls] = collections.deque()
            self._order.append(cls)
            self._deficit[cls] = 0.0
            self._tokens[cls] = float(self.policy.spec_of(cls).burst)
            self._token_at[cls] = None

    @property
    def classes(self) -> tuple[str, ...]:
        """Every class seen so far (policy-declared first)."""
        return tuple(self._order)

    def depth_by_class(self) -> dict[str, int]:
        return {c: len(self._queues[c]) for c in self._order}

    # -- deque-compatible surface -----------------------------------------
    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def __iter__(self):
        for cls in sorted(
                self._order,
                key=lambda c: (-self.policy.spec_of(c).priority,
                               self._order.index(c))):
            yield from self._queues[cls]

    def append(self, req) -> None:
        self._register(req.tenant)
        self._queues[req.tenant].append(req)

    def appendleft(self, req) -> None:
        """Head-of-class re-queue (preempted victims continue first)."""
        self._register(req.tenant)
        self._queues[req.tenant].appendleft(req)

    def remove(self, req) -> None:
        self._queues[req.tenant].remove(req)

    def index(self, req) -> int:
        for i, r in enumerate(self):
            if r is req:
                return i
        raise ValueError("request is not queued")

    # -- eligibility -------------------------------------------------------
    def _refill(self, cls: str, now: float) -> None:
        spec = self.policy.spec_of(cls)
        if spec.rate_per_s is None:
            return
        last = self._token_at[cls]
        if last is None:
            self._token_at[cls] = now
            return
        if now > last:
            self._tokens[cls] = min(
                float(spec.burst),
                self._tokens[cls] + (now - last) * spec.rate_per_s)
            self._token_at[cls] = now

    def _eligible(self, cls: str, now: float) -> bool:
        """May this class admit its head right now? (non-empty queue,
        quota headroom, and a whole token in the bucket)"""
        if not self._queues[cls]:
            return False
        spec = self.policy.spec_of(cls)
        if spec.max_slots is not None and self.running[cls] >= spec.max_slots:
            return False
        if spec.rate_per_s is not None:
            self._refill(cls, now)
            if self._tokens[cls] < 1.0:
                return False
        return True

    def top_eligible_priority(self, now: float) -> int | None:
        """Highest priority that could admit a request right now, or
        None when every queued class is blocked (quota / tokens)."""
        prios = [self.policy.spec_of(c).priority
                 for c in self._order if self._eligible(c, now)]
        return max(prios) if prios else None

    # -- scheduling --------------------------------------------------------
    def pop_admissible(self, now: float):
        """The next request the policy admits, or None.

        Strict priority picks the highest stratum with an eligible
        class; deficit round-robin arbitrates inside it: the cursor
        class is granted ``quantum * weight`` timesteps per visit and
        serves FIFO while its deficit covers the head's ``steps_total``;
        exhausted (or blocked) classes pass the grant on. An emptied
        class forfeits its leftover deficit (classic DRR anti-hoarding).
        Serving consumes one token and counts the stream as running.
        """
        top = self.top_eligible_priority(now)
        if top is None:
            return None
        ring = [c for c in self._order
                if self.policy.spec_of(c).priority == top]
        cur = self._drr.setdefault(top, {"at": None, "grant": True})
        if cur["at"] not in ring:
            cur["at"], cur["grant"] = ring[0], True
        i = ring.index(cur["at"])
        # each full lap grants every eligible class one quantum, so the
        # largest head cost bounds the laps needed before someone serves
        max_cost = max(self._queues[c][0].steps_total
                       for c in ring if self._eligible(c, now))
        budget = len(ring) * (2 + max_cost // self.policy.quantum)
        for _ in range(budget + 1):
            cls = ring[i]
            spec = self.policy.spec_of(cls)
            if self._eligible(cls, now):
                if cur["grant"]:
                    self._deficit[cls] += float(
                        self.policy.quantum * spec.weight)
                    cur["grant"] = False
                head = self._queues[cls][0]
                if self._deficit[cls] >= head.steps_total:
                    self._queues[cls].popleft()
                    self._deficit[cls] -= float(head.steps_total)
                    if not self._queues[cls]:
                        self._deficit[cls] = 0.0
                    if spec.rate_per_s is not None:
                        self._tokens[cls] -= 1.0
                    self.running[cls] += 1
                    cur["at"] = cls           # keep serving while deficit lasts
                    return head
            elif not self._queues[cls]:
                self._deficit[cls] = 0.0
            i = (i + 1) % len(ring)
            cur["at"], cur["grant"] = ring[i], True
        return None     # unreachable: the budget covers the worst case

    def note_admitted(self, req) -> None:
        """Count a stream admitted OUTSIDE pop_admissible (not used by
        the pump today; kept so external drivers keep quotas honest)."""
        self._register(req.tenant)
        self.running[req.tenant] += 1

    def note_released(self, req) -> None:
        """A running stream of this class retired / expired / was
        cancelled or preempted — give its quota unit back."""
        self.running[req.tenant] -= 1

    def drop_victim(self):
        """Backpressure shedding (``drop-oldest`` under QoS): among the
        non-empty classes of the LOWEST priority, drop the oldest
        request (smallest rid) — the least important, stalest work."""
        heads = [self._queues[c][0] for c in self._order
                 if self._queues[c]]
        if not heads:
            raise IndexError("drop_victim on an empty queue")
        low = min(self.policy.spec_of(h.tenant).priority for h in heads)
        victim = min((h for h in heads
                      if self.policy.spec_of(h.tenant).priority == low),
                     key=lambda h: h.rid)
        self._queues[victim.tenant].popleft()
        return victim


def choose_victim(policy: QoSPolicy, running, *, below: int):
    """The preemption rule: among running requests whose class priority
    is strictly below ``below``, shed the lowest-priority one; ties
    break to the NEWEST (highest rid) so long-running streams keep
    their sunk service. Returns None when nothing outranked runs."""
    victims = [r for r in running
               if policy.spec_of(r.tenant).priority < below]
    if not victims:
        return None
    return min(victims,
               key=lambda r: (policy.spec_of(r.tenant).priority, -r.rid))
