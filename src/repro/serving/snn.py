"""Streaming SNN serving — stateful spike streams over one compiled step.

SNAP-V's accelerator is a *stateful* device: membrane potentials persist
across timesteps and spike events are consumed as they arrive, not as
pre-materialized rasters. This module is the host-runtime analogue of that
contract, built with the same fixed-slot discipline the LM ``BatchServer``
uses (one jitted step of a pinned batch shape, reused for all traffic —
the continuous-batching idiom):

  * :class:`SlotScheduler` — admission of stream ids into a fixed set of
    batch slots: FIFO waiting queue, FIFO slot reuse, no double
    assignment. Pure bookkeeping; property-tested.
  * :class:`SpikeServer` — owns the persistent slot carry
    ``{v, spikes}`` (via ``SpikeEngine.init_carry``), chunked
    :meth:`~SpikeServer.feed` (push N timesteps of external spikes per
    stream, get the spike raster / counts back), carry zeroing on
    eviction, and a closed-loop mode where the decoded output of step t
    drives the encoder at step t+1.
  * :class:`ModelStream` — a per-model view over a server running the
    *fused multi-model* engine: co-resident models stream together
    through one physical-array step, each seeing only its own input
    columns and cluster range (``AcceleratorSession.serve``).

Exactness contract (pinned by tests/test_serving_snn.py): for any chunking
of a spike raster — including ragged chunk boundaries and co-resident
traffic in other slots — the concatenated ``feed`` outputs are
byte-for-byte identical to one-shot ``SpikeEngine.run`` on that raster,
for every backend and reset mode. This falls out of the masked step: an
active slot advances exactly as the batch scan body would; an inactive
slot's carry is untouched.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import time

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SpikeEngine

__all__ = ["SlotScheduler", "SpikeServer", "ModelStream", "StreamStats"]

# Source-block granularity the measured-traffic counters account at —
# the kernels' block_src (one weight block per 128 source rows).
_OBS_BLOCK_SRC = 128


class SlotScheduler:
    """Fixed-slot admission bookkeeping (no array state).

    Invariants (property-tested in tests/test_serving_scheduler.py):
      * an active uid occupies exactly one slot; no two share one;
      * a freed slot is handed to the LONGEST-waiting uid (FIFO fairness);
      * freed slots are reused in FIFO order, so slot assignment is a
        deterministic function of the attach/detach sequence.
    """

    def __init__(self, n_slots: int):
        if n_slots <= 0:
            raise ValueError(f"n_slots must be positive, got {n_slots}")
        self.n_slots = int(n_slots)
        self._slot_of: dict = {}                      # uid -> slot
        self._free = collections.deque(range(n_slots))
        self._waiting: collections.deque = collections.deque()

    # -- queries ----------------------------------------------------------
    @property
    def active(self) -> dict:
        """{uid: slot} of admitted streams (copy)."""
        return dict(self._slot_of)

    @property
    def free_slots(self) -> int:
        """Slots with no resident stream (an admission front door checks
        this before attaching, so its own queue — not the scheduler's
        waiting list — is the only place requests ever wait)."""
        return len(self._free)

    @property
    def free_slot_ids(self) -> list:
        """Free slot indices in FIFO-reuse order (copy) — migration
        passes pick targeted destinations from this."""
        return list(self._free)

    @property
    def waiting(self) -> list:
        """uids queued for admission, FIFO order (copy)."""
        return list(self._waiting)

    def slot_of(self, uid) -> int | None:
        """The uid's slot, or None while it waits."""
        if uid in self._slot_of:
            return self._slot_of[uid]
        if uid in self._waiting:
            return None
        raise KeyError(f"unknown stream {uid!r}")

    # -- transitions ------------------------------------------------------
    def submit(self, uid) -> int | None:
        """Admit uid into a free slot, or queue it. Returns the slot or
        None (queued)."""
        if uid in self._slot_of or uid in self._waiting:
            raise ValueError(f"stream {uid!r} already submitted")
        if self._free:
            slot = self._free.popleft()
            self._slot_of[uid] = slot
            return slot
        self._waiting.append(uid)
        return None

    def submit_at(self, uid, slot: int) -> int:
        """Admit uid into a SPECIFIC free slot (migration / rebalance
        placement). Unlike :meth:`submit`, never queues: a targeted
        restore must land now or fail loudly."""
        if uid in self._slot_of or uid in self._waiting:
            raise ValueError(f"stream {uid!r} already submitted")
        if slot not in self._free:
            raise ValueError(f"slot {slot} is not free")
        self._free.remove(slot)
        self._slot_of[uid] = slot
        return slot

    def release(self, uid) -> tuple[int, object | None]:
        """Free uid's slot; the FIFO-head waiter (if any) is admitted into
        it. Returns (freed_slot, admitted_uid_or_None). The caller MUST
        zero the slot's carry before the admitted stream is stepped."""
        if uid not in self._slot_of:
            raise KeyError(f"stream {uid!r} is not active")
        slot = self._slot_of.pop(uid)
        if self._waiting:
            nxt = self._waiting.popleft()
            self._slot_of[nxt] = slot
            return slot, nxt
        self._free.append(slot)
        return slot, None

    def cancel(self, uid) -> None:
        """Withdraw a WAITING uid (never touches slots)."""
        try:
            self._waiting.remove(uid)
        except ValueError:
            raise KeyError(f"stream {uid!r} is not waiting") from None


def decode_aer_chunk(stream, n_inputs: int, label: str = "AER chunk"
                     ) -> np.ndarray:
    """Validate + decode a single-lane ``(T, 1, n_inputs)`` AER chunk to
    its dense ``(T, n_inputs)`` raster — THE entry-point contract shared
    by :meth:`SpikeServer.feed_events` and
    :meth:`~repro.serving.frontend.AsyncSpikeFrontend.submit_events`
    (one lane per stream: the slot address inside the server is the
    server's business, not the caller's)."""
    from repro.events.aer import aer_to_dense

    T, lanes, n_src = stream.shape
    if lanes != 1 or n_src != n_inputs:
        raise ValueError(
            f"{label}: AER chunk must address (T, 1, {n_inputs}), "
            f"got {stream.shape}")
    return np.asarray(aer_to_dense(stream))[:, 0, :]


@dataclasses.dataclass
class StreamStats:
    """Per-stream accounting the server keeps while a stream lives."""

    uid: object
    steps: int = 0               # timesteps consumed so far
    spike_count: int = 0         # total output spikes emitted
    attached_at: float = 0.0     # wall clock at submit()
    admitted_at: float | None = None  # wall clock at slot grant


class SpikeServer:
    """Stateful streaming server: churning spike streams, one compiled step.

    The server pins the slot-batch shape ``(chunk_steps, n_slots)``: every
    :meth:`feed` call is processed as full chunks of ``chunk_steps``
    timesteps padded with inactive steps, so ONE XLA program (per engine)
    serves arbitrary ragged traffic. Slot carries persist across calls;
    :meth:`detach` zeroes the evicted slot so re-attachment starts from
    the unified power-on state (V = 0, no prior spikes).

    ``mesh`` scales the server out over devices: the engine is re-hosted
    as a :class:`~repro.distributed.spike_mesh.MeshSpikeEngine` (neuron
    shards hold their SRAM slice, slot batch sharded over the ``batch``
    axis) with byte-identical ``feed`` semantics — streaming slot-batches
    run sharded with no change to any caller.

    ``gate`` re-hosts the engine under another event-gate granularity
    (see :data:`repro.core.engine.GATES`): serving slot batches are mostly
    idle, so ``gate="per-example"`` — the batch-tile=1 mode — lets every
    silent slot skip its own weight traffic instead of riding along with
    the tile OR. Outputs are bit-identical under either gate.

    ``fuse_steps`` re-hosts the engine under a K-step fused kernel window
    (``SpikeEngine.with_fuse_steps``): each ``feed`` chunk scans K-step
    windows, fetching every weight block once per window instead of once
    per step. ``chunk_steps`` need NOT be K-aligned — the engine pads the
    window remainder with inactive steps under the same masked-slot
    contract that pads ragged chunks, so outputs stay byte-identical.

    ``metrics`` / ``tracer`` (a
    :class:`~repro.obs.metrics.MetricsRegistry` / an
    :class:`~repro.obs.tracing.SpanTracer`) opt the server into
    telemetry: per-chunk latency, slot occupancy, and measured
    SOP/weight-traffic counters (docs/observability.md tables the
    names). Instrumentation is a pure host-side read of arrays ``feed``
    already materializes — it NEVER runs inside the scan, so the
    byte-exactness contract is untouched; with both left ``None`` the
    datapath does zero extra work.
    """

    def __init__(self, engine: SpikeEngine, *, n_slots: int = 8,
                 chunk_steps: int = 8, mesh=None, gate: str | None = None,
                 fuse_steps: int | None = None, metrics=None, tracer=None):
        if chunk_steps <= 0:
            raise ValueError(f"chunk_steps must be positive, got {chunk_steps}")
        if gate is not None:
            engine = engine.with_gate(gate)
        if fuse_steps is not None:
            engine = engine.with_fuse_steps(fuse_steps)
        if mesh is not None and getattr(engine, "mesh", None) is not mesh:
            engine = engine.to_mesh(mesh)
        self.engine = engine
        self.n_slots = int(n_slots)
        self.chunk_steps = int(chunk_steps)
        self.scheduler = SlotScheduler(n_slots)
        self.carry = engine.init_carry(self.n_slots)
        self.streams: dict = {}      # uid -> StreamStats (active + waiting)
        self._auto_uid = itertools.count()
        self.total_steps = 0         # slot-timesteps consumed (all streams)
        self.metrics = metrics
        self.tracer = tracer
        self._prev_host = None       # (n_slots, n_phys) recurrent mirror
        if metrics is not None:
            from repro.core.energy import SOPS_PER_ROW

            w = np.asarray(engine.weights_raw)
            # per-source accounting vectors (trace.py semantics): real
            # nonzero fanout, and nonzero SOPS_PER_ROW-wide row segments
            self._fanout = np.count_nonzero(w, axis=1).astype(np.int64)
            pad = (-w.shape[1]) % SOPS_PER_ROW
            wp = np.pad(w, ((0, 0), (0, pad))) if pad else w
            self._rowseg = (
                (wp.reshape(w.shape[0], -1, SOPS_PER_ROW) != 0)
                .any(axis=2).sum(axis=1).astype(np.int64))
            self._n_src_blocks = -(-engine.n_sources // _OBS_BLOCK_SRC)
            self._prev_host = np.zeros(
                (self.n_slots, engine.n_phys), np.int32)
            metrics.gauge("snn_server_slots_total").set(self.n_slots)
            metrics.gauge("snn_server_slots_occupied").set(0)

    # -- observability ----------------------------------------------------
    def _obs_clock(self):
        if self.metrics is not None:
            return self.metrics.clock
        return self.tracer.clock

    def _obs_occupancy(self) -> None:
        if self.metrics is not None:
            self.metrics.gauge("snn_server_slots_occupied").set(
                len(self.scheduler.active))

    def _obs_count_chunk(self, ext_u: np.ndarray, out_u: np.ndarray,
                         prev_row: np.ndarray) -> np.ndarray:
        """Measured-event accounting for ONE stream's (n, ...) raster
        slice (the closed-loop single-step path; batch dispatches use the
        vectorized pass in :meth:`_obs_feed_chunk`): count source events,
        SOPs (events x real fanout), row fetches, and per-example-gate
        weight-block traffic, exactly as
        :func:`repro.events.trace.trace_run` would measure the same
        rasters. Returns the stream's new recurrent row. Host-side only."""
        m = self.metrics
        prev_u = np.concatenate([prev_row[None, :], out_u[:-1]], axis=0)
        src = np.concatenate([ext_u, prev_u], axis=1) != 0  # (n, S)
        m.counter("snn_server_source_events_total").labels(
            kind="external").inc(int(np.count_nonzero(ext_u)))
        m.counter("snn_server_source_events_total").labels(
            kind="recurrent").inc(int(np.count_nonzero(prev_u)))
        per_src = src.sum(axis=0, dtype=np.int64)  # (S,) event counts
        m.counter("snn_server_sops_total").inc(int(per_src @ self._fanout))
        m.counter("snn_server_row_fetches_total").inc(
            int(per_src @ self._rowseg))
        n, S = src.shape
        pad = self._n_src_blocks * _OBS_BLOCK_SRC - S
        if pad:
            src = np.pad(src, ((0, 0), (0, pad)))
        touched = int(src.reshape(n, self._n_src_blocks, _OBS_BLOCK_SRC)
                      .any(axis=2).sum())
        m.counter("snn_server_weight_blocks_fetched_total").inc(touched)
        m.counter("snn_server_weight_blocks_dense_total").inc(
            n * self._n_src_blocks)
        return out_u[-1]

    def _obs_feed_chunk(self, t_start: float, active: np.ndarray,
                        spikes: np.ndarray, ext: np.ndarray,
                        chunks: dict, t0: int) -> None:
        """Record one chunk dispatch: latency + step/spike counters, a
        chunk_step span, and measured-event accounting.

        The accounting — source events, SOPs (events x real fanout), row
        fetches, per-example-gate weight-block traffic, exactly as
        :func:`repro.events.trace.trace_run` would measure the same
        rasters — runs ONE vectorized pass over the whole ``(T, n_slots,
        ...)`` dispatch rather than per stream: the per-stream loop's
        numpy-call overhead was the single biggest telemetry cost
        (benchmarks/kernel_bench.py --obs-overhead gates the budget).
        Inactive (slot, step) rows are masked out, so the counters match
        the per-stream slicing bit-for-bit on ragged chunks."""
        from repro.obs.tracing import Span

        dt = self._obs_clock()() - t_start
        n_active = int(active.sum())
        if self.tracer is not None:
            now = self.tracer.clock()
            # participating stream uids (slot order), so timeline
            # reconstruction can attribute the chunk to its streams —
            # and audit that each one was admitted at dispatch time
            uids = [uid for uid, (slot, arr) in
                    sorted(chunks.items(), key=lambda kv: kv[1][0])
                    if arr.shape[0] - t0 > 0]
            # duration span timed by the caller (clock read bracketed the
            # dispatch; recording it here keeps the hot loop branch-free)
            self.tracer._record(Span(
                "chunk_step", None, now - dt, now,
                {"steps": n_active, "streams": len(chunks), "uids": uids}))
        if self.metrics is None:
            return
        m = self.metrics
        m.histogram("snn_server_chunk_latency_seconds").observe(dt)
        m.counter("snn_server_chunks_total").inc()
        m.counter("snn_server_steps_total").inc(n_active)
        m.counter("snn_server_spikes_total").inc(int(spikes.sum()))
        mask = active.astype(bool)                      # (T, n_slots)
        if n_active == 0:
            return
        # recurrent source rows: each stream's previous output (its
        # carried row for step 0), masked to the steps it actually ran;
        # the full-chunk case (every slot active every step — the steady
        # state) skips the masking copies entirely
        full = bool(mask.all())
        sp = spikes if full else np.where(mask[:, :, None], spikes, 0)
        prev = np.concatenate([self._prev_host[None], sp[:-1]], axis=0)
        ext_b = ext != 0                                # pre-masked zeros
        prev_b = prev != 0
        if not full:
            prev_b &= mask[:, :, None]
        m.counter("snn_server_source_events_total").labels(
            kind="external").inc(int(ext_b.sum()))
        m.counter("snn_server_source_events_total").labels(
            kind="recurrent").inc(int(prev_b.sum()))
        per_src = np.concatenate(
            [ext_b.sum(axis=(0, 1)), prev_b.sum(axis=(0, 1))]
        ).astype(np.int64)                              # (S,) event counts
        m.counter("snn_server_sops_total").inc(int(per_src @ self._fanout))
        m.counter("snn_server_row_fetches_total").inc(
            int(per_src @ self._rowseg))
        src = np.concatenate([ext_b, prev_b], axis=2)   # (T, n_slots, S)
        T, n_slots, S = src.shape
        pad = self._n_src_blocks * _OBS_BLOCK_SRC - S
        if pad:
            src = np.pad(src, ((0, 0), (0, 0), (0, pad)))
        touched = int(src.reshape(T, n_slots, self._n_src_blocks,
                                  _OBS_BLOCK_SRC).any(axis=3).sum())
        m.counter("snn_server_weight_blocks_fetched_total").inc(touched)
        m.counter("snn_server_weight_blocks_dense_total").inc(
            n_active * self._n_src_blocks)
        # roll each served stream's recurrent row forward to its LAST
        # active step's output (ragged streams end mid-chunk)
        n_per = mask.sum(axis=0)
        served = n_per > 0
        self._prev_host[served] = sp[n_per[served] - 1, served]

    # -- lifecycle --------------------------------------------------------
    def attach(self, uid=None):
        """Register a stream. Returns its uid; ``slot_of(uid)`` is None
        while it waits for a slot (FIFO admission on the next detach)."""
        if uid is None:
            uid = next(self._auto_uid)
            while uid in self.streams:  # caller-chosen uids may collide
                uid = next(self._auto_uid)
        now = time.perf_counter()
        slot = self.scheduler.submit(uid)
        st = StreamStats(uid=uid, attached_at=now)
        if slot is not None:
            st.admitted_at = now
        self.streams[uid] = st
        self._obs_occupancy()
        if self.tracer is not None:
            if slot is None:
                self.tracer.event("queued", uid)
            else:
                self.tracer.event("admitted", uid, slot=slot)
        return uid

    def detach(self, uid, *, reason: str = "detached") -> StreamStats:
        """Evict a stream. Frees + ZEROES its slot (the next occupant must
        power up from clean state); the longest-waiting stream, if any, is
        admitted into the freed slot.

        ``reason`` is observational only (the datapath is identical for
        every reason): it becomes the stream's terminal ``retired`` span
        outcome — or, with ``reason="parked"``, a ``parked`` span
        instead, for callers that park the carry in a connector (spill,
        migration, rolling drain) so the timeline continues through the
        later restore instead of ending here."""
        st = self.streams.pop(uid)
        self._obs_detached(uid, st, reason)
        if self.scheduler.slot_of(uid) is None:
            self.scheduler.cancel(uid)
            self._obs_occupancy()
            return st
        slot, admitted = self.scheduler.release(uid)
        self.carry = {
            "v": self.carry["v"].at[slot].set(0),
            "spikes": self.carry["spikes"].at[slot].set(0),
        }
        if self._prev_host is not None:
            self._prev_host[slot] = 0
        if admitted is not None:
            self.streams[admitted].admitted_at = time.perf_counter()
            if self.tracer is not None:
                self.tracer.event("admitted", admitted, slot=slot)
        self._obs_occupancy()
        return st

    def _obs_detached(self, uid, st: "StreamStats", reason: str) -> None:
        if self.tracer is None:
            return
        if reason == "parked":
            self.tracer.event("parked", uid, steps_done=int(st.steps))
        else:
            self.tracer.event("retired", uid, outcome=reason,
                              steps_done=int(st.steps))

    def slot_of(self, uid) -> int | None:
        return self.scheduler.slot_of(uid)

    # -- carry migration (the stream-state connector) ---------------------
    def slot_params(self) -> dict:
        """This server's carry-compatibility identity (see
        :func:`repro.serving.connector.slot_params_of`)."""
        from repro.serving.connector import slot_params_of

        return slot_params_of(self.engine)

    def snapshot_stream(self, uid) -> "CarrySnapshot":
        """A stream's portable state — carry rows + counters — WITHOUT
        disturbing it (the stream keeps running; checkpointing uses
        this). The stream must hold a slot."""
        from repro.serving.connector import CarrySnapshot

        slot = self.scheduler.slot_of(uid)
        if slot is None:
            raise ValueError(
                f"stream {uid!r} is waiting for a slot; nothing to "
                f"snapshot (its carry does not exist yet)")
        st = self.streams[uid]
        return CarrySnapshot(
            stream_id=uid,
            slot_params=self.slot_params(),
            arrays={
                "v": np.asarray(self.carry["v"][slot], np.int32),
                "spikes": np.asarray(self.carry["spikes"][slot], np.int32),
            },
            meta={"steps": int(st.steps),
                  "spike_count": int(st.spike_count)},
        )

    def detach_stream(self, uid, connector) -> "CarrySnapshot":
        """Drain a stream to ``connector``: snapshot, park, then detach
        (the slot is zeroed and handed on exactly like :meth:`detach`).
        The stream is gone from this server but not from the world —
        :meth:`attach_stream` restores it anywhere compatible."""
        snap = self.snapshot_stream(uid)
        connector.insert(uid, snap)
        self.detach(uid, reason="parked")
        return snap

    def attach_stream(self, source, uid=None, *, slot: int | None = None):
        """Admit a stream whose carry starts from a snapshot instead of
        power-on zero — the restore half of live migration.

        Args:
          source: a :class:`~repro.serving.connector.CarrySnapshot`, or a
            connector to ``select`` (and, on success, ``evict``) the
            snapshot from under ``uid``.
          uid: the restored stream's id on THIS server (defaults to the
            snapshot's recorded id when restoring from a connector, else
            a fresh auto id). Must not collide with a live stream.
          slot: targeted placement (rebalance); default = FIFO free slot.

        The snapshot is slot-params / dtype / shape checked before one
        byte lands; a restored stream needs a slot NOW (its state cannot
        wait in a queue), so no free slot raises ``RuntimeError``.
        """
        from repro.serving.connector import CarrySnapshot

        connector = None
        if isinstance(source, CarrySnapshot):
            snap = source
            if uid is None:
                uid = next(self._auto_uid)
                while uid in self.streams:
                    uid = next(self._auto_uid)
        else:
            connector = source
            if uid is None:
                raise ValueError(
                    "attach_stream from a connector needs the stream id")
            snap = connector.select(uid)
            if snap is None:
                raise KeyError(f"no parked carry for stream {uid!r}")
        snap.check_compatible(self.slot_params())
        if self.scheduler.free_slots == 0:
            raise RuntimeError(
                f"cannot restore stream {uid!r}: no free slot (a restored "
                f"carry cannot wait in the admission queue)")
        now = time.perf_counter()
        if slot is None:
            slot = self.scheduler.submit(uid)
        else:
            slot = self.scheduler.submit_at(uid, slot)
        self.carry = {
            "v": self.carry["v"].at[slot].set(
                jnp.asarray(snap.arrays["v"])),
            "spikes": self.carry["spikes"].at[slot].set(
                jnp.asarray(snap.arrays["spikes"])),
        }
        self.streams[uid] = StreamStats(
            uid=uid,
            steps=int(snap.meta.get("steps", 0)),
            spike_count=int(snap.meta.get("spike_count", 0)),
            attached_at=now, admitted_at=now,
        )
        if self._prev_host is not None:
            self._prev_host[slot] = np.asarray(
                snap.arrays["spikes"], np.int32)
        self._obs_occupancy()
        if self.tracer is not None:
            self.tracer.event("admitted", uid, slot=slot, resumed=True)
        if connector is not None:
            connector.evict(uid)
        return uid

    def checkpoint_streams(self, connector) -> list:
        """Park a snapshot of EVERY live stream in ``connector`` without
        disturbing any of them — the crash-recovery write barrier. With a
        :class:`~repro.serving.connector.FileCarryConnector` this is what
        lets a dead server's streams resume bit-clean on a fresh one.
        Returns the checkpointed uids."""
        uids = sorted(self.scheduler.active, key=repr)
        for uid in uids:
            connector.insert(uid, self.snapshot_stream(uid))
        return uids

    def restore_streams(self, connector, uids=None) -> list:
        """Re-admit parked streams (all of ``connector``'s, or ``uids``)
        into free slots, consuming their snapshots; restores what fits
        and leaves the rest parked. Returns the restored uids."""
        if uids is None:
            uids = connector.stream_ids()
        restored = []
        for uid in uids:
            if self.scheduler.free_slots == 0:
                break
            self.attach_stream(connector, uid)
            restored.append(uid)
        return restored

    # -- streaming --------------------------------------------------------
    def feed(self, inputs: dict) -> dict:
        """Push timesteps of external spikes for one or more streams.

        Args:
          inputs: {uid: (T_uid, n_inputs) array in {0,1}} — ragged T per
            stream is fine; every uid must hold a slot.
        Returns:
          {uid: {'spikes': (T_uid, n_phys) int32 raster,
                 'counts': (n_phys,) int32 spike counts over the chunk}}.

        Slots not mentioned (or past their stream's T) are masked
        inactive: their carries are bit-for-bit untouched. A zero-length
        chunk is a per-stream no-op (empty raster back, carry untouched)
        so front-ends can feed "whatever arrived this round".
        """
        if not inputs:
            return {}
        out: dict = {}
        chunks: dict = {}
        n_phys = self.engine.n_phys
        for uid, arr in inputs.items():
            slot = self.scheduler.slot_of(uid)
            if slot is None:
                raise ValueError(
                    f"stream {uid!r} is waiting for a slot; cannot feed"
                )
            arr = np.asarray(arr)
            if arr.ndim != 2 or arr.shape[1] != self.engine.n_inputs:
                raise ValueError(
                    f"stream {uid!r}: chunk must be "
                    f"(T, {self.engine.n_inputs}), got {arr.shape}"
                )
            if arr.shape[0] == 0:
                out[uid] = {"spikes": np.zeros((0, n_phys), np.int32),
                            "counts": np.zeros((n_phys,), np.int32)}
                continue
            chunks[uid] = (slot, arr.astype(np.int32))
        if not chunks:
            return out

        T_max = max(arr.shape[0] for _, arr in chunks.values())
        n_in = self.engine.n_inputs
        pieces: dict = {uid: [] for uid in chunks}
        obs = self.metrics is not None or self.tracer is not None
        for t0 in range(0, T_max, self.chunk_steps):
            ext = np.zeros((self.chunk_steps, self.n_slots, n_in), np.int32)
            active = np.zeros((self.chunk_steps, self.n_slots), np.int32)
            for uid, (slot, arr) in chunks.items():
                n = min(self.chunk_steps, arr.shape[0] - t0)
                if n <= 0:
                    continue
                ext[:n, slot] = arr[t0:t0 + n]
                active[:n, slot] = 1
            t_chunk = self._obs_clock()() if obs else 0.0
            self.carry, spikes = self.engine.step_chunk(
                self.carry, jnp.asarray(ext), jnp.asarray(active))
            spikes = np.asarray(spikes)
            self.total_steps += int(active.sum())
            if obs:
                self._obs_feed_chunk(t_chunk, active, spikes, ext,
                                     chunks, t0)
            for uid, (slot, arr) in chunks.items():
                n = min(self.chunk_steps, arr.shape[0] - t0)
                if n > 0:
                    pieces[uid].append(spikes[:n, slot])

        for uid, (slot, arr) in chunks.items():
            raster = np.concatenate(pieces[uid], axis=0)
            st = self.streams[uid]
            st.steps += raster.shape[0]
            st.spike_count += int(raster.sum())
            out[uid] = {"spikes": raster, "counts": raster.sum(axis=0)}
        return out

    def feed_events(self, inputs: dict, *, out_capacity: int | None = None,
                    out_policy: str = "error") -> dict:
        """Event-driven :meth:`feed`: AER streams in, optionally AER out.

        The sparse front door of the server — what arrives from an event
        source (sensor, upstream model) is a stream of ``(t, slot,
        source)`` addresses, not a raster. Each stream is decoded by one
        jitted op, pushed through the SAME masked chunk step ``feed``
        uses (so the byte-exactness contract carries over verbatim), and
        the spike raster comes back — optionally re-encoded as AER.

        Args:
          inputs: {uid: AERStream} — each stream addresses a dense
            ``(T_uid, 1, n_inputs)`` chunk (slot axis 1: a stream is one
            lane; the slot address inside the server is the server's
            business, not the caller's).
          out_capacity: when set, each stream's result also carries
            ``'events'``: its output raster as an AER stream of at most
            this many events under ``out_policy``.
        Returns:
          {uid: {'spikes', 'counts'[, 'events']}} exactly as :meth:`feed`.
        """
        from repro.events.aer import dense_to_aer

        dense_inputs = {
            uid: decode_aer_chunk(stream, self.engine.n_inputs,
                                  f"stream {uid!r}")
            for uid, stream in inputs.items()
        }
        out = self.feed(dense_inputs)
        if out_capacity is not None:
            for uid, res in out.items():
                res["events"] = dense_to_aer(
                    res["spikes"][:, None, :], out_capacity,
                    policy=out_policy)
        return out

    def run_closed_loop(self, uid, controller, num_steps: int, ext0) -> dict:
        """Closed-loop mode: output of step t feeds the encoder at t+1.

        Args:
          uid: an admitted stream.
          controller: ``spikes_t (n_phys,) int32 -> ext_{t+1} (n_inputs,)``
            — decode + environment + encode, the perception->action loop.
          num_steps: timesteps to run.
          ext0: (n_inputs,) external spikes for step 0.
        Returns:
          {'spikes': (num_steps, n_phys) int32, 'counts': (n_phys,)}.

        Uses a T=1 slot-batch step (its own cached XLA program) so other
        streams' slots stay untouched between iterations.
        """
        slot = self.scheduler.slot_of(uid)
        if slot is None:
            raise ValueError(f"stream {uid!r} is waiting for a slot")
        ext_t = np.asarray(ext0, np.int32)
        if ext_t.shape != (self.engine.n_inputs,):
            raise ValueError(
                f"ext0 must be ({self.engine.n_inputs},), got {ext_t.shape}"
            )
        n_in = self.engine.n_inputs
        rows = []
        active = np.zeros((1, self.n_slots), np.int32)
        active[0, slot] = 1
        active = jnp.asarray(active)
        for t in range(num_steps):
            ext = np.zeros((1, self.n_slots, n_in), np.int32)
            ext[0, slot] = ext_t
            self.carry, spikes = self.engine.step_chunk(
                self.carry, jnp.asarray(ext), active)
            self.total_steps += 1
            spikes_t = np.asarray(spikes)[0, slot]
            if self.metrics is not None:
                m = self.metrics
                m.counter("snn_server_chunks_total").inc()
                m.counter("snn_server_steps_total").inc(1)
                m.counter("snn_server_spikes_total").inc(
                    int(spikes_t.sum()))
                self._prev_host[slot] = self._obs_count_chunk(
                    ext_t[None, :], spikes_t[None, :],
                    self._prev_host[slot])
            rows.append(spikes_t)
            if t + 1 < num_steps:
                ext_t = np.asarray(controller(spikes_t), np.int32)
                if ext_t.shape != (n_in,):
                    raise ValueError(
                        f"controller must return ({n_in},) external "
                        f"spikes, got shape {ext_t.shape} at step {t}"
                    )
        raster = np.stack(rows, axis=0)
        st = self.streams[uid]
        st.steps += num_steps
        st.spike_count += int(raster.sum())
        return {"spikes": raster, "counts": raster.sum(axis=0)}


class ModelStream:
    """Per-model streaming view over a (possibly fused multi-model) server.

    ``AcceleratorSession.serve`` hands these out: all models sharing a LIF
    configuration stream through ONE fused-engine :class:`SpikeServer`
    (one compiled step for the whole co-resident set); each view embeds
    its model's external spikes at the model's column offset and decodes
    only its own cluster range — the same address-space isolation the
    fused batch path (``run_all``) provides.
    """

    def __init__(self, server: SpikeServer, *, name: str, n_inputs: int,
                 ext_offset: int, phys_slice: tuple[int, int],
                 output_map: np.ndarray, stale_check=None, frontend=None):
        self.server = server
        self.name = name
        self.n_inputs = int(n_inputs)
        self.ext_offset = int(ext_offset)
        self.phys_slice = (int(phys_slice[0]), int(phys_slice[1]))
        self.output_map = np.asarray(output_map)
        self._stale_check = stale_check
        #: the group's shared AsyncSpikeFrontend when this view was served
        #: with ``session.serve(..., frontend=)`` (None otherwise).
        self.frontend = frontend

    def _check_fresh(self) -> None:
        if self._stale_check is not None and self._stale_check():
            raise RuntimeError(
                f"stale ModelStream view for {self.name!r}: a later deploy "
                f"changed the fused layout; call session.serve() again"
            )

    # lifecycle passes straight through to the shared server
    def attach(self, uid=None):
        self._check_fresh()
        return self.server.attach(uid)

    def detach(self, uid, *, reason: str = "detached") -> StreamStats:
        return self.server.detach(uid, reason=reason)

    def slot_of(self, uid):
        return self.server.slot_of(uid)

    def embed(self, chunk: np.ndarray) -> np.ndarray:
        """Model-local (T, n_inputs) spikes -> fused-layout external rows
        (zero everywhere but this model's input columns)."""
        chunk = np.asarray(chunk, np.int32)
        fused = np.zeros((chunk.shape[0], self.server.engine.n_inputs),
                         np.int32)
        fused[:, self.ext_offset:self.ext_offset + self.n_inputs] = chunk
        return fused

    def decode(self, raster: np.ndarray) -> dict:
        """Fused physical raster -> this model's masked spikes + decoded
        output counts / prediction (its cluster range only)."""
        lo, hi = self.phys_slice
        spikes = np.zeros_like(raster)
        spikes[:, lo:hi] = raster[:, lo:hi]  # mask to the model's clusters
        counts = spikes.sum(axis=0)
        return {
            "spikes": spikes,
            "output_counts": counts[self.output_map],
            "predictions": int(np.argmax(counts[self.output_map])),
        }

    def submit(self, chunk, **kwargs):
        """Async entry: enqueue a full model-local ``(T, n_inputs)``
        raster on the group's shared request queue and return a
        :class:`~repro.serving.frontend.RequestHandle` (the frontend's
        pump admits + serves it between chunk steps; the decoded result
        is byte-identical to a synchronous :meth:`feed` of the same
        raster). Requires the view to have been served with
        ``session.serve(..., frontend=)``."""
        self._check_fresh()
        if self.frontend is None:
            raise RuntimeError(
                f"view {self.name!r} has no async frontend; pass "
                f"frontend=FrontendConfig(...) to session.serve()")
        return self.frontend.submit(chunk, view=self, **kwargs)

    def submit_events(self, stream, **kwargs):
        """AER-native :meth:`submit`: a ``(T, 1, n_inputs)`` model-local
        AER stream in, same async handle back."""
        self._check_fresh()
        if self.frontend is None:
            raise RuntimeError(
                f"view {self.name!r} has no async frontend; pass "
                f"frontend=FrontendConfig(...) to session.serve()")
        return self.frontend.submit_events(stream, view=self, **kwargs)

    def feed(self, uid, chunk) -> dict:
        """Push (T, n_inputs) model-local external spikes; get the model's
        masked raster + decoded output counts for the chunk back."""
        return self.feed_many({uid: chunk})[uid]

    def feed_many(self, inputs: dict) -> dict:
        """Batched feed: {uid: (T_uid, n_inputs) chunk} for several of
        this model's streams in ONE slot-batch dispatch (the same
        multi-stream call :meth:`SpikeServer.feed` takes; front-ends
        should prefer this per round over per-stream ``feed`` loops)."""
        self._check_fresh()
        fused: dict = {}
        for uid, chunk in inputs.items():
            chunk = np.asarray(chunk, np.int32)
            if chunk.ndim != 2 or chunk.shape[1] != self.n_inputs:
                raise ValueError(
                    f"stream {uid!r}: chunk must be (T, {self.n_inputs}), "
                    f"got {chunk.shape}"
                )
            fused[uid] = self.embed(chunk)
        out = self.server.feed(fused)
        return {uid: self.decode(o["spikes"]) for uid, o in out.items()}

    def run_closed_loop(self, uid, controller, num_steps: int, ext0) -> dict:
        """Closed loop at timestep granularity: ``controller`` sees the
        model's masked spike vector and returns the next model-local
        external spike vector."""
        self._check_fresh()
        lo, hi = self.phys_slice

        def fused_controller(spikes_t):
            local = np.zeros_like(spikes_t)
            local[lo:hi] = spikes_t[lo:hi]
            nxt = np.asarray(controller(local), np.int32)
            if nxt.shape != (self.n_inputs,):
                raise ValueError(
                    f"controller must return ({self.n_inputs},) "
                    f"model-local external spikes, got shape {nxt.shape}"
                )
            full = np.zeros((self.server.engine.n_inputs,), np.int32)
            full[self.ext_offset:self.ext_offset + self.n_inputs] = nxt
            return full

        ext0 = np.asarray(ext0, np.int32)
        if ext0.shape != (self.n_inputs,):
            raise ValueError(
                f"ext0 must be ({self.n_inputs},), got {ext0.shape}"
            )
        full0 = np.zeros((self.server.engine.n_inputs,), np.int32)
        full0[self.ext_offset:self.ext_offset + self.n_inputs] = ext0
        out = self.server.run_closed_loop(uid, fused_controller, num_steps,
                                          full0)
        return self.decode(out["spikes"])
