"""Stream-state carry connector — the membrane carry as a movable payload.

SNAP-V keeps each neuron's membrane potential in distributed per-node
memory; in this reproduction that state is the per-slot carry inside
:class:`~repro.serving.snn.SpikeServer`, and it is the system's KV cache:
the one thing that binds a live stream to one server, one mesh, one host.
This module unbinds it, the way vLLM's ``KVConnectorBase`` unbinds the KV
cache from one engine (and FeNN-DMA unbinds neuron state from pinned SRAM
by making it DMA-able payload):

  * :func:`slot_params_of` — the strict carry-compatibility identity of an
    engine: ``(n_phys, decay, threshold_raw, reset_mode)``. Deliberately
    EXCLUDES backend, gate, ``fuse_steps``, mesh shape, and the input
    width — byte-identity holds across all of those re-hostings (pinned by
    the engine test suite), so a snapshot taken under one may restore
    under any other.
  * :class:`CarrySnapshot` — one stream's portable state: membrane
    potentials + last-spike vector (the carry), the step/spike counters,
    and the slot params it is only valid against. Serializes to a
    versioned, CRC-checked host-memory blob; restore is dtype- and
    shape-checked and rejects corrupted blobs.
  * :class:`CarryConnectorBase` — ``insert`` / ``select`` / ``evict`` over
    ``(stream_id, slot_params)`` keys, with :class:`InMemoryCarryConnector`
    (spill to host memory) and :class:`FileCarryConnector` (spill to disk;
    atomic writes, survives the server process) implementations. Both
    store the *serialized* blob, so every select round-trips the wire
    format and a corrupted store fails loudly, never silently.
  * :func:`migrate_stream` / :func:`rebalance_streams` — intra-server slot
    moves, and the mesh load-balancing pass that walks streams off
    straggler-flagged batch shards onto the donor shards' free slots.

Governing contract (pinned by tests/test_carry_migration.py): a stream
detached to a snapshot and re-attached anywhere compatible — same server,
a different server, a different mesh shape, another ``gate`` /
``fuse_steps`` / backend hosting, after a session redeploy, or out of a
file after a crash — produces an output raster byte-identical to the
never-migrated run. Migration changes WHERE a stream's state lives,
never one bit of what it computes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import struct
import zlib

import numpy as np

__all__ = [
    "SNAPSHOT_MAGIC",
    "SNAPSHOT_VERSION",
    "CarryConnectorBase",
    "CarrySnapshot",
    "FileCarryConnector",
    "InMemoryCarryConnector",
    "migrate_stream",
    "rebalance_streams",
    "slot_params_of",
]

#: wire-format magic + version. Bump the version on any layout change;
#: readers reject versions they do not know instead of guessing.
SNAPSHOT_MAGIC = b"SNAPC"
SNAPSHOT_VERSION = 1

# dtypes a snapshot may carry. The carry contract is int32, but the wire
# format is generic over the table so counters/metadata arrays added by a
# future version (refractory timers, eligibility traces) need no format
# bump — only a new array name.
_DTYPES = ("int8", "uint8", "int16", "uint16", "int32", "uint32",
           "int64", "uint64", "float32", "float64", "bool")


def slot_params_of(engine) -> dict:
    """The carry-compatibility identity of an engine.

    Two engines with equal slot params hold interchangeable slot carries:
    a ``(n_phys,)`` int32 membrane vector plus last-spike vector evolves
    identically under both (same decay, same threshold, same reset), so a
    snapshot moves between them without changing one bit of the stream's
    future. Everything else — backend, gate, ``fuse_steps``, mesh, input
    width, co-residents — is a *hosting* choice the engine's byte-identity
    contracts already quotient out, and is deliberately absent here.
    """
    decay = engine.decay
    return {
        "n_phys": int(engine.n_phys),
        "decay_kind": str(decay.kind),
        "decay_rate": float(decay.rate),
        "decay_raw": int(decay.raw),
        "threshold_raw": int(engine.threshold_raw),
        "reset_mode": str(engine.reset_mode),
    }


def _key_token(stream_id) -> str:
    """Stable storage token for an arbitrary (repr-able) stream id."""
    rep = repr(stream_id)
    return hashlib.sha256(rep.encode("utf-8")).hexdigest()[:32]


@dataclasses.dataclass
class CarrySnapshot:
    """One stream's portable state: carry + counters + compatibility key.

    ``arrays`` holds the slot carry — ``'v'`` (membrane potentials) and
    ``'spikes'`` (last emitted spike vector), each ``(n_phys,)`` int32
    under the carry contract (the wire format itself is generic over
    dtype/shape; :meth:`check_compatible` enforces the contract at
    restore). ``meta`` carries the stream's counters (``steps``,
    ``spike_count``) so accounting survives migration; there is no
    refractory state in this LIF model, but a future counter rides in
    ``meta``/``arrays`` without a format bump.
    """

    stream_id: object
    slot_params: dict
    arrays: dict            # name -> np.ndarray
    meta: dict = dataclasses.field(default_factory=dict)
    version: int = SNAPSHOT_VERSION

    # -- wire format -------------------------------------------------------
    # MAGIC(5) | version u16 LE | header_len u32 LE | header JSON (utf-8)
    # | raw array payloads (header order, C-contiguous LE) | crc32 u32 LE
    # over everything before it.
    def to_bytes(self) -> bytes:
        header = {
            "stream_id": repr(self.stream_id),
            "slot_params": self.slot_params,
            "meta": self.meta,
            "arrays": [
                {"name": name, "dtype": str(arr.dtype),
                 "shape": list(arr.shape)}
                for name, arr in self.arrays.items()
            ],
        }
        for spec in header["arrays"]:
            if spec["dtype"] not in _DTYPES:
                raise ValueError(
                    f"array {spec['name']!r}: dtype {spec['dtype']} is not "
                    f"snapshot-serializable (one of {_DTYPES})")
        hdr = json.dumps(header, sort_keys=True).encode("utf-8")
        parts = [SNAPSHOT_MAGIC,
                 struct.pack("<HI", self.version, len(hdr)), hdr]
        for name, arr in self.arrays.items():
            a = np.ascontiguousarray(arr)
            if a.dtype.byteorder == ">":  # pragma: no cover - exotic hosts
                a = a.astype(a.dtype.newbyteorder("<"))
            parts.append(a.tobytes())
        body = b"".join(parts)
        return body + struct.pack("<I", zlib.crc32(body) & 0xFFFFFFFF)

    @classmethod
    def from_bytes(cls, blob: bytes) -> "CarrySnapshot":
        """Parse + validate a snapshot blob; raises ``ValueError`` on any
        corruption (bad magic, unknown version, CRC mismatch, truncated or
        oversized payload, malformed header)."""
        if len(blob) < len(SNAPSHOT_MAGIC) + 6 + 4:
            raise ValueError("corrupt carry snapshot: truncated blob")
        if blob[: len(SNAPSHOT_MAGIC)] != SNAPSHOT_MAGIC:
            raise ValueError(
                f"corrupt carry snapshot: bad magic "
                f"{blob[:len(SNAPSHOT_MAGIC)]!r}")
        body, (crc_stored,) = blob[:-4], struct.unpack("<I", blob[-4:])
        if zlib.crc32(body) & 0xFFFFFFFF != crc_stored:
            raise ValueError("corrupt carry snapshot: CRC mismatch")
        off = len(SNAPSHOT_MAGIC)
        version, hdr_len = struct.unpack_from("<HI", body, off)
        if version != SNAPSHOT_VERSION:
            raise ValueError(
                f"carry snapshot version {version} is not supported "
                f"(reader knows version {SNAPSHOT_VERSION})")
        off += 6
        if off + hdr_len > len(body):
            raise ValueError("corrupt carry snapshot: truncated header")
        try:
            header = json.loads(body[off:off + hdr_len].decode("utf-8"))
            specs = header["arrays"]
            slot_params = header["slot_params"]
            meta = header["meta"]
            stream_id = header["stream_id"]
        except (ValueError, KeyError, UnicodeDecodeError) as e:
            raise ValueError(
                f"corrupt carry snapshot: malformed header ({e})") from e
        off += hdr_len
        arrays: dict = {}
        for spec in specs:
            if spec["dtype"] not in _DTYPES:
                raise ValueError(
                    f"corrupt carry snapshot: unknown dtype "
                    f"{spec['dtype']!r}")
            dt = np.dtype(spec["dtype"]).newbyteorder("<")
            shape = tuple(int(s) for s in spec["shape"])
            nbytes = dt.itemsize * int(np.prod(shape, dtype=np.int64))
            if off + nbytes > len(body):
                raise ValueError(
                    "corrupt carry snapshot: truncated array payload")
            arrays[spec["name"]] = np.frombuffer(
                body, dtype=dt, count=int(np.prod(shape, dtype=np.int64)),
                offset=off).reshape(shape).astype(np.dtype(spec["dtype"]))
            off += nbytes
        if off != len(body):
            raise ValueError(
                "corrupt carry snapshot: trailing bytes after payload")
        return cls(stream_id=stream_id, slot_params=slot_params,
                   arrays=arrays, meta=meta, version=version)

    # -- restore-side validation ------------------------------------------
    def check_compatible(self, params: dict) -> None:
        """Raise ``ValueError`` naming the first field on which this
        snapshot cannot restore into a slot with ``params`` (see
        :func:`slot_params_of`), or on a carry array with the wrong
        dtype/shape for the target."""
        for field in ("n_phys", "decay_kind", "decay_rate", "decay_raw",
                      "threshold_raw", "reset_mode"):
            if self.slot_params.get(field) != params[field]:
                raise ValueError(
                    f"carry snapshot for stream {self.stream_id!r} is "
                    f"incompatible: {field}="
                    f"{self.slot_params.get(field)!r} != {params[field]!r}")
        n_phys = params["n_phys"]
        for name in ("v", "spikes"):
            arr = self.arrays.get(name)
            if arr is None:
                raise ValueError(
                    f"carry snapshot for stream {self.stream_id!r} is "
                    f"missing array {name!r}")
            if arr.dtype != np.int32:
                raise ValueError(
                    f"carry snapshot array {name!r}: dtype {arr.dtype} "
                    f"!= int32 (the carry contract)")
            if arr.shape != (n_phys,):
                raise ValueError(
                    f"carry snapshot array {name!r}: shape {arr.shape} "
                    f"!= ({n_phys},)")


class CarryConnectorBase:
    """insert/select/evict over ``(stream_id, slot_params)`` keys.

    The store is keyed by ``stream_id``; the snapshot carries its
    ``slot_params`` half of the key, and :meth:`select` re-checks it when
    the caller supplies the target's params — so a stream id can never
    silently resolve to state for an incompatible engine. Implementations
    store the serialized blob: every select round-trips the wire format,
    so a corrupted store raises at select, not at step time.

    :meth:`instrument` opts a connector into telemetry: every insert
    (op=``snapshot``) and hit select (op=``restore``) counts ops, blob
    bytes, and latency into the registry and records a span. Pure
    accounting around the store — the stored bytes are untouched.
    """

    metrics = None
    tracer = None

    def instrument(self, metrics=None, tracer=None) -> "CarryConnectorBase":
        """Attach a MetricsRegistry / SpanTracer; returns self."""
        self.metrics = metrics
        self.tracer = tracer
        return self

    def _obs_clock(self):
        if self.metrics is not None:
            return self.metrics.clock
        if self.tracer is not None:
            return self.tracer.clock
        return None

    def _obs_op(self, op: str, stream_id, nbytes: int, t0: float) -> None:
        clock = self._obs_clock()
        now = clock()
        if self.metrics is not None:
            m = self.metrics
            m.counter("snn_connector_ops_total").labels(op=op).inc()
            m.counter("snn_connector_bytes_total").labels(op=op).inc(nbytes)
            m.histogram("snn_connector_op_seconds").labels(
                op=op).observe(now - t0)
        if self.tracer is not None:
            from repro.obs.tracing import Span

            self.tracer._record(
                Span(op, stream_id, t0, now, {"nbytes": nbytes}))

    def insert(self, stream_id, snapshot: CarrySnapshot) -> None:
        """Park (or overwrite) a stream's snapshot under ``stream_id``."""
        raise NotImplementedError

    def select(self, stream_id, slot_params: dict | None = None
               ) -> CarrySnapshot | None:
        """The parked snapshot for ``stream_id`` (None if absent). With
        ``slot_params``, an incompatible parked snapshot raises instead
        of restoring wrong state."""
        raise NotImplementedError

    def evict(self, stream_id) -> bool:
        """Drop a parked snapshot; True if one was present."""
        raise NotImplementedError

    def stream_ids(self) -> list:
        """Parked stream ids (recovery enumerates these), sorted by repr
        so recovery order is deterministic regardless of store order."""
        raise NotImplementedError

    def __contains__(self, stream_id) -> bool:
        return self.select(stream_id) is not None

    def __len__(self) -> int:
        return len(self.stream_ids())


class InMemoryCarryConnector(CarryConnectorBase):
    """Host-memory connector: spill target + migration scratchpad.

    This is what makes slot count stop bounding concurrent streams: a
    cold stream's carry lives here (a few hundred bytes) instead of
    holding a slot.
    """

    def __init__(self):
        self._store: dict = {}   # key token -> (stream_id, blob)

    def insert(self, stream_id, snapshot: CarrySnapshot) -> None:
        clock = self._obs_clock()
        t0 = clock() if clock else 0.0
        blob = snapshot.to_bytes()
        self._store[_key_token(stream_id)] = (stream_id, blob)
        if clock:
            self._obs_op("snapshot", stream_id, len(blob), t0)

    def select(self, stream_id, slot_params: dict | None = None
               ) -> CarrySnapshot | None:
        clock = self._obs_clock()
        t0 = clock() if clock else 0.0
        hit = self._store.get(_key_token(stream_id))
        if hit is None:
            return None
        snap = CarrySnapshot.from_bytes(hit[1])
        if slot_params is not None:
            snap.check_compatible(slot_params)
        if clock:
            self._obs_op("restore", stream_id, len(hit[1]), t0)
        return snap

    def evict(self, stream_id) -> bool:
        return self._store.pop(_key_token(stream_id), None) is not None

    def stream_ids(self) -> list:
        return sorted((sid for sid, _ in self._store.values()), key=repr)


class FileCarryConnector(CarryConnectorBase):
    """Disk-backed connector: snapshots survive the server process.

    One ``<token>.carry`` file per stream under ``root`` (token = hash of
    the stream id's repr; the id itself is recovered from the blob
    header). Writes are atomic (tmp + ``os.replace``) so a crash mid-write
    leaves the previous snapshot intact, never a torn one — the property
    the crash-recovery test leans on.
    """

    SUFFIX = ".carry"

    def __init__(self, root: str):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)

    def _path(self, stream_id) -> str:
        return os.path.join(self.root, _key_token(stream_id) + self.SUFFIX)

    def insert(self, stream_id, snapshot: CarrySnapshot) -> None:
        clock = self._obs_clock()
        t0 = clock() if clock else 0.0
        path = self._path(stream_id)
        tmp = path + ".tmp"
        blob = snapshot.to_bytes()
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)
        if clock:
            self._obs_op("snapshot", stream_id, len(blob), t0)

    def select(self, stream_id, slot_params: dict | None = None
               ) -> CarrySnapshot | None:
        clock = self._obs_clock()
        t0 = clock() if clock else 0.0
        path = self._path(stream_id)
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            blob = f.read()
        snap = CarrySnapshot.from_bytes(blob)
        if slot_params is not None:
            snap.check_compatible(slot_params)
        if clock:
            self._obs_op("restore", stream_id, len(blob), t0)
        return snap

    def evict(self, stream_id) -> bool:
        try:
            os.remove(self._path(stream_id))
            return True
        except FileNotFoundError:
            return False

    def stream_ids(self) -> list:
        ids = []
        for fname in os.listdir(self.root):
            if not fname.endswith(self.SUFFIX):
                continue
            with open(os.path.join(self.root, fname), "rb") as f:
                snap = CarrySnapshot.from_bytes(f.read())
            # header stores repr(stream_id); recovered ids are the reprs
            # parsed back by the caller's attach (the server restores
            # under the recovered id verbatim, so round-trips are exact
            # for the str/int ids serving traffic actually uses).
            ids.append(_parse_stream_id(snap.stream_id))
        return sorted(ids, key=repr)


def _parse_stream_id(rep: str):
    """Invert ``repr`` for the id types serving traffic uses (ints, strs,
    tuples of those). Anything fancier comes back as the repr string —
    still a stable, unique recovery key."""
    import ast

    try:
        return ast.literal_eval(rep)
    except (ValueError, SyntaxError):
        return rep


# --------------------------------------------------------------------------
# Live migration passes
# --------------------------------------------------------------------------

def migrate_stream(server, uid, *, slot: int) -> int:
    """Move a live stream to a specific free slot of the same server.

    snapshot -> detach (zeroes the old slot) -> attach into ``slot``.
    The stream keeps its uid, counters, and — the contract — its future:
    the output raster continues byte-identically, because a slot index is
    an address, not a parameter of the step. Returns the old slot.
    """
    old = server.slot_of(uid)
    if old is None:
        raise ValueError(f"stream {uid!r} is waiting; nothing to migrate")
    if slot == old:
        return old
    metrics = getattr(server, "metrics", None)
    tracer = getattr(server, "tracer", None)
    clock = (metrics.clock if metrics is not None
             else tracer.clock if tracer is not None else None)
    t0 = clock() if clock else 0.0
    snap = server.snapshot_stream(uid)
    server.detach(uid, reason="parked")
    server.attach_stream(snap, uid=uid, slot=slot)
    if metrics is not None:
        nbytes = sum(a.nbytes for a in snap.arrays.values())
        metrics.counter("snn_connector_ops_total").labels(op="migrate").inc()
        metrics.counter("snn_connector_bytes_total").labels(
            op="migrate").inc(nbytes)
        metrics.histogram("snn_connector_op_seconds").labels(
            op="migrate").observe(clock() - t0)
    if tracer is not None:
        tracer.event("migrated", uid, from_slot=old, to_slot=slot)
    return old


def rebalance_streams(server, flagged, *, slots_per_shard: int) -> list:
    """Walk streams off straggler-flagged batch shards onto donor shards.

    ``flagged`` is the straggler detector's per-shard bool mask (see
    :func:`repro.distributed.straggler.donor_shards`); slots map onto
    batch shards contiguously (``shard = slot // slots_per_shard``, the
    same attribution ``serve_snn``'s ShardLoadWatch uses). Each move is a
    :func:`migrate_stream` — byte-identical by construction — from the
    busiest flagged shard's lowest live slot into the emptiest donor
    shard's lowest free slot (deterministic), until flagged shards hold
    no more live slots than the donors' emptiest or donors run out of
    free slots.

    Returns the moves as ``[(uid, from_slot, to_slot), ...]``.
    """
    from repro.distributed.straggler import donor_shards

    flagged = np.asarray(flagged, bool)
    donors = set(int(d) for d in donor_shards(flagged))
    if not donors or donors == set(range(len(flagged))):
        return []

    def shard_of(slot: int) -> int:
        return min(slot // slots_per_shard, len(flagged) - 1)

    moves = []
    while True:
        active = server.scheduler.active          # uid -> slot
        free = server.scheduler.free_slot_ids
        load = _shard_loads(active, shard_of, len(flagged))
        donor_free = sorted(s for s in free if shard_of(s) in donors)
        if not donor_free:
            break
        # the most loaded flagged shard gives; stop when no flagged shard
        # is busier than the emptiest donor would become after taking one
        flagged_loads = [(load[sh], sh) for sh in range(len(flagged))
                         if flagged[sh] and load[sh] > 0]
        if not flagged_loads:
            break
        src_load, src_shard = max(flagged_loads)
        # receive into the EMPTIEST donor shard (lowest slot id on ties)
        dst = min(donor_free, key=lambda s: (load[shard_of(s)], s))
        if src_load <= load[shard_of(dst)] + 1:
            break  # a move would just relocate the imbalance
        uid, from_slot = min(
            ((u, s) for u, s in active.items()
             if shard_of(s) == src_shard), key=lambda kv: kv[1])
        migrate_stream(server, uid, slot=dst)
        moves.append((uid, from_slot, dst))
    return moves


def _shard_loads(active: dict, shard_of, n_shards: int) -> list:
    """Live-slot count per shard for an ``{uid: slot}`` map."""
    load = [0] * n_shards
    for slot in active.values():
        load[shard_of(slot)] += 1
    return load
