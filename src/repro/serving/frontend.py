"""Async serving front door — admission queue decoupled from the step loop.

SNAP-V splits management from compute: the RISC-V SpikeCore admits and
sequences work while the Cerebra array only ever executes timesteps. The
streaming layer (:mod:`repro.serving.snn`) reproduced the compute half —
one compiled masked chunk step serving resident streams — but its callers
still coupled *admission* to *stepping*: a request could only arrive when
the driver loop was between ``feed`` calls. This module is the management
half: a bounded request queue in front of the server, drained into free
:class:`~repro.serving.snn.SlotScheduler` slots between chunk steps by a
pump loop — the same decoupling vLLM-style continuous batching uses for
LLM serving (requests arrive on their own clock; the engine loop admits
whatever is waiting whenever a slot frees up).

The pieces:

  * :class:`AsyncSpikeFrontend` — owns the bounded queue
    (:meth:`~AsyncSpikeFrontend.submit` / :meth:`~AsyncSpikeFrontend.cancel`
    / per-request deadlines / an explicit backpressure policy) and the
    :meth:`~AsyncSpikeFrontend.pump` round that expires, admits, feeds one
    chunk, and retires — recording queue-wait vs service vs total latency
    per request.
  * :class:`RequestHandle` — what ``submit`` returns: ``poll()`` the
    request's state without blocking, ``result()`` when it is done.
  * :class:`FrontendConfig` — the knob bundle ``session.serve(...,
    frontend=)`` takes to hang a shared frontend off co-resident
    :class:`~repro.serving.snn.ModelStream` views.

Exactness contract (pinned by tests/test_serving_frontend.py): the
frontend never touches the numerical path — every request's spikes go
through the SAME masked chunk step ``SpikeServer.feed`` uses, and a slot
is always power-on clean at admission (eviction zeroes it). Given the
same realized admission order, async-served rasters are therefore
byte-identical to direct synchronous ``feed`` of each request's full
raster, for every backend x reset mode x gate x mesh. Admission order and
slot assignment are themselves deterministic functions of the submit /
cancel / pump sequence (FIFO queue, FIFO slot reuse) — a property test
pins this.

Backpressure policies (queue full at ``submit``):

  * ``"reject"``  — the NEW request is refused (state ``"rejected"``; the
    handle comes back so the caller can see it). Load shedding at the
    door; the open-loop launcher's default.
  * ``"block"``   — ``submit`` pumps the loop until a queue place frees
    up (the closed-loop degradation: the submitting client waits).
  * ``"drop-oldest"`` — the OLDEST queued request is dropped (state
    ``"dropped"``) to make room; freshest-data semantics for sensor-like
    traffic where a stale stimulus is worthless.

Admission is FIFO by default. Built with ``qos=`` (a
:class:`repro.serving.qos.QoSPolicy`) the single deque becomes per-tenant
queues under strict priority + weighted fair queueing, with slot quotas,
token-bucket rate limits on the same injectable clock, and (with
``preempt``) SLO-aware eviction that parks the lowest-priority running
stream through the connector. QoS off is byte-identical to the FIFO
path; QoS on keeps admission order and slot assignment a pure function
of the op sequence (pinned by tests/test_serving_qos.py).

Nothing here runs inside jit; the frontend is pure host-side bookkeeping
around the already-compiled step (clock injectable for deterministic
deadline tests).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time

import numpy as np

from repro.serving.qos import QoSPolicy, WeightedFairQueue, choose_victim

__all__ = [
    "BACKPRESSURE",
    "AsyncSpikeFrontend",
    "FrontendConfig",
    "RequestHandle",
    "latency_percentiles",
]

BACKPRESSURE: tuple[str, ...] = ("reject", "block", "drop-oldest")

# terminal request states (a handle in one of these never changes again).
# "parked" is deliberately NOT terminal: a spilled request's carry sits in
# the connector and resume() re-queues it (cancel() evicts it for good).
_TERMINAL = frozenset({"done", "cancelled", "expired", "rejected", "dropped"})

# rolling-window size of the latency / queue-depth sample buffers: big
# enough that percentiles describe hours of traffic, bounded so a
# long-running front door cannot grow without limit
_METRICS_WINDOW = 100_000

# per-process frontend ids, namespacing spill keys in a shared connector
_FRONTEND_IDS = itertools.count()

# every outcome key `metrics()["counts"]` documents. The dict ALWAYS
# carries all of them (zeros included): an empty or all-expired run
# returns the same shape as a busy one, so dashboards and tests index
# keys without existence checks (pinned by tests/test_serving_frontend).
OUTCOME_KEYS: tuple[str, ...] = (
    "submitted", "done", "rejected", "dropped", "cancelled",
    "expired", "expired_queued", "expired_running", "parked", "resumed",
    "evicted",
)


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Knobs for a frontend hung off ``session.serve(..., frontend=)``.

    ``queue_capacity`` bounds the admission queue (backpressure engages
    beyond it); ``backpressure`` picks the policy from
    :data:`BACKPRESSURE`; ``deadline_ms`` is the default per-request
    deadline (None = no deadline) measured on ``clock`` — requests past
    it are expired by the pump whether queued or mid-stream.
    """

    queue_capacity: int = 32
    backpressure: str = "reject"
    deadline_ms: float | None = None
    #: park mid-stream deadline evictions in the session's carry
    #: connector (state ``"parked"``) instead of zeroing them, so
    #: ``resume()`` continues the stream bit-clean (spill-on-evict).
    spill: bool = False
    #: optional ``repro.obs.slo.SLOWatchdog`` the pump feeds (latencies
    #: on retire, misses on expiry, queue depth per round) and checks
    #: once per round. Excluded from the shared-frontend conflict check:
    #: a watchdog observes, it does not shape admission.
    slo: object | None = None
    #: optional :class:`repro.serving.qos.QoSPolicy` — multi-tenant
    #: admission (priority classes, WFQ, quotas, rate limits, optional
    #: preemptive eviction). None keeps the plain FIFO path, which is
    #: byte-identical to a frontend built before QoS existed. Part of
    #: the shared-frontend conflict check: co-resident views must agree
    #: on the policy shaping their shared queue.
    qos: QoSPolicy | None = None


@dataclasses.dataclass
class _Request:
    """Internal per-request record (callers see :class:`RequestHandle`)."""

    rid: int
    chunk: np.ndarray              # dense (T, n_inputs) external spikes
    view: object | None            # ModelStream for embed/decode, or None
    deadline: float | None         # absolute clock value, or None
    submitted_at: float
    tenant: str = "default"        # QoS class / latency-histogram label
    events_capacity: int | None = None
    events_policy: str = "error"
    state: str = "queued"
    uid: object = None             # server stream uid once admitted
    cursor: int = 0                # timesteps fed so far
    parked_key: object = None      # connector key while spilled/parked
    pieces: list = dataclasses.field(default_factory=list)
    admitted_at: float | None = None
    finished_at: float | None = None
    result_cache: dict | None = None   # built once terminal, then reused

    @property
    def steps_total(self) -> int:
        return int(self.chunk.shape[0])


class RequestHandle:
    """Caller-side view of one submitted request.

    ``poll()`` never blocks; ``result()`` returns the decoded output once
    the request is terminal (None while it is still queued/running, and
    for requests that never ran). ``cancel()`` routes back through the
    frontend.
    """

    def __init__(self, frontend: "AsyncSpikeFrontend", req: _Request):
        self._frontend = frontend
        self._req = req

    @property
    def rid(self) -> int:
        """Frontend-assigned request id (submission order)."""
        return self._req.rid

    @property
    def state(self) -> str:
        return self._req.state

    @property
    def done(self) -> bool:
        return self._req.state in _TERMINAL

    def poll(self) -> dict:
        """Non-blocking status: state, progress, and queue position."""
        return self._frontend._poll(self._req)

    def result(self) -> dict | None:
        """The request's output once terminal (see
        :meth:`AsyncSpikeFrontend.submit` for the shape); None while
        pending or when the request never consumed a timestep."""
        return self._frontend._result(self._req)

    def timing(self) -> dict:
        """{'queue_wait', 'service', 'total'} in seconds (None where the
        request never reached that stage)."""
        return self._frontend._timing(self._req)

    def cancel(self) -> bool:
        return self._frontend.cancel(self)


def latency_percentiles(xs) -> dict:
    """mean/p50/p95/p99/max summary (seconds in, seconds out) of a
    latency sample list; empty input yields an all-None dict."""
    if not len(xs):
        return {"mean": None, "p50": None, "p95": None, "p99": None,
                "max": None}
    a = np.asarray(xs, np.float64)
    return {
        "mean": float(a.mean()),
        "p50": float(np.percentile(a, 50)),
        "p95": float(np.percentile(a, 95)),
        "p99": float(np.percentile(a, 99)),
        "max": float(a.max()),
    }


class AsyncSpikeFrontend:
    """Bounded admission queue + pump loop over one :class:`SpikeServer`.

    The frontend NEVER steps the engine on its own clock: all compute
    happens inside :meth:`pump`, which between two chunk steps (a) expires
    requests past their deadline — queued ones are refused, mid-stream
    ones are evicted with their slot carry zeroed exactly like any
    eviction, (b) drains the queue head-first into free scheduler slots,
    (c) feeds ONE ``chunk_steps`` service quantum for every running
    stream in a single batched ``SpikeServer.feed`` dispatch, and
    (d) retires finished streams, freeing their slots for the next
    round's admission. ``submit`` only enqueues (or applies backpressure);
    it is safe to call from another thread than the pump loop.

    Exactness: requests ride the same masked chunk step ``feed`` uses, so
    for the same realized admission order the per-request rasters are
    byte-identical to synchronous ``feed`` — the queue changes WHEN work
    runs, never what it computes.
    """

    def __init__(self, server, *, queue_capacity: int = 32,
                 backpressure: str = "reject",
                 deadline_ms: float | None = None,
                 clock=time.perf_counter, connector=None,
                 metrics=None, tracer=None, slo=None, qos=None):
        if queue_capacity <= 0:
            raise ValueError(
                f"queue_capacity must be positive, got {queue_capacity}")
        if backpressure not in BACKPRESSURE:
            raise ValueError(
                f"unknown backpressure policy {backpressure!r}; expected "
                f"one of {BACKPRESSURE}")
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be positive, got {deadline_ms}")
        if qos is not None and not isinstance(qos, QoSPolicy):
            raise TypeError(
                f"qos must be a QoSPolicy or None, got "
                f"{type(qos).__name__}")
        if qos is not None and qos.preempt and connector is None:
            raise ValueError(
                "QoSPolicy(preempt=True) needs a connector: preemptive "
                "eviction PARKS the victim's carry (never drops it), so "
                "the frontend must have somewhere to spill")
        self.server = server
        self.queue_capacity = int(queue_capacity)
        self.backpressure = backpressure
        self.default_deadline_ms = deadline_ms
        self.clock = clock
        #: spill-on-evict target (a CarryConnectorBase): with one set,
        #: mid-stream deadline expiry PARKS the stream's carry instead of
        #: zeroing it, and resume() continues it bit-clean. Keys are
        #: namespaced per frontend so several front doors (and the
        #: session's redeploy drain) can share one connector.
        self.connector = connector
        #: optional telemetry (a MetricsRegistry / SpanTracer). Outcome
        #: counts, queue depth, and latency histograms mirror into the
        #: registry — exportable while the run is live — without changing
        #: one value `metrics()` reports. Pure host-side accounting.
        self.registry = metrics
        self.tracer = tracer
        #: optional SLO watchdog (repro.obs.slo.SLOWatchdog): the pump
        #: feeds it total latencies, deadline outcomes, and queue depth,
        #: and runs one burn-rate evaluation per round. Observational
        #: only — a breach fires the watchdog's callbacks (e.g. a
        #: flight-recorder dump), never touches admission.
        self.slo = slo
        #: optional QoSPolicy: admission policy for the queue below.
        #: None = plain FIFO (byte-identical to the pre-QoS frontend).
        self.qos = qos
        self._spill_ns = f"spill-{next(_FRONTEND_IDS)}"
        self._lock = threading.RLock()
        self._rid = itertools.count()
        # QoS swaps the single FIFO deque for per-tenant queues under
        # strict priority + DRR; both expose the same deque surface
        # (len / iter / append / remove / index), only the admission
        # pop differs (see pump step 2).
        self._queue = (WeightedFairQueue(qos) if qos is not None
                       else collections.deque())
        self._running: dict = {}      # server uid -> _Request
        # accounting — the sample buffers are bounded (rolling window of
        # the most recent entries) so a long-running front door cannot
        # leak memory; counts are plain integers and stay exact forever.
        self.counts = collections.Counter()      # terminal-state counters
        w = _METRICS_WINDOW
        self.queue_wait = collections.deque(maxlen=w)  # submit->grant (s)
        self.service = collections.deque(maxlen=w)     # grant->done (s)
        self.total = collections.deque(maxlen=w)       # submit->done (s)
        self.depth_samples = collections.deque(maxlen=w)  # depth per pump
        self.rounds = 0
        # per-class mirrors of the same accounting, zero-filled for
        # every policy-declared class in metrics()["by_class"]
        self.class_counts: dict[str, collections.Counter] = {}
        self._class_lat: dict[str, dict[str, collections.deque]] = {}
        # background pump driver (start()/stop()); _work wakes the loop
        # out of its idle wait as soon as a submit/resume lands
        self._pump_thread = None
        self._stop_evt: threading.Event | None = None
        self._work_evt: threading.Event | None = None

    # -- queries -----------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        """Requests currently waiting for admission."""
        with self._lock:
            return len(self._queue)

    @property
    def n_running(self) -> int:
        with self._lock:
            return len(self._running)

    @property
    def idle(self) -> bool:
        """True when no request is queued or running."""
        with self._lock:
            return not self._queue and not self._running

    # -- telemetry ---------------------------------------------------------
    # Mirrors of the plain-dict accounting into the injected registry /
    # tracer. All no-ops when telemetry is off; never touch the server.
    def _count(self, outcome: str, req: _Request | None = None,
               n: int = 1) -> None:
        self.counts[outcome] += n
        if req is not None:
            self.class_counts.setdefault(
                self._class_of(req), collections.Counter())[outcome] += n
        if self.registry is not None:
            self.registry.counter("snn_frontend_requests_total").labels(
                outcome=outcome).inc(n)
            if req is not None:
                self.registry.counter(
                    "snn_frontend_class_outcomes_total").labels(
                    stream_class=self._class_of(req),
                    outcome=outcome).inc(n)

    def _obs_depth(self) -> None:
        if self.registry is not None:
            self.registry.gauge("snn_frontend_queue_depth").set(
                len(self._queue))
            if self.qos is not None:
                gauge = self.registry.gauge(
                    "snn_frontend_class_queue_depth")
                for cls, depth in self._queue.depth_by_class().items():
                    gauge.labels(stream_class=cls).set(depth)

    @staticmethod
    def _class_of(req: _Request) -> str:
        """Per-class accounting label: the tenant given at submit, else
        the view (model) name, else "default" (set once at submission)."""
        return req.tenant

    def _lat(self, key: str, req: _Request, seconds: float) -> None:
        """One latency sample: the global window, the per-class window,
        and (when a registry is wired) the labelled histogram."""
        getattr(self, key).append(seconds)
        per = self._class_lat.setdefault(
            self._class_of(req),
            {k: collections.deque(maxlen=_METRICS_WINDOW)
             for k in ("queue_wait", "service", "total")})
        per[key].append(seconds)
        self._obs_latency(f"snn_frontend_{key}_seconds", req, seconds)

    def _obs_latency(self, name: str, req: _Request,
                     seconds: float) -> None:
        if self.registry is not None:
            self.registry.histogram(name).labels(
                stream_class=self._class_of(req)).observe(seconds)

    def _obs_event(self, kind: str, req: _Request, **attrs) -> None:
        """Record a request-lifecycle event. Request ids and server
        stream uids are independent namespaces sharing one tracer, so
        every request span carries ``domain="request"`` — timeline
        reconstruction keys on (domain, uid) and never aliases rid 0
        with stream uid 0."""
        if self.tracer is not None:
            self.tracer.event(kind, req.rid, domain="request", **attrs)

    def _obs_retired(self, req: _Request, outcome: str) -> None:
        self._obs_event("retired", req, outcome=outcome,
                        steps_done=req.cursor)

    # -- submission --------------------------------------------------------
    def submit(self, chunk, *, view=None, deadline_ms: float | None = None,
               tenant: str | None = None,
               events_capacity: int | None = None,
               events_policy: str = "error") -> RequestHandle:
        """Enqueue a request: the full ``(T, n_inputs)`` external spike
        raster one stream wants served.

        Args:
          chunk: (T, n_inputs) {0,1} spikes — model-local when ``view`` is
            a :class:`~repro.serving.snn.ModelStream` (embedded into the
            fused layout at feed time), server-wide otherwise. T >= 1.
          view: optional ModelStream; its cluster range also decodes the
            output (``session.serve(..., frontend=)`` routes through
            here).
          deadline_ms: overrides the frontend default; measured from
            submission on the frontend clock. A request past its deadline
            is EXPIRED by the pump — refused if still queued, evicted
            mid-stream (slot carry zeroed, partial raster kept).
          tenant: QoS class name (defaults to the view name, else
            "default") — routes the request to its per-tenant queue
            under a QoS policy and labels its per-class metrics either
            way.
          events_capacity/events_policy: when set, the result also
            carries ``'events'`` — the output raster AER-encoded at this
            capacity (see :meth:`SpikeServer.feed_events`).

        Returns a :class:`RequestHandle`. Under backpressure (queue at
        capacity) the policy decides: ``"reject"`` hands back an
        already-terminal handle in state ``"rejected"``; ``"block"``
        pumps until a place frees; ``"drop-oldest"`` drops the oldest
        queued request and admits this one. ``result()`` of a finished
        request: ``{'spikes': (T', n_phys) int32, 'counts'}`` (T' < T
        with ``'partial': True`` when expired/cancelled mid-stream), the
        view-decoded fields for view requests, plus ``'events'`` when
        requested.
        """
        chunk = np.asarray(chunk, np.int32)
        n_in = (view.n_inputs if view is not None
                else self.server.engine.n_inputs)
        if chunk.ndim != 2 or chunk.shape[1] != n_in:
            raise ValueError(
                f"request chunk must be (T, {n_in}), got {chunk.shape}")
        if chunk.shape[0] == 0:
            raise ValueError("request chunk must hold at least 1 timestep")
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms
        if tenant is None:
            tenant = view.name if view is not None else "default"
        with self._lock:
            now = self.clock()
            req = _Request(
                rid=next(self._rid), chunk=chunk, view=view,
                deadline=(None if deadline_ms is None
                          else now + deadline_ms / 1e3),
                submitted_at=now,
                tenant=str(tenant),
                events_capacity=events_capacity,
                events_policy=events_policy,
            )
            self._count("submitted", req)
            self._obs_event("queued", req, steps=req.steps_total,
                            stream_class=self._class_of(req))
            if not self._make_room():
                req.state = "rejected"
                self._count("rejected", req)
                self._obs_retired(req, "rejected")
                return RequestHandle(self, req)
            self._queue.append(req)
            self._obs_depth()
            if self._work_evt is not None:
                self._work_evt.set()
            return RequestHandle(self, req)

    def submit_events(self, stream, **kwargs) -> RequestHandle:
        """AER-native :meth:`submit`: a ``(T, 1, n_inputs)`` AER stream in
        (decoded through the same shared contract as
        :meth:`SpikeServer.feed_events`), same handle back. Pass
        ``events_capacity`` to get the output as AER too."""
        from repro.serving.snn import decode_aer_chunk

        view = kwargs.get("view")
        n_in = (view.n_inputs if view is not None
                else self.server.engine.n_inputs)
        return self.submit(
            decode_aer_chunk(stream, n_in, "AER request"), **kwargs)

    def cancel(self, handle: RequestHandle) -> bool:
        """Withdraw a request. Queued: removed without ever touching the
        server. Running: evicted mid-stream — the slot carry is zeroed
        (detach semantics) and the partial raster is kept. Parked (or
        queued-for-resume): the spilled carry is evicted from the
        connector; the server is never touched — it holds no state for a
        parked stream. Terminal: returns False (too late)."""
        req = handle._req
        with self._lock:
            if req.state == "queued":
                self._queue.remove(req)
                if req.parked_key is not None:
                    self.connector.evict(req.parked_key)
                    req.parked_key = None
                req.state = "cancelled"
                self._count("cancelled", req)
                self._obs_retired(req, "cancelled")
                self._obs_depth()
                return True
            if req.state == "parked":
                self.connector.evict(req.parked_key)
                req.parked_key = None
                req.state = "cancelled"
                req.finished_at = self.clock()
                self._count("cancelled", req)
                self._obs_retired(req, "cancelled")
                return True
            if req.state == "running":
                self.server.detach(req.uid, reason="cancelled")
                del self._running[req.uid]
                if self.qos is not None:
                    self._queue.note_released(req)
                req.state = "cancelled"
                req.finished_at = self.clock()
                self._count("cancelled", req)
                self._obs_retired(req, "cancelled")
                return True
            return False

    def resume(self, handle: RequestHandle,
               deadline_ms: float | None = None) -> bool:
        """Re-queue a PARKED request: on admission its spilled carry is
        restored into a free slot and the stream continues exactly where
        it left off — the concatenated raster is byte-identical to a
        never-spilled run. ``deadline_ms`` arms a fresh deadline from now
        (None = no deadline this time). Under backpressure the frontend's
        policy applies; ``"reject"`` leaves the request parked and
        returns False."""
        req = handle._req
        with self._lock:
            if req.state != "parked":
                return False
            if not self._make_room():
                return False
            now = self.clock()
            req.deadline = (None if deadline_ms is None
                            else now + deadline_ms / 1e3)
            req.state = "queued"
            self._queue.append(req)
            self._obs_event("queued", req, steps=req.steps_total,
                            stream_class=self._class_of(req),
                            resumed=True)
            self._obs_depth()
            if self._work_evt is not None:
                self._work_evt.set()
            return True

    def _make_room(self) -> bool:
        """Apply the backpressure policy until the queue has a place;
        False = policy says refuse (caller keeps the request out)."""
        if len(self._queue) < self.queue_capacity:
            return True
        if self.backpressure == "reject":
            return False
        if self.backpressure == "drop-oldest":
            # under QoS the shed victim is the lowest-priority class's
            # oldest request, not the global head — load shedding should
            # cost the least important tenant first
            oldest = (self._queue.drop_victim() if self.qos is not None
                      else self._queue.popleft())
            if oldest.parked_key is not None:
                # a resumed-but-not-yet-admitted request falls back to
                # "parked": its carry is still in the connector and a
                # later resume() may try again — shedding the queue
                # place must not lose the stream's state
                oldest.state = "parked"
                self._obs_event("parked", oldest)
            else:
                oldest.state = "dropped"
                self._obs_retired(oldest, "dropped")
            self._count("dropped", oldest)
            return True
        while len(self._queue) >= self.queue_capacity:  # "block"
            progress = self.pump()
            if not any(progress[k] for k in
                       ("admitted", "retired", "expired", "steps")):
                raise RuntimeError(
                    "blocked submit cannot make progress: queue full and "
                    "a pump round moved nothing (no free slots and no "
                    "stream advancing)")
        return True

    # -- the pump ----------------------------------------------------------
    def pump(self) -> dict:
        """One admission + service round (call between chunk steps).

        Order within the round: expire (queued refusals + mid-stream
        evictions) -> admit queue head into every free slot -> ONE
        batched ``feed`` of a ``chunk_steps`` quantum for all running
        streams -> retire finished streams. Returns the round summary
        ``{'admitted', 'retired', 'expired', 'steps', 'queue_depth'}``.
        """
        with self._lock:
            now = self.clock()
            summary = {"admitted": 0, "retired": 0, "expired": 0,
                       "evicted": 0, "steps": 0}
            # 1. deadline expiry — queued requests are refused outright
            # (a resumed one falls back to "parked": its carry is still
            # in the connector and a later resume() may try again)
            for req in [r for r in self._queue
                        if r.deadline is not None and now > r.deadline]:
                self._queue.remove(req)
                if req.parked_key is not None:
                    req.state = "parked"
                    self._obs_event("parked", req)
                else:
                    req.state = "expired"
                    self._count("expired_queued", req)
                    self._obs_retired(req, "expired")
                self._count("expired", req)
                if self.slo is not None:
                    self.slo.record_miss()
                summary["expired"] += 1
            # ... mid-stream streams are evicted like any other eviction:
            # detach zeroes the slot carry, so the next occupant powers
            # up clean (pinned by tests/test_serving_frontend.py).
            # With a connector, the eviction SPILLS instead: the carry is
            # parked under a frontend-namespaced key and the request goes
            # to state "parked" — resume() continues it bit-clean.
            for uid, req in [(u, r) for u, r in self._running.items()
                             if r.deadline is not None
                             and now > r.deadline]:
                del self._running[uid]
                if self.qos is not None:
                    self._queue.note_released(req)
                if self.connector is not None:
                    req.parked_key = (self._spill_ns, req.rid)
                    snap = self.server.snapshot_stream(uid)
                    self.server.detach(uid, reason="parked")
                    self.connector.insert(req.parked_key, snap)
                    req.uid = None
                    req.state = "parked"
                    self._count("parked", req)
                    self._obs_event("parked", req, steps_done=req.cursor)
                else:
                    self.server.detach(uid, reason="expired")
                    req.state = "expired"
                    req.finished_at = now
                    self._count("expired", req)
                    self._count("expired_running", req)
                    self._obs_retired(req, "expired")
                if self.slo is not None:
                    self.slo.record_miss()
                summary["expired"] += 1
            # 1b. SLO-aware preemption (QoS preempt only): every slot
            # busy while an eligible queued request strictly outranks a
            # running stream -> shed the lowest-priority running stream
            # (newest first within it). The victim's carry is PARKED
            # through the connector — never dropped — and it re-queues
            # at the head of its class, continuing bit-clean once
            # pressure clears. One eviction per round: takeover is
            # gradual and the victim sequence stays a pure function of
            # the op sequence.
            if (self.qos is not None and self.qos.preempt
                    and self._queue
                    and self.server.scheduler.free_slots == 0):
                top = self._queue.top_eligible_priority(now)
                victim = (choose_victim(self.qos, self._running.values(),
                                        below=top)
                          if top is not None else None)
                if victim is not None:
                    uid = victim.uid
                    del self._running[uid]
                    self._queue.note_released(victim)
                    victim.parked_key = (self._spill_ns, victim.rid)
                    snap = self.server.snapshot_stream(uid)
                    self.server.detach(uid, reason="parked")
                    self.connector.insert(victim.parked_key, snap)
                    victim.uid = None
                    self._count("evicted", victim)
                    self._count("parked", victim)
                    self._obs_event("parked", victim,
                                    steps_done=victim.cursor,
                                    preempted=True)
                    victim.state = "queued"
                    self._queue.appendleft(victim)
                    self._obs_event("queued", victim,
                                    steps=victim.steps_total,
                                    stream_class=self._class_of(victim),
                                    resumed=True)
                    summary["evicted"] += 1
            # 2. continuous-batching admission: queue head -> free slots
            # (a resumed request re-attaches FROM its parked carry — the
            # only admission that does not power up from zero). Under
            # QoS the "head" is whatever the policy grants next: strict
            # priority, then DRR inside the stratum, quota and token
            # gated — None when every queued class is blocked.
            while self._queue and self.server.scheduler.free_slots > 0:
                if self.qos is not None:
                    req = self._queue.pop_admissible(now)
                    if req is None:
                        break
                else:
                    req = self._queue.popleft()
                resumed = req.parked_key is not None
                if resumed:
                    snap = self.connector.select(req.parked_key)
                    req.uid = self.server.attach_stream(snap)
                    self.connector.evict(req.parked_key)
                    req.parked_key = None
                    self._count("resumed", req)
                    self._obs_event("resumed", req, server_uid=req.uid)
                else:
                    req.uid = self.server.attach()
                self._obs_event("admitted", req,
                                slot=self.server.slot_of(req.uid),
                                server_uid=req.uid, resumed=resumed)
                req.admitted_at = now
                req.state = "running"
                self._running[req.uid] = req
                self._lat("queue_wait", req, now - req.submitted_at)
                summary["admitted"] += 1
            # 3. one service quantum for every running stream, batched
            inputs = {}
            for uid, req in self._running.items():
                piece = req.chunk[req.cursor:
                                  req.cursor + self.server.chunk_steps]
                inputs[uid] = (req.view.embed(piece)
                               if req.view is not None else piece)
            if inputs:
                out = self.server.feed(inputs)
                for uid, res in out.items():
                    req = self._running[uid]
                    req.pieces.append(res["spikes"])
                    req.cursor += res["spikes"].shape[0]
                    summary["steps"] += res["spikes"].shape[0]
            # 4. retire finished streams (slots free for the next round)
            now = self.clock()
            for uid in [u for u, r in self._running.items()
                        if r.cursor >= r.steps_total]:
                req = self._running.pop(uid)
                if self.qos is not None:
                    self._queue.note_released(req)
                self.server.detach(uid, reason="done")
                req.state = "done"
                req.finished_at = now
                self._count("done", req)
                self._lat("service", req, now - req.admitted_at)
                self._lat("total", req, now - req.submitted_at)
                self._obs_retired(req, "done")
                if self.slo is not None:
                    self.slo.record_done(now - req.submitted_at)
                summary["retired"] += 1
            self.rounds += 1
            self.depth_samples.append(len(self._queue))
            if self.registry is not None:
                self.registry.counter("snn_frontend_rounds_total").inc()
                self._obs_depth()
            if self.slo is not None:
                self.slo.record_queue_depth(len(self._queue))
                self.slo.check(now)
            summary["queue_depth"] = len(self._queue)
            return summary

    # -- background driver -------------------------------------------------
    def start(self, poll_interval_s: float = 0.001) -> None:
        """Run the pump loop on a daemon thread: the real multi-threaded
        driver. Submitters on any thread call :meth:`submit` as usual —
        the queue, counters, and server access all serialize on the
        frontend lock, and each submit wakes the loop out of its idle
        wait. Rounds interleave with submissions on the thread
        scheduler's clock, so threaded runs trade the *replayable* op
        sequence for liveness — accounting invariants (no lost or
        duplicated handles, exact outcome counts) still hold, pinned by
        the stress test in tests/test_serving_qos.py."""
        with self._lock:
            if self._pump_thread is not None:
                raise RuntimeError("pump thread already running")
            self._stop_evt = threading.Event()
            self._work_evt = threading.Event()
            self._pump_thread = threading.Thread(
                target=self._pump_loop, args=(poll_interval_s,),
                name=f"frontend-pump-{self._spill_ns}", daemon=True)
        self._pump_thread.start()

    def _pump_loop(self, poll_interval_s: float) -> None:
        while not self._stop_evt.is_set():
            if self.idle:
                self._work_evt.wait(poll_interval_s)
                self._work_evt.clear()
                continue
            self.pump()

    def stop(self, drain: bool = True,
             timeout_s: float | None = 30.0) -> None:
        """Stop the background driver. ``drain=True`` (default) waits
        until the frontend is idle first so no accepted request is left
        behind; the thread itself is then joined."""
        thread = self._pump_thread
        if thread is None:
            return
        if drain:
            deadline = (None if timeout_s is None
                        else time.monotonic() + timeout_s)
            while not self.idle:
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError(
                        "frontend did not drain before stop() timeout")
                time.sleep(0.001)
        self._stop_evt.set()
        self._work_evt.set()
        thread.join(timeout_s)
        if thread.is_alive():
            raise TimeoutError("pump thread did not stop")
        self._pump_thread = None
        self._work_evt = None
        self._stop_evt = None

    def drain(self, max_rounds: int | None = None) -> dict:
        """Pump until idle (or ``max_rounds``); returns :meth:`metrics`.
        Terminates for any finite workload: every round either advances a
        running stream, admits, or expires — progress is monotone."""
        while not self.idle:
            if max_rounds is not None and max_rounds <= 0:
                break
            if max_rounds is not None:
                max_rounds -= 1
            self.pump()
        return self.metrics()

    # -- accounting --------------------------------------------------------
    def metrics(self) -> dict:
        """Front-door accounting: terminal-state counts, queue-wait /
        service / total latency percentiles (seconds), and queue-depth
        stats over the pump rounds so far.

        Shape contract: ``counts`` carries EVERY key in
        :data:`OUTCOME_KEYS` (zero when nothing reached that outcome) and
        every other key is always present — an empty or all-expired run
        returns the same structure as a busy one, so callers index
        without existence checks. Percentile fields are None (not
        missing) when no sample exists. ``by_class`` applies the same
        contract per tenant class: every class a QoS policy declares OR
        traffic has touched appears with the full zero-filled
        ``counts`` and all-None-able latency percentiles (an empty
        QoS-less run yields ``{}``)."""
        with self._lock:
            depth = np.asarray(self.depth_samples or [0])
            counts = {k: int(self.counts.get(k, 0)) for k in OUTCOME_KEYS}
            # ad-hoc outcomes (none today) must never be silently dropped
            counts.update({k: int(v) for k, v in self.counts.items()
                           if k not in counts})
            classes = set(self.class_counts) | set(self._class_lat)
            if self.qos is not None:
                classes |= set(self.qos.classes)
            by_class = {}
            for cls in sorted(classes):
                cc = self.class_counts.get(cls, {})
                lat = self._class_lat.get(cls, {})
                by_class[cls] = {
                    "counts": {k: int(cc.get(k, 0))
                               for k in OUTCOME_KEYS},
                    "queue_wait": latency_percentiles(
                        lat.get("queue_wait", ())),
                    "service": latency_percentiles(
                        lat.get("service", ())),
                    "total": latency_percentiles(lat.get("total", ())),
                }
            return {
                "counts": counts,
                "by_class": by_class,
                "queue_wait": latency_percentiles(self.queue_wait),
                "service": latency_percentiles(self.service),
                "total": latency_percentiles(self.total),
                "queue_depth": {"max": int(depth.max()),
                                "mean": float(depth.mean())},
                "rounds": self.rounds,
            }

    # -- handle internals --------------------------------------------------
    def _poll(self, req: _Request) -> dict:
        with self._lock:
            st = {"state": req.state, "steps_done": req.cursor,
                  "steps_total": req.steps_total}
            if req.state == "queued":
                st["queue_position"] = self._queue.index(req)
            return st

    def _result(self, req: _Request) -> dict | None:
        with self._lock:
            if req.state not in _TERMINAL or not req.pieces:
                return None
            if req.result_cache is not None:
                return req.result_cache
            raster = np.concatenate(req.pieces, axis=0)
            if req.view is not None:
                res = req.view.decode(raster)
            else:
                res = {"spikes": raster, "counts": raster.sum(axis=0)}
            if req.cursor < req.steps_total:
                res["partial"] = True
            if req.events_capacity is not None:
                from repro.events.aer import dense_to_aer
                res["events"] = dense_to_aer(
                    res["spikes"][:, None, :], req.events_capacity,
                    policy=req.events_policy)
            req.result_cache = res
            return res

    def _timing(self, req: _Request) -> dict:
        with self._lock:
            qw = sv = tot = None
            if req.admitted_at is not None:
                qw = req.admitted_at - req.submitted_at
            if req.finished_at is not None and req.admitted_at is not None:
                sv = req.finished_at - req.admitted_at
                tot = req.finished_at - req.submitted_at
            return {"queue_wait": qw, "service": sv, "total": tot}
