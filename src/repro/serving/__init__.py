"""Batched LM serving runtime (the ``serve_step`` the decode shapes lower).

Design mirrors production TPU serving: a static-shape decode loop over a
fixed batch of sequence slots (XLA-friendly — one compiled program reused
every step), a length-bucketing scheduler for admission, greedy sampling,
and per-slot completion masks. The KV cache is the stacked per-layer tree
from ``model.init_cache`` and shards per ``cache_partition`` on real
meshes.

Two layers:
  * :class:`BatchServer` — prefill a batch of prompts, decode to
    completion with a single jitted step (the decode_32k / long_500k cells
    lower exactly this step function).
  * :class:`Scheduler` — groups pending requests into length buckets so
    padding waste stays bounded (the admission policy a cluster front-end
    would run).

The SNN analogue — stateful spike streams over one compiled SpikeEngine
step — lives in :mod:`repro.serving.snn` (:class:`~repro.serving.snn.
SpikeServer` et al., re-exported here), with the async admission layer
(bounded request queue decoupled from the step loop) in
:mod:`repro.serving.frontend` (:class:`~repro.serving.frontend.
AsyncSpikeFrontend`).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.connector import (  # noqa: E402  (re-export)
    CarryConnectorBase,
    CarrySnapshot,
    FileCarryConnector,
    InMemoryCarryConnector,
    migrate_stream,
    rebalance_streams,
)
from repro.serving.frontend import (  # noqa: E402  (re-export)
    AsyncSpikeFrontend,
    FrontendConfig,
    RequestHandle,
)
from repro.serving.qos import (  # noqa: E402  (re-export)
    QoSClass,
    QoSPolicy,
    WeightedFairQueue,
)
from repro.serving.snn import (  # noqa: E402  (re-export)
    ModelStream,
    SlotScheduler,
    SpikeServer,
    StreamStats,
)

__all__ = ["Request", "Completion", "BatchServer", "Scheduler",
           "SpikeServer", "SlotScheduler", "ModelStream", "StreamStats",
           "AsyncSpikeFrontend", "FrontendConfig", "RequestHandle",
           "QoSClass", "QoSPolicy", "WeightedFairQueue",
           "CarryConnectorBase", "CarrySnapshot", "InMemoryCarryConnector",
           "FileCarryConnector", "migrate_stream", "rebalance_streams"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32 token ids
    max_new_tokens: int = 32
    eos_id: int | None = None


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray           # (<=max_new,) generated ids
    prompt_len: int
    latency_s: float


class BatchServer:
    """Fixed-slot batched prefill + decode engine for one model."""

    def __init__(self, model, params, *, max_seq: int, pad_id: int = 0):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.pad_id = pad_id
        self._decode_fn = jax.jit(self._decode_step)
        self._prefill_fn = jax.jit(self._prefill,
                                   static_argnames=("batch", "seq"))

    # -- jitted bodies ----------------------------------------------------
    def _prefill(self, params, tokens, *, batch: int, seq: int):
        cache = self.model.init_cache(batch, self.max_seq)
        logits, cache = self.model.prefill(params, {"tokens": tokens}, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    def _decode_step(self, params, cache, tokens, pos):
        logits, cache = self.model.decode_step(params, {"tokens": tokens},
                                               pos, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    # -- public -----------------------------------------------------------
    def serve(self, requests: Sequence[Request]) -> list[Completion]:
        """Greedy-decode a batch of same-bucket requests."""
        t0 = time.perf_counter()
        B = len(requests)
        prompt_lens = [len(r.prompt) for r in requests]
        S = max(prompt_lens)
        toks = np.full((B, S), self.pad_id, np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad to align end
        tok, cache = self._prefill_fn(self.params, jnp.asarray(toks),
                                      batch=B, seq=S)
        max_new = max(r.max_new_tokens for r in requests)
        max_new = min(max_new, self.max_seq - S)
        out = np.zeros((B, max_new), np.int32)
        done = np.zeros(B, bool)
        steps = 0
        for step in range(max_new):
            out[:, step] = np.asarray(tok[:, 0])
            for i, r in enumerate(requests):
                if r.eos_id is not None and out[i, step] == r.eos_id:
                    done[i] = True
                if step + 1 >= r.max_new_tokens:
                    done[i] = True
            steps += 1
            if done.all():
                break
            tok, cache = self._decode_fn(self.params, cache, tok,
                                         jnp.int32(S + step))
        dt = time.perf_counter() - t0
        comps = []
        for i, r in enumerate(requests):
            n = min(r.max_new_tokens, steps)
            comps.append(Completion(uid=r.uid, tokens=out[i, :n],
                                    prompt_len=prompt_lens[i], latency_s=dt))
        return comps

    def throughput_stats(self, comps: list[Completion]) -> dict:
        toks = sum(len(c.tokens) for c in comps)
        dt = max(c.latency_s for c in comps)
        return {"generated_tokens": toks, "wall_s": dt,
                "tokens_per_s": toks / max(dt, 1e-9)}


class Scheduler:
    """Length-bucketing admission: batches of <= max_batch, prompts padded
    at most 2x within a bucket (bounded padding waste)."""

    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.pending: list[Request] = []

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def next_batch(self) -> list[Request]:
        if not self.pending:
            return []
        self.pending.sort(key=lambda r: len(r.prompt))
        anchor = len(self.pending[0].prompt)
        batch = [r for r in self.pending
                 if len(r.prompt) <= max(2 * anchor, anchor + 16)]
        batch = batch[: self.max_batch]
        taken = {id(r) for r in batch}
        self.pending = [r for r in self.pending if id(r) not in taken]
        return batch
