"""Address-Event Representation: the sparse spike format.

Neuromorphic hardware does not move rasters, it moves *events*: the spike
packet paths of the paper carry ``(timestep, source address)`` tuples, and
silence costs nothing. :class:`AERStream` is that wire format as data — a
fixed-capacity array of ``(t, slot, source)`` address tuples plus a count,
so a whole stream is one static-shape pytree that crosses jit boundaries
without re-tracing per spike count.

Contracts:

  * **Addresses are sorted** lexicographically by ``(t, slot, source)`` —
    the order events leave the array, and the order ``jnp.nonzero`` emits,
    so dense -> AER -> dense is the identity whenever capacity suffices.
  * **Fixed capacity, explicit overflow.** A stream holds at most
    ``capacity`` events; ``total`` records how many the dense raster
    actually contained. ``policy="error"`` refuses a lossy conversion
    (host-side check on the jitted result); ``policy="drop"`` keeps the
    EARLIEST ``capacity`` events (hardware event-queue semantics: when the
    FIFO is full, late events are the ones lost) and flags
    :attr:`AERStream.overflowed`.
  * **Binary events.** Dense rasters are binarized (any nonzero is one
    event); spike rasters in this repo are {0,1} already.

Decoding routes through the u32-lane bitpacked raster form
(:mod:`repro.kernels.bitpack`): events scatter as single BITS into packed
lanes (:func:`aer_to_packed` — the kernel-side wire format), and the dense
{0,1} raster is the unpack of that. Only ``jax`` and the leaf-level
``repro.kernels.bitpack`` are imported here — everything above (engine,
serving, data) may depend on this module without cycles.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.kernels import bitpack

__all__ = ["AERStream", "dense_to_aer", "aer_to_dense", "aer_to_packed"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["addrs", "count", "total"],
    meta_fields=["shape"],
)
@dataclasses.dataclass(frozen=True)
class AERStream:
    """A fixed-capacity sparse spike stream.

    addrs: ``(capacity, 3)`` int32 — ``(t, slot, source)`` per event,
      lexicographically sorted; rows past ``count`` are ``-1`` filler.
    count: ``()`` int32 — events actually stored (<= capacity).
    total: ``()`` int32 — events in the source raster; ``total > count``
      iff the conversion overflowed (and was allowed to drop).
    shape: static ``(T, B, S)`` dense shape the stream addresses.
    """

    addrs: jnp.ndarray
    count: jnp.ndarray
    total: jnp.ndarray
    shape: tuple[int, int, int]

    @property
    def capacity(self) -> int:
        return int(self.addrs.shape[0])

    @property
    def overflowed(self) -> bool:
        return int(self.total) > int(self.count)

    @property
    def sparsity(self) -> float:
        """Fraction of dense (t, slot, source) sites that carry an event."""
        t, b, s = self.shape
        return float(self.total) / max(t * b * s, 1)

    def __len__(self) -> int:
        return int(self.count)


@functools.partial(jax.jit, static_argnames=("capacity",))
def _dense_to_aer(dense, capacity: int):
    nz = dense != 0
    total = nz.sum(dtype=jnp.int32)
    # row-major nonzero == (t, slot, source) lexicographic: truncation at
    # `capacity` drops the LATEST events, matching a full hardware FIFO.
    t, b, s = jnp.nonzero(nz, size=capacity, fill_value=-1)
    addrs = jnp.stack([t, b, s], axis=-1).astype(jnp.int32)
    return addrs, jnp.minimum(total, capacity), total


def dense_to_aer(dense, capacity: int, *, policy: str = "error") -> AERStream:
    """Convert a dense ``(T, B, S)`` raster to a fixed-capacity AER stream.

    ``policy="error"`` raises :class:`OverflowError` when the raster holds
    more than ``capacity`` events (no silent loss); ``policy="drop"``
    keeps the earliest ``capacity`` events and marks the stream
    ``overflowed``. The conversion itself is one jitted op either way —
    the policy is enforced on the already-computed ``total`` at the host
    boundary, where raising is possible.
    """
    if policy not in ("error", "drop"):
        raise ValueError(
            f"unknown overflow policy {policy!r}; expected 'error' or 'drop'"
        )
    dense = jnp.asarray(dense)
    if dense.ndim != 3:
        raise ValueError(
            f"dense raster must be (T, B, S), got shape {dense.shape}"
        )
    capacity = int(capacity)
    if capacity < 0:
        raise ValueError(f"capacity must be >= 0, got {capacity}")
    addrs, count, total = _dense_to_aer(dense, capacity)
    stream = AERStream(addrs=addrs, count=count, total=total,
                       shape=tuple(int(d) for d in dense.shape))
    if policy == "error" and stream.overflowed:
        raise OverflowError(
            f"raster holds {int(total)} events but the stream capacity is "
            f"{capacity}; raise capacity or use policy='drop'"
        )
    return stream


@functools.partial(jax.jit, static_argnames=("shape",))
def _aer_to_packed(addrs, count, shape: tuple[int, int, int]):
    # Each event scatters ONE BIT: value 1 << (source % 32) added into
    # lane (t, slot, source // 32). Stored addresses are unique (rows come
    # from jnp.nonzero), so add == bitwise-or. Rows past `count` (and -1
    # filler) must not scatter: their value is zeroed AND their index is
    # redirected to a positive sentinel past every axis (mode='drop' only
    # ignores out-of-bounds indices; negative indices would wrap).
    T, B, S = shape
    lanes = bitpack.packed_lanes(S)
    oob = jnp.int32(max(T, B, lanes, 1))
    valid = ((jnp.arange(addrs.shape[0]) < count)[:, None]
             & (addrs >= 0)).all(axis=1)
    t = jnp.where(valid, addrs[:, 0], oob)
    b = jnp.where(valid, addrs[:, 1], oob)
    lane = jnp.where(valid, addrs[:, 2] // bitpack.LANE_BITS, oob)
    bit = (addrs[:, 2] % bitpack.LANE_BITS).astype(jnp.uint32)
    val = jnp.where(valid, jnp.uint32(1) << bit, jnp.uint32(0))
    packed = jnp.zeros((T, B, lanes), jnp.uint32)
    return packed.at[t, b, lane].add(val, mode="drop")


def aer_to_packed(stream: AERStream) -> jnp.ndarray:
    """Decode an AER stream to the bitpacked ``(T, B, lanes)`` uint32
    raster (:mod:`repro.kernels.bitpack` lane layout: source ``s`` = lane
    ``s // 32``, bit ``s % 32``).

    This is the event path onto the kernel-side wire format: one jitted
    scatter of single bits, no dense intermediate.
    ``bitpack.count_spikes`` over the result equals the stream's stored
    event count.
    """
    return _aer_to_packed(stream.addrs, stream.count, stream.shape)


def aer_to_dense(stream: AERStream) -> jnp.ndarray:
    """Decode an AER stream back to its dense ``(T, B, S)`` {0,1} raster.

    Exact inverse of :func:`dense_to_aer` on binary rasters whenever the
    stream did not overflow; after a ``policy="drop"`` overflow it yields
    the raster of the earliest ``capacity`` events. The decode goes
    events -> packed lanes -> unpack, so the dense raster is by
    construction the unpack of :func:`aer_to_packed`.
    """
    return bitpack.unpack_spikes(aer_to_packed(stream), stream.shape[2])
