"""Spike/SOP trace recorder — measured event accounting from real rasters.

"Are SNNs Truly Energy-efficient?" (arXiv:2309.03388) argues SOP-level
energy claims must be *measured*, not estimated. This module measures: a
trace is a pure pass over the actual spike rasters a run produced —
counting source events, synaptic operations (each event weighted by its
source's real nonzero fan-out), and the weight-block traffic the event
gate does / would skip — and hands the totals to the energy model as
:class:`~repro.core.energy.WorkloadCounts`.

Purity discipline (same as the cost models): nothing here ever runs inside
the scan. Functional semantics and accounting cannot drift, and the trace
works on ANY raster — batch ``run`` outputs, streaming ``feed`` rasters
that never went through a frontend cost model, or AER streams straight
from :mod:`repro.events.aer`.

Traffic accounting mirrors the kernel's gate exactly: the Pallas timestep
fetches one ``(block_src, P)`` weight block per (batch tile, source block)
whose activity scalar is nonzero. ``gate="batch-tile"`` tiles the batch by
``tile_batch`` rows (one fetch serves the whole tile — the OR the kernel
used before per-example gating); ``gate="per-example"`` is the
batch-tile=1 mode, where every silent (example, source-block) pair skips
its fetch.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.events.aer import AERStream, aer_to_dense

__all__ = [
    "SpikeTraceReport",
    "block_traffic",
    "fused_block_traffic",
    "measured_counts",
    "trace_run",
]


def _as_dense(x) -> np.ndarray:
    if isinstance(x, AERStream):
        return np.asarray(aer_to_dense(x))
    return np.asarray(x)


def block_traffic(sources, *, block_src: int = 128,
                  tile_batch: int = 8,
                  fuse_steps: int = 1) -> tuple[int, int]:
    """Weight-block fetches the event gate performs on ``sources``.

    Args:
      sources: (T, B, S) source activity (external + boundary spikes).
      block_src: source rows per weight block (kernel ``block_src``).
      tile_batch: batch rows sharing one fetch (1 = per-example gate).
      fuse_steps: timesteps per fused kernel window (K). Gate scalars are
        ORed over each window — a block is fetched once per window iff ANY
        of its K steps spikes on it — and the trailing ragged window pads
        with silence, mirroring the engine's masked remainder.
    Returns:
      ``(touched, total)`` block fetches: gated vs dense for this tiling,
      at one fetch per (window, batch tile, source block).
    """
    src = _as_dense(sources)
    if src.ndim != 3:
        raise ValueError(f"sources must be (T, B, S), got {src.shape}")
    if fuse_steps < 1:
        raise ValueError(f"fuse_steps must be >= 1, got {fuse_steps}")
    T, B, S = src.shape
    nw = -(-T // fuse_steps)
    nb = -(-B // tile_batch)
    ns = -(-S // block_src)
    padded = np.zeros(
        (nw * fuse_steps, nb * tile_batch, ns * block_src), bool)
    padded[:T, :B, :S] = src != 0
    tiles = padded.reshape(nw, fuse_steps, nb, tile_batch, ns, block_src)
    touched = int(tiles.any(axis=(1, 3, 5)).sum())
    return touched, nw * nb * ns


def fused_block_traffic(sources, n_inputs: int, *, block_src: int = 128,
                        tile_batch: int = 8,
                        fuse_steps: int = 1) -> tuple[int, int]:
    """Weight-block fetches of the K-STEP FUSED kernel on ``sources``.

    The fused datapath splits the image at ``n_inputs``: EXTERNAL blocks
    are gated on window-OR activity and DMA'd once per active (window,
    batch tile, block); the RECURRENT image cannot be gated ahead of the
    in-window feedback, so ALL its blocks are fetched once per (window,
    batch tile) and held VMEM-resident. Returns ``(touched, total)``
    where ``total`` is the single-step dense baseline ``T * tiles *
    blocks`` — so ``touched / total`` is directly the fraction of
    per-step dense traffic the fused kernel moves (~1/K at dense
    activity; less when the external gate bites).
    """
    src = _as_dense(sources)
    if src.ndim != 3:
        raise ValueError(f"sources must be (T, B, S), got {src.shape}")
    T, B, S = src.shape
    if not 0 <= n_inputs <= S:
        raise ValueError(f"n_inputs={n_inputs} outside [0, {S}]")
    nw = -(-T // fuse_steps)
    nb = -(-B // tile_batch)
    ns_ext = -(-n_inputs // block_src)
    ns_rec = -(-(S - n_inputs) // block_src)
    ext_touched, _ = block_traffic(
        src[:, :, :n_inputs], block_src=block_src, tile_batch=tile_batch,
        fuse_steps=fuse_steps) if n_inputs else (0, 0)
    rec_touched = nw * nb * ns_rec
    total = T * nb * (ns_ext + ns_rec)
    return ext_touched + rec_touched, total


@dataclasses.dataclass(frozen=True)
class SpikeTraceReport:
    """Measured event totals for one run (any chunking, any backend)."""

    steps: int
    batch: int
    n_sources: int
    n_phys: int
    source_events: int        # source-side spikes (external + boundary)
    output_events: int        # spikes the neuron array emitted
    measured_sops: int        # sum over events of the source's real fanout
    dense_sops: int           # SOPs if every source spiked every step
    blocks: dict              # gate name -> (touched, total) block fetches

    @property
    def source_sparsity(self) -> float:
        return self.source_events / max(
            self.steps * self.batch * self.n_sources, 1)

    @property
    def output_sparsity(self) -> float:
        return self.output_events / max(
            self.steps * self.batch * self.n_phys, 1)

    def traffic_ratio(self, gate: str) -> float:
        """Gated weight-block traffic as a fraction of dense (lower is
        better; 1.0 means the gate skipped nothing)."""
        touched, total = self.blocks[gate]
        return touched / max(total, 1)

    @property
    def sop_ratio(self) -> float:
        """Measured SOPs as a fraction of the dense datapath's SOPs."""
        return self.measured_sops / max(self.dense_sops, 1)

    def summary(self) -> str:
        parts = [
            f"{self.steps} steps x {self.batch} streams: "
            f"{self.source_events} source events "
            f"({100 * self.source_sparsity:.2f}% dense), "
            f"{self.measured_sops} SOPs "
            f"({100 * self.sop_ratio:.2f}% of dense)",
        ]
        for gate, (touched, total) in self.blocks.items():
            parts.append(
                f"{gate} gate: {touched}/{total} weight blocks "
                f"({100 * touched / max(total, 1):.2f}% of dense)")
        return "; ".join(parts)


def trace_run(engine, ext_spikes, spikes, *, block_src: int = 128,
              tile_batch: int = 8) -> SpikeTraceReport:
    """Measure one run's event totals from its real rasters.

    Args:
      engine: a :class:`~repro.core.engine.SpikeEngine` (its weight image
        supplies the per-source fanout the SOP count weights events by).
      ext_spikes: (T, B, n_inputs) external raster or an
        :class:`~repro.events.aer.AERStream` of it.
      spikes: (T, B, n_phys) output raster (or AER stream) the engine
        produced for ``ext_spikes``.
    Returns:
      A :class:`SpikeTraceReport` with measured SOPs and gated-vs-dense
      weight-block traffic under both the batch-tile and per-example gate.
    """
    from repro.core.engine import sources_raster  # deferred: import cycle

    ext = _as_dense(ext_spikes)
    out = _as_dense(spikes)
    if ext.ndim != 3 or out.ndim != 3:
        raise ValueError(
            f"rasters must be (T, B, *), got ext {ext.shape} / "
            f"out {out.shape}"
        )
    if ext.shape[:2] != out.shape[:2]:
        raise ValueError(
            f"ext and output rasters disagree on (T, B): "
            f"{ext.shape[:2]} vs {out.shape[:2]}"
        )
    weights = np.asarray(engine.weights_raw)
    fanout = np.count_nonzero(weights, axis=1)  # (S,) real synapses/source
    sources = np.asarray(sources_raster(ext, out))  # (T, B, S)
    T, B, S = sources.shape
    events = sources != 0
    return SpikeTraceReport(
        steps=T,
        batch=B,
        n_sources=S,
        n_phys=out.shape[2],
        source_events=int(events.sum()),
        output_events=int((out != 0).sum()),
        measured_sops=int((events * fanout[None, None, :]).sum()),
        dense_sops=int(T * B * fanout.sum()),
        blocks={
            "batch-tile": block_traffic(
                sources, block_src=block_src, tile_batch=tile_batch),
            "per-example": block_traffic(
                sources, block_src=block_src, tile_batch=1),
        },
    )


def measured_counts(program, ext_spikes, spikes):
    """Measured :class:`~repro.core.energy.WorkloadCounts` for a program.

    SOPs and SRAM row fetches are COUNTED from the real rasters (each
    source event contributes its actual nonzero synapses / its actual
    existing ``(source, cluster)`` rows); only ``cycles`` still comes from
    the timing model — time is modeled, events are measured. The batch
    axis sums, as in :func:`repro.core.energy.counts_from_run` (one
    physical accelerator runs the B inferences sequentially).
    """
    from repro.core import cerebra_h
    from repro.core.energy import WorkloadCounts
    from repro.core.engine import sources_raster

    ext = _as_dense(ext_spikes)
    out = _as_dense(spikes)
    sources = np.asarray(sources_raster(ext, out)) != 0  # (T, B, S)
    fanout = np.asarray(program.fanout)                  # (S,)
    rows_per_event = np.asarray(program.row_exists).sum(axis=1)  # (S,)
    sops = float((sources * fanout[None, None, :]).sum())
    row_fetches = float((sources * rows_per_event[None, None, :]).sum())
    cost = cerebra_h.cost_model(program, ext, out)
    return WorkloadCounts(
        sops=sops,
        row_fetches=row_fetches,
        spike_packets=row_fetches,
        cycles=float(np.sum(np.asarray(cost["cycles"]))),
    )
