"""Event-driven sparse spike subsystem — AER streams + measured traces.

SNAP-V's efficiency story is event-driven sparsity: the Incoming Forwarder
only fetches weight rows for sources that actually spiked, so compute,
SRAM traffic, and energy all scale with spike activity. This package is
the software home of that property:

  aer    — fixed-capacity Address-Event Representation: ``(t, slot,
           source)`` address tuples, jitted dense<->AER conversion with an
           explicit overflow policy. The wire format of the spike-packet
           paths, as data.
  trace  — spike/SOP trace recorder: pure passes over real rasters (never
           inside the scan, same discipline as the cost models) producing
           MEASURED SOP counts and gated-vs-dense weight-traffic
           accounting for the energy model.
"""

from repro.events import aer, trace  # noqa: F401
from repro.events.aer import AERStream, aer_to_dense, dense_to_aer  # noqa: F401
from repro.events.trace import SpikeTraceReport, trace_run  # noqa: F401
