"""Loop-aware cost analysis of post-SPMD HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
but our models scan over layers and the train step scans over microbatches
— flops/bytes/collective counts are undercounted by factors of 32..832 for
the production programs (verified against an unrolled reference). XLA
however annotates every scan-derived loop with
``backend_config={"known_trip_count":{"n":...}}``, so the true totals are
recoverable from the HLO text alone.

This module parses ``compiled.as_text()`` into computations + instructions
and evaluates, with loop multipliers applied recursively:

  * flops       — dot ops exactly (2 * prod(result) * prod(contracted));
                  elementwise/reduce ops at 1 flop/element (matches the
                  HloCostAnalysis convention; dots dominate regardless)
  * bytes       — per instruction at fusion boundaries: result bytes +
                  operand bytes (the HBM-traffic view XLA itself uses)
  * collectives — result bytes per collective kind (all-reduce weighted 2x
                  downstream, ring reduce-scatter + all-gather)

The dry-run (repro.launch.dryrun) uses these totals for the roofline
terms; ``tests/test_hlo_analysis.py`` pins the analyzer against XLA's own
cost_analysis on unrolled programs.
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

__all__ = ["analyze_hlo", "collective_profile", "HLOCost"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

# ops that alias / move no HBM bytes of their own
_NO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "opt-barrier"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"          # name
    # tuple shapes may contain /*index=N*/ comments; no nested parens occur
    r"((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"  # shape
    r"([\w\-]+)\(")                                  # opcode
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_dims(shape_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """'(f32[4,2]{1,0}, s32[])' -> [('f32',(4,2)), ('s32',())]."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dtype, shape))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        total += int(np.prod(dims, dtype=np.int64)) * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(shape_str: str) -> int:
    return sum(int(np.prod(dims, dtype=np.int64))
               for _, dims in _shape_dims(shape_str))


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str


@dataclasses.dataclass
class HLOCost:
    flops: float
    bytes_accessed: float
    collective_bytes: dict[str, float]
    collective_counts: dict[str, float]

    @property
    def total_collective_bytes(self) -> float:
        """Per-device wire bytes; all-reduce counts 2x (ring RS + AG)."""
        w = {"all-reduce": 2.0}
        return float(sum(v * w.get(k, 1.0)
                         for k, v in self.collective_bytes.items()))

    def as_dict(self) -> dict:
        return {
            "counts": {k: v for k, v in self.collective_counts.items()},
            "bytes_by_kind": dict(self.collective_bytes),
            "total_bytes": self.total_collective_bytes,
        }


def _parse(hlo_text: str):
    """-> (computations {name: [Instr]}, fused_names set)."""
    comps: dict[str, list[Instr]] = {}
    fused: set[str] = set()
    cur: list[Instr] | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m and "=" not in line.split("(")[0]:
                comps[m.group(1)] = cur = []
            continue
        if s == "}" or s == "})":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, opcode = m.groups()
        rest = line[m.end():]
        # operand section: up to the matching close-paren at depth 0
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operand_str, attrs = rest[:i - 1], rest[i:]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        cur.append(Instr(name, shape, opcode, operands, attrs, line))
        if opcode == "fusion":
            cm = re.search(r"calls=%([\w.\-]+)", attrs)
            if cm:
                fused.add(cm.group(1))
        # reduce/scatter lambdas are effectively fused scalar bodies
        for am in re.finditer(r"to_apply=%([\w.\-]+)", attrs):
            fused.add(am.group(1))
    return comps, fused


def _dot_flops(instr: Instr, shapes: dict[str, str]) -> float:
    result_elems = _shape_elems(instr.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    if not m or not instr.operands:
        return 2.0 * result_elems  # degenerate
    lhs_shape = shapes.get(instr.operands[0])
    if lhs_shape is None:
        return 2.0 * result_elems
    dims_list = _shape_dims(lhs_shape)
    if not dims_list:
        return 2.0 * result_elems
    lhs_dims = dims_list[0][1]
    k = 1
    for d in (int(x) for x in m.group(1).split(",") if x):
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    return 2.0 * result_elems * k


def analyze_hlo(hlo_text: str) -> HLOCost:
    comps, fused = _parse(hlo_text)
    if not comps:
        # degenerate input (empty text, or a dialect the parser does not
        # recognize): report zero cost instead of crashing on the entry
        # lookup — callers treat it as "nothing to analyze"
        return HLOCost(flops=0.0, bytes_accessed=0.0,
                       collective_bytes={}, collective_counts={})
    # name -> result shape, for operand byte/contraction lookups (names are
    # unique module-wide in post-optimization HLO)
    shapes: dict[str, str] = {}
    for instrs in comps.values():
        for ins in instrs:
            shapes[ins.name] = ins.shape

    # ---- slice/in-place-aware fusion accounting -------------------------
    # Two pervasive patterns would otherwise overcount HBM traffic by the
    # loop trip count:
    #   * dynamic-slice of the (L, ...) stacked scan weights reads ONE
    #     layer's slice, not the full array;
    #   * dynamic-update-slice / scatter into a carried accumulator (the
    #     grad stacks in the backward scan, MoE buffer scatter) writes the
    #     UPDATE region in place — the full array is aliased, not copied.
    # Map: computation -> {param_index: effective_read_bytes}; and
    # computation -> effective_result_bytes for in-place-root fusions.
    _SLICE_OPS = {"dynamic-slice", "slice", "gather"}
    _INPLACE_OPS = {"dynamic-update-slice", "scatter"}
    # dtype round-trips and layout casts around an in-place update are
    # CPU-backend artifacts (convert(DUS(convert(x), u)) stays in-place on
    # TPU after algebraic simplification) — chase through them.
    _PASS_OPS = {"convert", "bitcast", "copy", "reshape"}
    fusion_param_bytes: dict[str, dict[int, float]] = {}
    fusion_result_bytes: dict[str, float] = {}
    for cname, instrs in comps.items():
        if cname not in fused:
            continue
        by_name = {i.name: i for i in instrs}
        params: dict[str, int] = {}
        for ins in instrs:
            if ins.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.line)
                if m:
                    params[ins.name] = int(m.group(1))

        def _read_bytes(pname: str, _depth=0) -> float | None:
            """Effective bytes read from `pname`; None = full read."""
            if _depth > 8:
                return None
            consumers = [i for i in instrs if pname in i.operands]
            if not consumers:
                return 0.0
            charged = 0.0
            for c in consumers:
                if c.opcode in _SLICE_OPS:
                    charged += float(_shape_bytes(c.shape))
                elif (c.opcode in _INPLACE_OPS and c.operands
                      and c.operands[0] == pname):
                    pass  # aliased in-place destination
                elif (c.opcode in _PASS_OPS and c.operands
                      and c.operands[0] == pname
                      and _shape_elems(c.shape) == _shape_elems(
                          by_name[pname].shape if pname in by_name
                          else c.shape)):
                    sub = _read_bytes(c.name, _depth + 1)
                    if sub is None:
                        return None
                    charged += sub
                else:
                    return None  # a full read exists
            return charged

        eff: dict[int, float] = {}
        for pname, pidx in params.items():
            got = _read_bytes(pname)
            if got is not None:
                eff[pidx] = got
        if eff:
            fusion_param_bytes[cname] = eff

        root = next((i for i in instrs if "ROOT" in i.line), None)
        # chase the root back through pass-through ops to find an in-place
        # update (write cost = the update region, not the accumulator)
        seen = 0
        while (root is not None and root.opcode in _PASS_OPS
               and root.operands and root.operands[0] in by_name
               and seen < 8):
            root = by_name[root.operands[0]]
            seen += 1
        if root is not None and root.opcode in _INPLACE_OPS:
            upd = (root.operands[1] if len(root.operands) > 1 else None)
            if upd is not None and upd in by_name:
                fusion_result_bytes[cname] = float(
                    _shape_bytes(by_name[upd].shape))

    memo: dict[tuple[str, bool], tuple] = {}

    def comp_cost(name: str, in_fusion: bool):
        """Returns (flops, bytes, coll_bytes dict, coll_counts dict)."""
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        flops = 0.0
        bytes_ = 0.0
        cb: dict[str, float] = {}
        cc: dict[str, float] = {}
        for ins in comps.get(name, ()):
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            # ---- flops ----
            if base in ("dot", "convolution"):
                flops += _dot_flops(ins, shapes)
            elif base not in _NO_BYTES and base not in ("while",
                                                        "conditional",
                                                        "call", "fusion"):
                flops += _shape_elems(ins.shape)  # ~1 flop/element
            # ---- bytes (fusion-boundary view; skip inside fusions) ----
            if not in_fusion and base not in _NO_BYTES and base not in (
                    "while", "conditional", "call"):
                eff = {}
                called = None
                if base == "fusion":
                    cm = re.search(r"calls=%([\w.\-]+)", ins.attrs)
                    if cm:
                        called = cm.group(1)
                        eff = fusion_param_bytes.get(called, {})
                if called is not None and called in fusion_result_bytes:
                    bytes_ += fusion_result_bytes[called]  # in-place write
                elif base == "dynamic-update-slice":
                    upd = shapes.get(ins.operands[1]) if len(
                        ins.operands) > 1 else None
                    bytes_ += 2.0 * _shape_bytes(upd) if upd else (
                        _shape_bytes(ins.shape))
                else:
                    bytes_ += _shape_bytes(ins.shape)
                if base != "dynamic-update-slice":
                    for oi, operand in enumerate(ins.operands):
                        if oi in eff:
                            bytes_ += eff[oi]  # sliced read, not full array
                            continue
                        osh = shapes.get(operand)
                        if osh is not None:
                            bytes_ += _shape_bytes(osh)
            # ---- collectives ----
            if base in COLLECTIVE_OPS:
                b = _shape_bytes(ins.shape)
                cb[base] = cb.get(base, 0.0) + b
                cc[base] = cc.get(base, 0.0) + 1
            # ---- sub-computations ----
            if base == "while":
                trip = 1.0
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trip = float(tm.group(1))
                for attr, mult in (("body", trip), ("condition", trip + 1)):
                    am = re.search(attr + r"=%([\w.\-]+)", ins.attrs)
                    if am:
                        f, b, scb, scc = comp_cost(am.group(1), in_fusion)
                        flops += f * mult
                        bytes_ += b * mult
                        for k, v in scb.items():
                            cb[k] = cb.get(k, 0.0) + v * mult
                        for k, v in scc.items():
                            cc[k] = cc.get(k, 0.0) + v * mult
            elif base == "fusion":
                am = re.search(r"calls=%([\w.\-]+)", ins.attrs)
                if am:
                    f, _, scb, scc = comp_cost(am.group(1), True)
                    flops += f
                    for k, v in scb.items():
                        cb[k] = cb.get(k, 0.0) + v
                    for k, v in scc.items():
                        cc[k] = cc.get(k, 0.0) + v
            elif base in ("call", "conditional"):
                for cname in re.findall(r"(?:to_apply|calls)=%([\w.\-]+)",
                                        ins.attrs):
                    f, b, scb, scc = comp_cost(cname, in_fusion)
                    flops += f
                    bytes_ += b
                    for k, v in scb.items():
                        cb[k] = cb.get(k, 0.0) + v
                    for k, v in scc.items():
                        cc[k] = cc.get(k, 0.0) + v
                if base == "conditional":
                    for cname in re.findall(
                            r"branch_computations=\{([^}]*)\}", ins.attrs):
                        for b_name in re.findall(r"%([\w.\-]+)", cname):
                            f, b, scb, scc = comp_cost(b_name, in_fusion)
                            flops += f  # upper bound: all branches
                            bytes_ += b
        memo[key] = (flops, bytes_, cb, cc)
        return memo[key]

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
                break
    if entry is None:  # fall back: the last computation
        entry = list(comps)[-1]
    flops, bytes_, cb, cc = comp_cost(entry, False)
    return HLOCost(flops=flops, bytes_accessed=bytes_,
                   collective_bytes=cb, collective_counts=cc)


def memory_profile(hlo_text: str, top: int = 16) -> list[tuple]:
    """Attribute bytes-accessed to (opcode, result shape) with loop
    multipliers — the memory-side §Perf profile.

    Returns [(bytes, opcode, shape, count, sample_name), ...]. Uses the
    same per-instruction convention as analyze_hlo (operands + result at
    fusion boundaries, slice/in-place aware via full analyze semantics is
    NOT replicated here — this is the raw boundary view for ranking).
    """
    comps, fused = _parse(hlo_text)
    if not comps:
        return []
    shapes = {}
    for instrs in comps.values():
        for ins in instrs:
            shapes[ins.name] = ins.shape
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        mult[name] = mult.get(name, 0.0) + m
        for ins in comps.get(name, ()):
            base = (ins.opcode[:-6] if ins.opcode.endswith("-start")
                    else ins.opcode)
            if base == "while":
                trip = 1.0
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trip = float(tm.group(1))
                bm = re.search(r"body=%([\w.\-]+)", ins.attrs)
                if bm:
                    visit(bm.group(1), m * trip)
            elif base == "call":
                for cname in re.findall(r"to_apply=%([\w.\-]+)", ins.attrs):
                    visit(cname, m)

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
                break
    visit(entry or list(comps)[-1], 1.0)

    agg: dict[tuple[str, str], list] = {}
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or cname in fused:
            continue
        for ins in instrs:
            base = (ins.opcode[:-6] if ins.opcode.endswith("-start")
                    else ins.opcode)
            if base in _NO_BYTES or base in ("while", "call", "conditional"):
                continue
            b = _shape_bytes(ins.shape)
            for o in ins.operands:
                if o in shapes:
                    b += _shape_bytes(shapes[o])
            key = (base, ins.shape.split("{")[0][:48])
            cur = agg.setdefault(key, [0.0, 0.0, ins.name])
            cur[0] += b * m
            cur[1] += m
    rows = [(v[0], k[0], k[1], v[1], v[2]) for k, v in agg.items()]
    rows.sort(reverse=True)
    return rows[:top]


def collective_profile(hlo_text: str, top: int = 12) -> list[tuple]:
    """Attribute collective bytes to (kind, result shape) with loop
    multipliers — the 'profile' the §Perf hillclimb reads.

    Returns [(weighted_bytes, kind, shape, count, sample_op_name), ...].
    """
    comps, fused = _parse(hlo_text)
    if not comps:
        return []
    # multiplier per computation = product of enclosing trip counts
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        mult[name] = mult.get(name, 0.0) + m
        for ins in comps.get(name, ()):
            base = (ins.opcode[:-6] if ins.opcode.endswith("-start")
                    else ins.opcode)
            if base == "while":
                trip = 1.0
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trip = float(tm.group(1))
                bm = re.search(r"body=%([\w.\-]+)", ins.attrs)
                if bm:
                    visit(bm.group(1), m * trip)
            elif base in ("call", "conditional", "fusion"):
                for cname in re.findall(
                        r"(?:calls|to_apply)=%([\w.\-]+)", ins.attrs):
                    visit(cname, m)

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
                break
    visit(entry or list(comps)[-1], 1.0)

    agg: dict[tuple[str, str], list] = {}
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for ins in instrs:
            base = (ins.opcode[:-6] if ins.opcode.endswith("-start")
                    else ins.opcode)
            if base not in COLLECTIVE_OPS:
                continue
            w = 2.0 if base == "all-reduce" else 1.0
            key = (base, ins.shape.split("{")[0])
            cur = agg.setdefault(key, [0.0, 0.0, ins.name])
            cur[0] += _shape_bytes(ins.shape) * w * m
            cur[1] += m
    rows = [(v[0], k[0], k[1], v[1], v[2]) for k, v in agg.items()]
    rows.sort(reverse=True)
    return rows[:top]
