"""gemma3-12b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3 family; unverified].

48L d_model=3840 16H (GQA kv=8, head_dim 256) d_ff=15360 vocab=262144.
Five sliding-window (1024) layers per global layer; qk-norm; geglu;
scaled + tied embeddings. Mostly-local attention -> long_500k RUNS (the
global layers' decode cost is linear in context with a cache; prefill
quadratic cost applies only to every 6th layer).
"""

import dataclasses

from repro.models.common import TransformerConfig
from repro.models.transformer import DecoderLM

CONFIG = TransformerConfig(
    name="gemma3-12b",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    sliding_window=1024,
    global_every=6,
    rope_theta=1e6,
    qk_norm=True,
    mlp_kind="geglu",
    embed_scale=True,
    tie_embeddings=True,
    subquadratic=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, sliding_window=8, global_every=3,
)


def build(cfg: TransformerConfig | None = None) -> DecoderLM:
    return DecoderLM(cfg or CONFIG)
