"""rwkv6-7b [ssm] — Finch, data-dependent decay [arXiv:2404.05892; hf].

32L d_model=4096 (attention-free; 64 heads x 64) d_ff=14336 vocab=65536.
Token mixing is an O(1)-state linear recurrence with learned per-channel
decay — the closest living relative of the paper's LIF leak (DESIGN.md
§4). long_500k RUNS (decode state does not grow with context at all).
"""

import dataclasses

from repro.models.common import RWKVConfig, TransformerConfig
from repro.models.transformer import DecoderLM

CONFIG = TransformerConfig(
    name="rwkv6-7b",
    n_layers=32,
    d_model=4096,
    n_heads=64,
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    block_kind="rwkv6",
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, gate_lora=32),
    subquadratic=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    rwkv=RWKVConfig(head_dim=16, decay_lora=8, gate_lora=8),
)


def build(cfg: TransformerConfig | None = None) -> DecoderLM:
    return DecoderLM(cfg or CONFIG)
