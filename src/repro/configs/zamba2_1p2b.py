"""zamba2-1.2b [hybrid] — Mamba2 + shared attention blocks
[arXiv:2411.15242; hf].

38 Mamba2 layers (d_model=2048, d_inner=4096, heads 64x64, ssm_state=64)
with ONE weight-shared attention+MLP block (32H, kv=32, d_ff=8192)
invoked every 6 layers through per-invocation LoRA. Hybrid/SSM ->
long_500k RUNS (backbone state is O(1) in context; only the 6 shared-attn
caches grow).
"""

import dataclasses

from repro.models.common import SSMConfig, TransformerConfig
from repro.models.zamba2 import Zamba2LM

CONFIG = TransformerConfig(
    name="zamba2-1.2b",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    block_kind="mamba2",
    shared_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
    tie_embeddings=True,
    subquadratic=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=7, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, shared_attn_every=3,
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16),
)


def build(cfg: TransformerConfig | None = None) -> Zamba2LM:
    return Zamba2LM(cfg or CONFIG)
