"""mixtral-8x7b [moe] — 8 experts top-2, SWA-4096 [arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8, head_dim 128) d_ff=14336 vocab=32000.
Sliding-window attention makes it sub-quadratic -> long_500k runs with a
4096-slot ring cache. MoE top-2 gating is the closest architectural
analogue of the paper's event-gated weight fetch (DESIGN.md §4).
"""

import dataclasses

from repro.models.common import MoEConfig, TransformerConfig
from repro.models.transformer import DecoderLM

CONFIG = TransformerConfig(
    name="mixtral-8x7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=8, top_k=2),
    subquadratic=True,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, sliding_window=8,
    moe=MoEConfig(n_experts=4, top_k=2),
)


def build(cfg: TransformerConfig | None = None) -> DecoderLM:
    return DecoderLM(cfg or CONFIG)
