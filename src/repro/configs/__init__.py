"""Architecture registry: ``--arch <id>`` resolves here.

Each module exports CONFIG (the exact published configuration), REDUCED
(a same-family small config for CPU smoke tests) and ``build(cfg)``.
"""

from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, Shape  # noqa: F401

ARCHS: dict[str, str] = {
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "granite-20b": "repro.configs.granite_20b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
}


def get_arch(arch_id: str):
    """Returns the arch module (CONFIG, REDUCED, build)."""
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(ARCHS)}")
    return importlib.import_module(ARCHS[arch_id])


def list_archs() -> list[str]:
    return list(ARCHS)
