"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8, head_dim 128) per-expert d_ff=8192
vocab=202048. Full attention -> long_500k SKIPPED (DESIGN.md §4). The
"early fusion" multimodal frontend is out of backbone scope (text tokens
here); MoE top-1 routing again mirrors the paper's gated weight access.
"""

import dataclasses

from repro.models.common import MoEConfig, TransformerConfig
from repro.models.transformer import DecoderLM

CONFIG = TransformerConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=5e5,
    moe=MoEConfig(n_experts=16, top_k=1, shared_expert=True),
    subquadratic=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=256,
    moe=MoEConfig(n_experts=4, top_k=1, shared_expert=True),
)


def build(cfg: TransformerConfig | None = None) -> DecoderLM:
    return DecoderLM(cfg or CONFIG)
