"""minicpm3-4b [dense] — MLA (multi-head latent attention)
[hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H d_ff=6400 vocab=73448. MLA compresses the KV cache
into a 256-d latent + 32-d rope key per token (the paper's
memory-dominates lens applied to decode). Full attention -> long_500k
SKIPPED. 40 heads are not divisible by the 16-way model axis: attention
weights replicate over 'model' (partitioner divisibility fallback); FFN
carries the TP sharding.
"""

import dataclasses

from repro.models.common import MLAConfig, TransformerConfig
from repro.models.transformer import DecoderLM

CONFIG = TransformerConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,  # nope 64 + rope 32
    d_ff=6400,
    vocab_size=73448,
    rope_theta=1e4,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, rope_head_dim=32,
                  nope_head_dim=64, v_head_dim=64),
    subquadratic=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=24,
    d_ff=128, vocab_size=256,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                  nope_head_dim=16, v_head_dim=16),
)


def build(cfg: TransformerConfig | None = None) -> DecoderLM:
    return DecoderLM(cfg or CONFIG)
