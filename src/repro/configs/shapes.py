"""Assigned input shapes (one set shared by all LM archs).

``train_*`` lowers train_step; ``prefill_*`` lowers the serving prefill;
``decode_*`` / ``long_*`` lower serve_step (one new token against a KV
cache of seq_len). long_500k requires a sub-quadratic arch
(cfg.subquadratic) — the dry-run records a documented SKIP otherwise
(DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses

__all__ = ["Shape", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}
