"""granite-20b [dense] — llama-arch code model, MQA [arXiv:2405.04324; hf].

52L d_model=6144 48H (MQA kv=1, head_dim 128) d_ff=24576 vocab=49152.
Single KV head: decode caches shard on the sequence axis (kv heads cannot
split). GELU (non-gated) MLP. Full attention -> long_500k SKIPPED.
"""

import dataclasses

from repro.models.common import TransformerConfig
from repro.models.transformer import DecoderLM

CONFIG = TransformerConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=1e5,
    mlp_kind="gelu",
    subquadratic=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256,
)


def build(cfg: TransformerConfig | None = None) -> DecoderLM:
    return DecoderLM(cfg or CONFIG)
