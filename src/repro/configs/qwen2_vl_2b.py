"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2, head_dim 128) d_ff=8960 vocab=151936.
The vision frontend is a stub: input_specs() supplies merged patch+text
embeddings (B, S, d) plus 3D M-RoPE position ids (3, B, S) =
(temporal, height, width). Tied embeddings. Full attention -> long_500k
SKIPPED. 12 heads not divisible by 16 -> attention replicates over
'model'; FFN carries TP.
"""

import dataclasses

from repro.models.common import TransformerConfig
from repro.models.transformer import DecoderLM

CONFIG = TransformerConfig(
    name="qwen2-vl-2b",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    rope_theta=1e6,
    mrope=True,
    frontend="embeddings",
    tie_embeddings=True,
    subquadratic=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
)


def build(cfg: TransformerConfig | None = None) -> DecoderLM:
    return DecoderLM(cfg or CONFIG)
