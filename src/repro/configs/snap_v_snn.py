"""The paper's own workload configs: SNAP-V MNIST spiking MLPs.

Table IV grid: hidden sizes {16, 32, 64, 128, 256} x T in {25, 50, 75,
100} (train) x same (infer). Plus the Cerebra-H accelerator geometry.
"""

from repro.core.cerebra_h import CerebraHConfig
from repro.core.lif import LIFParams
from repro.core.mapping import ClusterGeometry
from repro.snn.model import SNNModelConfig

HIDDEN_SIZES = (16, 32, 64, 128, 256)
TIMESTEPS = (25, 50, 75, 100)

ACCELERATOR = CerebraHConfig(
    geometry=ClusterGeometry(
        n_clusters=32, neurons_per_cluster=32, clusters_per_group=4,
        rows_per_group=2048),
    row_mode="external_broadcast",
)

LIF = LIFParams(decay_rate=0.1, threshold=1.0, reset_mode="zero")


def model_config(hidden: int) -> SNNModelConfig:
    return SNNModelConfig(layer_sizes=(784, hidden, 10), params=LIF)
