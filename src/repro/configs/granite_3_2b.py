"""granite-3-2b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base; hf].

40L d_model=2048 32H (GQA kv=8, head_dim 64) d_ff=8192 vocab=49155.
Tied embeddings. Full attention -> long_500k SKIPPED. vocab 49155 is not
divisible by the model axis (16): the embedding replicates (divisibility
fallback in the partitioner).
"""

import dataclasses

from repro.models.common import TransformerConfig
from repro.models.transformer import DecoderLM

CONFIG = TransformerConfig(
    name="granite-3-2b",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    rope_theta=1e4,
    tie_embeddings=True,
    subquadratic=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
)


def build(cfg: TransformerConfig | None = None) -> DecoderLM:
    return DecoderLM(cfg or CONFIG)
