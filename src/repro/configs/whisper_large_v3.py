"""whisper-large-v3 [audio] — enc-dec, conv frontend STUB
[arXiv:2212.04356; unverified].

32 encoder + 32 decoder layers, d_model=1280 20H (MHA kv=20, head_dim 64)
d_ff=5120 vocab=51866. The mel/conv frontend is a stub per the
assignment: input_specs() supplies precomputed frame embeddings
(B, S_enc, d). Shape mapping (DESIGN.md §4): seq_len splits as
enc_frames = seq//2, dec_tokens = seq//2. Full attention -> long_500k
SKIPPED. 20 heads not divisible by 16 -> attention replicates over
'model'; FFN carries TP.
"""

import dataclasses

from repro.models.common import TransformerConfig
from repro.models.whisper import WhisperLM

CONFIG = TransformerConfig(
    name="whisper-large-v3",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    attn_bias=True,
    mlp_kind="gelu",
    norm_eps=1e-5,
    subquadratic=False,
)

REDUCED = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
)


def build(cfg: TransformerConfig | None = None) -> WhisperLM:
    cfg = cfg or CONFIG
    return WhisperLM(cfg, max_dec_len=1 << 15 if cfg is CONFIG else 64)
