"""Fault-tolerant checkpointing (atomic, versioned, elastic).

Design points for 1000+ node deployments, scaled to this container:

  * **Atomicity**: checkpoints are staged into ``<dir>/tmp.<step>`` and
    ``os.replace``d into ``<dir>/step_<n>`` — a crashed save can never
    shadow a good checkpoint.
  * **Integrity**: every array file carries a CRC32 in the manifest;
    ``load`` verifies before restoring (a half-written file fails loudly).
  * **Retention**: ``keep`` newest checkpoints are retained; older ones are
    garbage-collected only AFTER a successful save (never delete the last
    good state).
  * **Elasticity**: checkpoints store the *logical* arrays (host numpy) +
    the pytree structure. ``load(..., sharding_tree=...)`` re-lays-out onto
    any mesh — restore on 256 chips what was saved from 512 (elastic
    scale-down) or vice versa. Nothing in the format encodes the mesh.
  * **Async**: ``AsyncCheckpointer`` overlaps serialization with training
    (device->host copy happens at call time; disk write on a worker
    thread), the standard hide-the-checkpoint-latency trick.

Format: one ``.npz`` for leaves + ``manifest.msgpack`` (treedef as
path-tuples, dtypes/shapes, crc32, user metadata).
"""

from __future__ import annotations

import dataclasses
import os
import re
import shutil
import threading
import zlib
from typing import Any

import jax
import msgpack
import numpy as np

__all__ = ["save", "load", "latest_step", "all_steps", "AsyncCheckpointer",
           "CheckpointError"]

_STEP_RE = re.compile(r"^step_(\d+)$")

# numpy cannot serialize ml_dtypes (bfloat16, float8_*) through npz; store
# them as raw same-width unsigned views and restore from the manifest dtype.
_NATIVE_KINDS = set("biufc?")  # bool/int/uint/float/complex


def _to_storable(arr: np.ndarray) -> np.ndarray:
    if arr.dtype.kind in _NATIVE_KINDS:
        return arr
    return arr.view(f"u{arr.dtype.itemsize}")


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if arr.dtype.name == dtype_name:
        return arr
    import ml_dtypes  # ships with jax
    return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))


class CheckpointError(RuntimeError):
    pass


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def save(directory: str, step: int, tree, metadata: dict | None = None,
         keep: int = 3) -> str:
    """Atomically persist ``tree`` as ``<directory>/step_<step>``."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    treedef = jax.tree.structure(tree)
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        arrays_path = os.path.join(tmp, "arrays.npz")
        np.savez(arrays_path, **{k: _to_storable(v) for k, v in flat.items()})
        crc = zlib.crc32(open(arrays_path, "rb").read())
        manifest = {
            "step": step,
            "keys": list(flat.keys()),
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "treedef": str(treedef),
            "crc32": crc,
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(
                os.path.join(directory, name, "manifest.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def load(directory: str, step: int | None = None, *, like=None,
         sharding_tree=None) -> tuple[Any, dict]:
    """Restore a checkpoint.

    Args:
      directory: checkpoint root.
      step: which step (default: latest).
      like: a pytree with the target structure; required to rebuild the
        treedef (the manifest stores paths, not code objects).
      sharding_tree: optional pytree of jax.sharding.Sharding matching
        ``like`` — each leaf is device_put with its sharding (elastic
        re-layout onto the current mesh).
    Returns: (tree, metadata)
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise CheckpointError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step}")
    man_path = os.path.join(path, "manifest.msgpack")
    if not os.path.exists(man_path):
        raise CheckpointError(f"missing manifest in {path}")
    manifest = msgpack.unpackb(open(man_path, "rb").read())
    arrays_path = os.path.join(path, "arrays.npz")
    raw = open(arrays_path, "rb").read()
    if zlib.crc32(raw) != manifest["crc32"]:
        raise CheckpointError(
            f"checkpoint {path} failed CRC validation (corrupt/partial)")
    npz = np.load(arrays_path)
    flat = {k: _from_storable(npz[k], manifest["dtypes"][k])
            for k in manifest["keys"]}
    if like is None:
        return flat, manifest["metadata"]
    # rebuild in the order of `like`'s flattened paths
    paths = [
        "/".join(_path_str(p) for p in path_)
        for path_, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    missing = [p for p in paths if p not in flat]
    if missing:
        raise CheckpointError(f"checkpoint missing keys: {missing[:5]} ...")
    leaves = [flat[p] for p in paths]
    treedef = jax.tree.structure(like)
    tree = jax.tree.unflatten(treedef, leaves)
    if sharding_tree is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, sharding_tree)
    return tree, manifest["metadata"]


@dataclasses.dataclass
class AsyncCheckpointer:
    """Overlap checkpoint IO with training (single in-flight save)."""

    directory: str
    keep: int = 3
    _thread: threading.Thread | None = None
    _error: BaseException | None = None

    def save(self, step: int, tree, metadata: dict | None = None) -> None:
        self.wait()  # one in-flight save; device->host copy happens HERE
        flat_host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def worker():
            try:
                save(self.directory, step, flat_host, metadata, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
