"""The hardware configuration compiler — logical neurons -> clusters.

Cerebra-H groups 1024 physical neurons into 32 clusters of 32; cluster
groups of 4 clusters share one single-port weight SRAM of 2048 rows, where
one row holds the 32 weights from ONE source (cluster-ID, neuron-ID) to the
32 neurons of ONE destination cluster. The paper: "Clustering enables us to
place neurons with common synapses within the same cluster to reduce the
distance spike packets should travel."

This module is the analogue of the paper's (unreleased) "custom hardware
configuration compiler": it places logical neurons onto physical slots,
checks SRAM row budgets, and reports the static communication profile the
timing model consumes.

Row-budget semantics (DESIGN.md §2, changed-assumption note): the literal
reading (every (source, destination-cluster) pair with any nonzero weight
consumes one row in the destination's group) makes the paper's own
784->256->10 MNIST net infeasible. We support both:

  * ``row_mode='strict'``      — literal reading; compile fails if over.
  * ``row_mode='external_broadcast'`` — rows for EXTERNAL stimulus sources
    are resolved once per group and fanned to its four clusters (the
    Incoming Forwarder already performs a per-cluster lookup, so sharing a
    fetched row across co-resident clusters is a small RTL delta). This is
    the mode that makes the paper's experiments fit, and the default.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.network import SNNetwork

__all__ = [
    "ClusterGeometry",
    "Placement",
    "place_contiguous",
    "place_random",
    "place_greedy",
    "row_usage",
    "check_capacity",
    "communication_profile",
]


@dataclasses.dataclass(frozen=True)
class ClusterGeometry:
    n_clusters: int = 32
    neurons_per_cluster: int = 32
    clusters_per_group: int = 4
    rows_per_group: int = 2048
    # hierarchical NoC shape: L1 router per `clusters_per_l1` clusters,
    # one L2 router over all L1s (paper: 4 clusters/L1, 8 L1s/L2).
    clusters_per_l1: int = 4

    @property
    def n_physical(self) -> int:
        return self.n_clusters * self.neurons_per_cluster

    @property
    def n_groups(self) -> int:
        return self.n_clusters // self.clusters_per_group

    @property
    def n_l1_routers(self) -> int:
        return self.n_clusters // self.clusters_per_l1

    @property
    def total_synapse_capacity(self) -> int:
        # rows * 32 weights each, all groups (paper: 524,288).
        return self.n_groups * self.rows_per_group * self.neurons_per_cluster

    def cluster_of(self, phys: np.ndarray) -> np.ndarray:
        return phys // self.neurons_per_cluster

    def group_of_cluster(self, cluster: np.ndarray) -> np.ndarray:
        return cluster // self.clusters_per_group

    def l1_of_cluster(self, cluster: np.ndarray) -> np.ndarray:
        return cluster // self.clusters_per_l1


@dataclasses.dataclass
class Placement:
    """neuron_to_physical[i] = physical slot of logical neuron i."""

    geometry: ClusterGeometry
    neuron_to_physical: np.ndarray  # (n_neurons,) int

    def __post_init__(self):
        p = np.asarray(self.neuron_to_physical, np.int64)
        if len(np.unique(p)) != len(p):
            raise ValueError("placement maps two neurons to one slot")
        if p.size and (p.min() < 0 or p.max() >= self.geometry.n_physical):
            raise ValueError("placement out of range")
        self.neuron_to_physical = p

    @property
    def n_neurons(self) -> int:
        return int(self.neuron_to_physical.size)

    def cluster_of_neuron(self, i) -> np.ndarray:
        return self.geometry.cluster_of(self.neuron_to_physical[i])


def place_contiguous(net: SNNetwork, geom: ClusterGeometry) -> Placement:
    """Identity placement: neuron i -> slot i (layer-contiguous for
    feedforward nets, since layers are numbered contiguously)."""
    _require_fits(net, geom)
    return Placement(geom, np.arange(net.n_neurons))


def place_random(net: SNNetwork, geom: ClusterGeometry, seed: int = 0
                 ) -> Placement:
    _require_fits(net, geom)
    rng = np.random.default_rng(seed)
    slots = rng.permutation(geom.n_physical)[: net.n_neurons]
    return Placement(geom, slots)


def place_greedy(net: SNNetwork, geom: ClusterGeometry) -> Placement:
    """Locality-aware greedy placement.

    Orders neurons so that neurons sharing presynaptic sources land in the
    same cluster (one SRAM row then serves up to 32 destinations at once,
    and spike packets stay on the local L1 router). Strategy: process
    layers in order (feedforward locality is already contiguous); within a
    layer, sort neurons by their dominant source cluster so recurrent nets
    also cluster by connectivity.
    """
    _require_fits(net, geom)
    order: list[int] = []
    slices = net.layer_slices or ((0, net.n_neurons),)
    W = net.weights
    for lo, hi in slices:
        idx = np.arange(lo, hi)
        if len(order) == 0:
            order.extend(idx.tolist())
            continue
        # dominant presynaptic *neuron* block of each candidate (inputs are
        # handled by external_broadcast rows; neuron sources drive NoC hops)
        src = np.abs(W[net.n_inputs :, lo:hi])  # (n_neurons, width)
        # bucket sources by the cluster their (already placed or identity)
        # position falls in
        buckets = np.add.reduceat(
            src,
            np.arange(0, src.shape[0], geom.neurons_per_cluster),
            axis=0,
        )
        dom = np.argmax(buckets, axis=0) if buckets.size else np.zeros(len(idx))
        order.extend(idx[np.argsort(dom, kind="stable")].tolist())
    return Placement(geom, _slots(order))


def _slots(order: list[int]) -> np.ndarray:
    """Assign consecutive physical slots in the given processing order."""
    slots = np.empty(len(order), np.int64)
    for phys, logical in enumerate(order):
        slots[logical] = phys
    return slots


def _require_fits(net: SNNetwork, geom: ClusterGeometry) -> None:
    if net.n_neurons > geom.n_physical:
        raise ValueError(
            f"{net.n_neurons} neurons > {geom.n_physical} physical slots"
        )


# --------------------------------------------------------------------------
# Capacity accounting
# --------------------------------------------------------------------------

def _edges(net: SNNetwork, placement: Placement):
    """Nonzero (source, dst_cluster) incidence.

    Returns (ext_rows, neuron_rows): boolean matrices
      ext_rows:    (n_inputs, n_clusters)
      neuron_rows: (n_clusters_src, n_clusters) — source *clusters* since a
                   row is addressed by source (cluster, neuron); we keep the
                   per-source-neuron resolution below where needed.
    plus per-destination-cluster nonzero masks at source-neuron resolution.
    """
    geom = placement.geometry
    n_in = net.n_inputs
    W = net.weights
    # destination cluster of each logical neuron
    dst_cluster = geom.cluster_of(placement.neuron_to_physical)  # (n_neurons,)
    nz = W != 0.0
    # collapse destinations into clusters
    n_c = geom.n_clusters
    dst_onehot = np.zeros((net.n_neurons, n_c), bool)
    dst_onehot[np.arange(net.n_neurons), dst_cluster] = True
    src_to_cluster_nz = nz @ dst_onehot  # (n_sources, n_clusters) bool
    return src_to_cluster_nz[:n_in], src_to_cluster_nz[n_in:]


def row_usage(
    net: SNNetwork,
    placement: Placement,
    row_mode: str = "external_broadcast",
) -> np.ndarray:
    """Rows consumed per cluster group. Returns (n_groups,) int array."""
    geom = placement.geometry
    ext_rows, neuron_rows = _edges(net, placement)
    group_of = geom.group_of_cluster(np.arange(geom.n_clusters))
    usage = np.zeros(geom.n_groups, np.int64)
    for g in range(geom.n_groups):
        clusters = np.where(group_of == g)[0]
        if row_mode == "strict":
            usage[g] += int(ext_rows[:, clusters].sum())
        elif row_mode == "external_broadcast":
            # one row per external source per *group* (fanned to clusters)
            usage[g] += int(ext_rows[:, clusters].any(axis=1).sum())
        else:
            raise ValueError(f"unknown row_mode {row_mode!r}")
        # neuron-to-neuron rows are always per (source neuron, dst cluster)
        usage[g] += int(neuron_rows[:, clusters].sum())
    return usage


def check_capacity(
    net: SNNetwork,
    placement: Placement,
    row_mode: str = "external_broadcast",
) -> dict:
    """Validate SRAM budgets; raises ValueError when infeasible."""
    geom = placement.geometry
    usage = row_usage(net, placement, row_mode)
    report = {
        "rows_per_group": usage,
        "rows_budget": geom.rows_per_group,
        "total_synapses": net.n_synapses,
        "synapse_capacity": geom.total_synapse_capacity,
        "feasible": bool(
            (usage <= geom.rows_per_group).all()
            and net.n_synapses <= geom.total_synapse_capacity
        ),
        "row_mode": row_mode,
    }
    if not report["feasible"]:
        raise ValueError(
            f"network exceeds Cerebra-H capacity: rows/group={usage.tolist()}"
            f" (budget {geom.rows_per_group}), synapses={net.n_synapses}"
            f" (capacity {geom.total_synapse_capacity}), row_mode={row_mode}"
        )
    return report


def communication_profile(net: SNNetwork, placement: Placement) -> dict:
    """Static NoC profile: cluster->cluster edges and their hop classes.

    Hop classes (paper Fig. 3 topology):
      local  — same cluster (never leaves the cluster datapath),
      l1     — distinct clusters under the same L1 router,
      l2     — crosses the central L2 router.
    """
    geom = placement.geometry
    _, neuron_rows = _edges(net, placement)  # (n_neurons, n_clusters)
    src_cluster = geom.cluster_of(placement.neuron_to_physical)
    n_c = geom.n_clusters
    edge = np.zeros((n_c, n_c), np.int64)  # src_cluster -> dst_cluster count
    for i in range(net.n_neurons):
        dsts = np.where(neuron_rows[i])[0]
        edge[src_cluster[i], dsts] += 1
    sc, dc = np.nonzero(edge)
    same_cluster = sc == dc
    same_l1 = geom.l1_of_cluster(sc) == geom.l1_of_cluster(dc)
    counts = edge[sc, dc]
    return {
        "edge_matrix": edge,
        "local_edges": int(counts[same_cluster].sum()),
        "l1_edges": int(counts[~same_cluster & same_l1].sum()),
        "l2_edges": int(counts[~same_l1].sum()),
    }
