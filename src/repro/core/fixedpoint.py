"""Fixed-point arithmetic emulation for the Cerebra accelerators.

The hardware datapath uses 32-bit fixed-point membrane potentials and
synaptic weights. We model them as Q16.16 (configurable) signed int32, with
arithmetic right-shift decay (Cerebra-H) and fixed-point multiply decay
(Cerebra-S). All functions are jittable and bit-exact with respect to the
RTL semantics described in the paper:

  * accumulation: wrapping int32 adds (hardware adders wrap),
  * Cerebra-S decay: (V * decay_q) >> frac_bits with round-toward-neg-inf
    (arithmetic shift), matching a truncating fixed-point multiplier,
  * Cerebra-H decay: V - (V >> k) compositions for decay rates
    {0.125, 0.25, 0.5, 0.75} (retain {0.875, 0.75, 0.5, 0.25}).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = [
    "FixedPointFormat",
    "Q16_16",
    "to_fixed",
    "from_fixed",
    "fx_mul",
    "shift_decay",
    "SHIFT_DECAY_RATES",
    "nearest_shift_decay",
]


@dataclasses.dataclass(frozen=True)
class FixedPointFormat:
    """Signed fixed-point format with ``int_bits`` + ``frac_bits`` + sign."""

    int_bits: int = 15
    frac_bits: int = 16

    @property
    def total_bits(self) -> int:
        return self.int_bits + self.frac_bits + 1

    @property
    def scale(self) -> int:
        return 1 << self.frac_bits

    @property
    def max_value(self) -> float:
        return ((1 << (self.int_bits + self.frac_bits)) - 1) / self.scale

    @property
    def min_value(self) -> float:
        return -(1 << self.int_bits)


Q16_16 = FixedPointFormat(15, 16)


def to_fixed(x, fmt: FixedPointFormat = Q16_16, *, saturate: bool = True):
    """Quantize float array to fixed point (int32 raw representation)."""
    x = jnp.asarray(x, jnp.float32)
    scaled = x * fmt.scale
    # Round-to-nearest-even, like a synthesized quantizer with rounding.
    r = jnp.round(scaled)
    if saturate:
        lo = -(1 << (fmt.int_bits + fmt.frac_bits))
        hi = (1 << (fmt.int_bits + fmt.frac_bits)) - 1
        r = jnp.clip(r, lo, hi)
    return r.astype(jnp.int32)


def from_fixed(x, fmt: FixedPointFormat = Q16_16):
    """Dequantize int32 raw fixed point to float32."""
    return jnp.asarray(x, jnp.int32).astype(jnp.float32) / fmt.scale


def fx_mul(a, b, fmt: FixedPointFormat = Q16_16):
    """Fixed-point multiply: floor((a*b) / 2^frac_bits) on raw int32.

    Matches a truncating fixed-point multiplier as used by Cerebra-S's
    potential-decay unit. Implemented as a hi/lo split multiply so it is
    exact without int64 (JAX x64 is off; TPU VPU has no int64) — this is
    also how the synthesized multiplier decomposes:

        a = a_hi * 2^16 + a_lo   (a_hi = a >> 16 arithmetic, 0<=a_lo<2^16)
        floor(a*b / 2^16) = a_hi*b + floor(a_lo*b / 2^16)

    Requires ``fmt.frac_bits == 16`` and ``0 <= b <= 2^16`` (a decay/retain
    factor in [0, 1]; b = 2^16 reduces to the exact identity
    a_hi*2^16 + a_lo == a, so beta = 1.0 needs no special casing).
    """
    if fmt.frac_bits != 16:
        raise ValueError("fx_mul split-multiply assumes Q*.16")
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    a_hi = a >> 16                                  # arithmetic shift
    a_lo = jnp.bitwise_and(a, 0xFFFF).astype(jnp.uint32)
    lo_prod = (a_lo * b.astype(jnp.uint32)) >> 16   # exact in uint32
    return (a_hi * b + lo_prod.astype(jnp.int32)).astype(jnp.int32)


# Cerebra-H supports these decay *rates* (fraction removed per timestep)
# via arithmetic right-shift compositions. retain = 1 - rate.
#   rate 0.125 -> V - (V >> 3)            (retain 0.875)
#   rate 0.25  -> V - (V >> 2)            (retain 0.75)
#   rate 0.5   -> V - (V >> 1)            (retain 0.5)
#   rate 0.75  -> (V >> 2)                (retain 0.25)
SHIFT_DECAY_RATES: tuple[float, ...] = (0.125, 0.25, 0.5, 0.75)


def _shift(v, k):
    # jnp right_shift on signed ints is arithmetic.
    return v >> k


def shift_decay(v, rate: float):
    """Cerebra-H shift-based decay on raw int32 membrane potentials.

    Deliberately NOT wrapped in jax.jit: it is called from inside jitted
    scan bodies and from inside Pallas kernel bodies (where a nested pjit
    primitive would not lower to Mosaic).
    """
    v = jnp.asarray(v, jnp.int32)
    if rate == 0.125:
        return (v - _shift(v, 3)).astype(jnp.int32)
    if rate == 0.25:
        return (v - _shift(v, 2)).astype(jnp.int32)
    if rate == 0.5:
        return (v - _shift(v, 1)).astype(jnp.int32)
    if rate == 0.75:
        return _shift(v, 2).astype(jnp.int32)
    raise ValueError(f"unsupported shift decay rate {rate}; "
                     f"hardware supports {SHIFT_DECAY_RATES}")


def nearest_shift_decay(rate: float) -> float:
    """Snap an arbitrary decay rate to the nearest hardware-supported one.

    This is the quantization the Cerebra-H deployment compiler performs when
    a software model was trained with an unsupported leak (e.g. beta=0.9 ->
    rate 0.1 -> nearest supported 0.125). It is one of the two sources of
    HW-vs-SW accuracy deviation studied in the paper (the other being weight
    quantization).
    """
    return float(min(SHIFT_DECAY_RATES, key=lambda r: abs(r - rate)))


def quantize_weights(w, fmt: FixedPointFormat = Q16_16):
    """Quantize a float weight matrix to the 32-bit hardware format.

    Returns (raw int32 weights, dequantized float reference).
    """
    raw = to_fixed(w, fmt)
    return raw, from_fixed(raw, fmt)


def np_to_fixed(x: np.ndarray, fmt: FixedPointFormat = Q16_16) -> np.ndarray:
    """Numpy mirror of :func:`to_fixed` (for host-side config compilers)."""
    scaled = np.asarray(x, np.float64) * fmt.scale
    r = np.round(scaled)
    lo = -(1 << (fmt.int_bits + fmt.frac_bits))
    hi = (1 << (fmt.int_bits + fmt.frac_bits)) - 1
    return np.clip(r, lo, hi).astype(np.int32)
