"""Logical SNN description — the object the mapping compiler consumes.

A logical network is hardware-agnostic: ``n_inputs`` external stimulus
sources plus ``n_neurons`` LIF neurons, connected by a dense adjacency
matrix ``W`` of shape (n_inputs + n_neurons, n_neurons): ``W[s, d]`` is the
synaptic weight from source ``s`` (external input if s < n_inputs, else
neuron s - n_inputs) to destination neuron ``d``. Zero entries are absent
synapses — exactly the paper's "neuron placement graph" adjacency-matrix
representation.

Feed-forward classifiers (the paper's MNIST networks) are built with
:func:`feedforward`; arbitrary recurrent graphs (the paper's robotic/PID
use cases) with the constructor directly.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.lif import LIFParams

__all__ = ["SNNetwork", "feedforward"]


@dataclasses.dataclass
class SNNetwork:
    """Logical spiking network.

    Attributes:
      n_inputs: number of external stimulus sources.
      n_neurons: number of LIF neurons.
      weights: (n_inputs + n_neurons, n_neurons) float adjacency matrix.
      params: per-network LIF parameters (the paper configures decay /
        threshold / reset per accelerator deployment; per-neuron overrides
        are carried in ``neuron_params`` when present).
      layer_slices: optional list of (start, end) neuron-index ranges per
        layer — used by the mapping compiler for locality-aware placement
        and by the decoder to find the output population.
      output_slice: (start, end) neuron-index range of the output layer.
    """

    n_inputs: int
    n_neurons: int
    weights: np.ndarray
    params: LIFParams = dataclasses.field(default_factory=LIFParams)
    layer_slices: tuple[tuple[int, int], ...] = ()
    output_slice: tuple[int, int] | None = None

    def __post_init__(self):
        w = np.asarray(self.weights, np.float32)
        expect = (self.n_inputs + self.n_neurons, self.n_neurons)
        if w.shape != expect:
            raise ValueError(f"weights shape {w.shape} != {expect}")
        self.weights = w
        if self.output_slice is None:
            if self.layer_slices:
                self.output_slice = self.layer_slices[-1]
            else:
                self.output_slice = (0, self.n_neurons)

    @property
    def n_sources(self) -> int:
        return self.n_inputs + self.n_neurons

    @property
    def n_synapses(self) -> int:
        return int(np.count_nonzero(self.weights))

    def fanout(self) -> np.ndarray:
        """Per-source count of outgoing synapses (bus events per spike)."""
        return np.count_nonzero(self.weights, axis=1)

    def validate(self) -> None:
        if not np.all(np.isfinite(self.weights)):
            raise ValueError("non-finite synaptic weights")


def feedforward(
    layer_weights: Sequence[np.ndarray],
    params: LIFParams | None = None,
) -> SNNetwork:
    """Build a feed-forward SNN from dense layer weight matrices.

    ``layer_weights[i]`` has shape (fan_in_i, fan_out_i); fan_in of layer 0
    is the external input dimension. Hidden/output neurons are numbered
    contiguously layer by layer — the paper's MNIST nets (784 -> H -> 10)
    are ``feedforward([W1 (784,H), W2 (H,10)])``.
    """
    params = params or LIFParams()
    sizes = [int(w.shape[0]) for w in layer_weights] + [
        int(layer_weights[-1].shape[1])
    ]
    for i, w in enumerate(layer_weights):
        if w.shape != (sizes[i], sizes[i + 1]):
            raise ValueError(
                f"layer {i} weight shape {w.shape} != {(sizes[i], sizes[i+1])}"
            )
    n_inputs = sizes[0]
    n_neurons = int(sum(sizes[1:]))
    W = np.zeros((n_inputs + n_neurons, n_neurons), np.float32)
    layer_slices = []
    dst_off = 0
    src_off = 0  # source index of the presynaptic population
    for i, w in enumerate(layer_weights):
        fan_in, fan_out = w.shape
        dst = slice(dst_off, dst_off + fan_out)
        src = slice(src_off, src_off + fan_in)
        W[src, dst] = np.asarray(w, np.float32)
        layer_slices.append((dst_off, dst_off + fan_out))
        # next layer's sources are this layer's neurons (offset by n_inputs)
        src_off = n_inputs + dst_off
        dst_off += fan_out
    return SNNetwork(
        n_inputs=n_inputs,
        n_neurons=n_neurons,
        weights=W,
        params=params,
        layer_slices=tuple(layer_slices),
        output_slice=layer_slices[-1],
    )
