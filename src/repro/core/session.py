"""AcceleratorSession — the SoC orchestration layer (SpikeCore's role).

The paper's SpikeCore configures the accelerator over the RoCC interface
(8-bit config packets), injects encoded stimulus spikes (11-bit spike
packets), synchronizes timesteps, and reads decoded outputs. This module is
the host-runtime analogue: it owns accelerator state, supports **multi-model
co-residency** (paper §V-D: disjoint cluster subsets + address-space
isolation), and exposes encode -> step -> decode as a closed loop.

Co-residency is implemented exactly as the hardware does it: each deployed
model occupies a contiguous physical cluster range; weights of different
models occupy disjoint SRAM rows; and ``run_all`` advances every resident
model in ONE fused SpikeEngine scan over the shared physical array —
external sources concatenated, one weight image, per-model decoded outputs.
Models sharing a LIF configuration (decay / threshold / reset — the
hardware's global config registers) fuse into a single scan; models with
different configurations form separate fused groups, mirroring the ASIC's
per-configuration register banks. Isolation (a model's outputs are
bit-identical to a solo deployment) is verified by tests/test_session.py.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import cerebra_h, coding
from repro.core.engine import DecaySpec, SpikeEngine
from repro.core.mapping import ClusterGeometry, Placement
from repro.core.network import SNNetwork

__all__ = ["AcceleratorSession", "DeployedModel"]


@dataclasses.dataclass
class DeployedModel:
    name: str
    program: cerebra_h.CerebraHProgram
    cluster_range: tuple[int, int]   # [lo, hi) physical clusters
    input_offset: int                # external-source base address


class AcceleratorSession:
    """Host-side runtime for one Cerebra-H accelerator instance.

    ``backend`` selects the SpikeEngine backend for every inference run on
    this session ("reference" | "pallas" | "pallas-mxu"). ``mesh`` (a
    ``jax.sharding.Mesh`` with ``neuron``/``batch`` axes, see
    ``repro.distributed.spike_mesh.make_spike_mesh``) scales the fused
    paths out over devices: ``run_all`` and the streaming servers behind
    :meth:`serve` step a mesh-sharded engine — neuron shards close to
    their SRAM slice, spike exchange per timestep — with outputs
    bit-identical to the single-device session.
    """

    def __init__(self, config: cerebra_h.CerebraHConfig | None = None,
                 backend: str = "reference", mesh=None,
                 fuse_steps: int = 1, connector=None,
                 metrics=None, tracer=None):
        from repro.serving.connector import InMemoryCarryConnector

        self.config = config or cerebra_h.CerebraHConfig()
        self.backend = backend
        self.mesh = mesh
        # optional telemetry, threaded into every server / frontend /
        # connector this session builds (deploy + redeploy spans recorded
        # here). Purely observational — see repro.obs.
        self.metrics = metrics
        self.tracer = tracer
        # the session's stream-state connector: rolling-redeploy drain
        # parks in-flight carries here (and spill-enabled frontends share
        # it); file-backed connectors survive the process.
        self.connector = (connector if connector is not None
                          else InMemoryCarryConnector())
        if (metrics is not None or tracer is not None) and hasattr(
                self.connector, "instrument"):
            self.connector.instrument(metrics, tracer)
        # {lif signature: [(uid, connector key | None), ...]} — streams
        # parked by deploy(), FIFO restore order, consumed by serve().
        # A None key is a stream that was still waiting for a slot (no
        # carry exists yet; it is simply re-queued).
        self._parked_groups: dict = {}
        # K timesteps per fused kernel window for every engine this
        # session builds (1 = single-step kernels); outputs are
        # byte-identical for any K, only weight traffic changes.
        self.fuse_steps = int(fuse_steps)
        self.models: dict[str, DeployedModel] = {}
        self._next_cluster = 0
        self._next_input = 0
        # fused-engine cache: {(model names, lif signature): SpikeEngine};
        # invalidated whenever the resident set changes.
        self._fused_engines: dict = {}
        # streaming-server cache: {(group names, sig, slots, chunk):
        # SpikeServer} — co-resident models with a shared LIF config
        # stream through ONE server (and one compiled step).
        self._stream_servers: dict = {}
        # async front doors, keyed like the servers they queue for: all
        # views over one server submit into ONE bounded request queue.
        self._frontends: dict = {}
        # bumped on every deploy; outstanding ModelStream views check it
        # so a stale view fails loudly instead of streaming against a
        # pre-deploy fused layout.
        self._serve_epoch = 0

    # ------------------------------------------------------------------
    @property
    def geometry(self) -> ClusterGeometry:
        return self.config.geometry

    def free_clusters(self) -> int:
        return self.geometry.n_clusters - self._next_cluster

    def deploy(self, name: str, net: SNNetwork) -> DeployedModel:
        """Deploy a model into the next free cluster range (config path).

        A ROLLING redeploy when streams are in flight: every live stream
        of every cached server is drained to the session connector first
        (:meth:`_drain_streams`), and the next :meth:`serve` of its LIF
        group restores it into the new fused server — the stream's raster
        continues byte-identically across the deploy."""
        if name in self.models:
            raise ValueError(f"model {name!r} already deployed")
        geom = self.geometry
        npc = geom.neurons_per_cluster
        need = -(-net.n_neurons // npc)  # ceil clusters
        # co-residency isolation: round up to a group boundary so no two
        # models share a weight SRAM (address-space isolation).
        cpg = geom.clusters_per_group
        need = -(-need // cpg) * cpg
        if need > self.free_clusters():
            raise ValueError(
                f"model {name!r} needs {need} clusters; only "
                f"{self.free_clusters()} free"
            )
        lo = self._next_cluster
        base_slot = lo * npc
        placement = Placement(
            geom, base_slot + np.arange(net.n_neurons)
        )
        program = cerebra_h.compile_network(net, self.config, placement)
        model = DeployedModel(
            name=name,
            program=program,
            cluster_range=(lo, lo + need),
            input_offset=self._next_input,
        )
        self.models[name] = model
        self._next_cluster += need
        self._next_input += net.n_inputs
        parked = self._drain_streams()  # park in-flight carries first —
        self._fused_engines.clear()   # resident set changed
        self._stream_servers.clear()  # fused layout changed with it
        self._frontends.clear()       # queues die with their servers
        self._serve_epoch += 1        # invalidate outstanding stream views
        if self.metrics is not None:
            self.metrics.counter("snn_session_deploys_total").inc()
            if parked:
                self.metrics.counter("snn_session_redeploys_total").inc()
        if self.tracer is not None:
            self.tracer.event("deploy", name, models=len(self.models),
                              parked_streams=parked)
        return model

    def _drain_streams(self) -> int:
        """Rolling-redeploy drain: park every in-flight stream of every
        cached server in the session connector, so :meth:`deploy` migrates
        live traffic instead of dropping it. The next :meth:`serve` of the
        same LIF group restores the parked streams — FIFO, what fits the
        new server's slots — and their rasters continue byte-identically:
        the physical array size is fixed across deploys, existing models
        keep their cluster ranges and input offsets, and a freshly
        deployed model's clusters stay silent for other streams (the
        co-residency isolation ``run_all`` is pinned on). Returns the
        number of carries parked."""
        parked = 0
        for key, server in self._stream_servers.items():
            sig = key[1]
            group = self._parked_groups.setdefault(sig, [])
            epoch = self._serve_epoch
            # admitted streams first (dict order = admission order), so
            # FIFO restore preserves the pre-deploy service order
            for uid in server.scheduler.active:
                ckey = ("deploy", epoch, sig, uid)
                self.connector.insert(ckey, server.snapshot_stream(uid))
                group.append((uid, ckey))
                parked += 1
                if self.tracer is not None:
                    self.tracer.event("redeployed", uid, epoch=epoch)
            for uid in server.scheduler.waiting:
                group.append((uid, None))
        return parked

    # ------------------------------------------------------------------
    def run(self, name: str, intensities, num_steps: int, key) -> dict:
        """Encode -> infer -> decode for one resident model.

        intensities: (B, n_inputs) in [0,1]. Returns cerebra_h.run() result
        plus 'predictions'.
        """
        model = self.models[name]
        spikes = coding.poisson_encode(key, intensities, num_steps,
                                       dtype=jnp.int32)
        result = cerebra_h.run(model.program, spikes, backend=self.backend)
        result["predictions"] = jnp.argmax(result["output_counts"], axis=-1)
        return result

    # ------------------------------------------------------------------
    @staticmethod
    def _lif_signature(program: cerebra_h.CerebraHProgram):
        """The global accelerator config a fused step must share."""
        return (program.decay_rate, program.params.threshold_raw,
                program.params.reset_mode)

    def _fused_engine(self, members: list[DeployedModel]) -> SpikeEngine:
        """One physical-array engine over the union of members' programs.

        External sources are concatenated in deployment order; the
        neuron-to-neuron rows of all members are summed — disjoint cluster
        ranges guarantee the nonzero patterns cannot overlap, so the sum
        IS the union SRAM image the hardware holds.
        """
        sig = self._lif_signature(members[0].program)
        key = (tuple(m.name for m in members), sig, self.backend, self.mesh,
               self.fuse_steps)
        engine = self._fused_engines.get(key)
        if engine is not None:
            return engine
        n_phys = self.geometry.n_physical
        n_ext = sum(m.program.n_inputs for m in members)
        W = jnp.zeros((n_ext + n_phys, n_phys), jnp.int32)
        off = 0
        for m in members:
            flat = m.program.weights_raw.reshape(
                m.program.n_sources, -1)  # (n_in_m + P, P)
            n_in = m.program.n_inputs
            W = W.at[off:off + n_in].set(flat[:n_in])
            W = W.at[n_ext:].add(flat[n_in:])
            off += n_in
        decay_rate, threshold_raw, reset_mode = sig
        engine = SpikeEngine(
            W,
            n_ext,
            decay=DecaySpec.shift(decay_rate),
            threshold_raw=threshold_raw,
            reset_mode=reset_mode,
            backend=self.backend,
            fuse_steps=self.fuse_steps,
        )
        if self.mesh is not None:
            engine = engine.to_mesh(self.mesh)
        self._fused_engines[key] = engine
        return engine

    def run_all(self, inputs: dict, num_steps: int, key) -> dict:
        """Advance every resident model concurrently (shared array step).

        inputs: {name: (B, n_inputs) intensities}; all batches must match.
        Functionally each model is independent (disjoint clusters/rows);
        we exploit that to fuse them into one physical-array SpikeEngine
        scan per LIF configuration — the same way the hardware timestep
        advances all clusters at once. Each model is encoded with the SAME
        key it would get from :meth:`run`, and its decoded outputs (and
        cost-model accounting) are bit-identical to a solo deployment.
        """
        members = [self.models[name] for name in inputs]
        batches = {np.shape(inputs[m.name])[0] for m in members}
        if len(batches) > 1:
            raise ValueError(f"batch sizes differ across models: {batches}")

        # encode per model with the same key run() uses -> solo-identical
        ext = {
            m.name: coding.poisson_encode(
                key, inputs[m.name], num_steps, dtype=jnp.int32)
            for m in members
        }

        # group by shared accelerator configuration (hardware config regs)
        groups: dict = {}
        for m in members:
            groups.setdefault(self._lif_signature(m.program), []).append(m)

        npc = self.geometry.neurons_per_cluster
        results: dict = {}
        for group in groups.values():
            engine = self._fused_engine(group)
            fused_ext = jnp.concatenate([ext[m.name] for m in group], axis=-1)
            raster = engine.run(fused_ext)["spikes"]  # (T, B, P) one scan
            for m in group:
                lo, hi = m.cluster_range
                # mask to the model's cluster range: bit-identical to the
                # raster a solo deployment produces (other slots silent)
                mask = jnp.zeros((raster.shape[-1],), jnp.int32)
                mask = mask.at[lo * npc:hi * npc].set(1)
                spikes = raster * mask[None, None, :]
                prog = m.program
                cost = cerebra_h.cost_model(prog, ext[m.name], spikes)
                out_counts = jnp.sum(
                    spikes[:, :, jnp.asarray(prog.output_map)], axis=0)
                results[m.name] = {
                    "spikes": spikes,
                    "output_counts": out_counts,
                    "cycles": cost["cycles"],
                    "sops": cost["sops"],
                    "row_fetches": cost["row_fetches"],
                    "predictions": jnp.argmax(out_counts, axis=-1),
                }
        return results

    # ------------------------------------------------------------------
    def serve(self, name: str, *, n_slots: int = 4, chunk_steps: int = 8,
              gate: str | None = None, frontend=None):
        """Streaming entry: a :class:`~repro.serving.snn.ModelStream` view
        for one resident model.

        All resident models sharing ``name``'s LIF configuration stream
        through ONE fused-engine :class:`~repro.serving.snn.SpikeServer`
        (the same union SRAM image ``run_all`` scans), so co-resident
        models' streams share slots of one compiled step. Repeated
        ``serve`` calls reuse the cached server — views over the same
        group see (and compete for) the same slots, exactly like
        co-resident workloads on the physical array.

        ``gate`` selects the event-gate granularity of the server's
        engine (``"per-example"`` is the batch-tile=1 serving mode, where
        idle slots skip their own weight traffic); outputs are
        bit-identical under any gate.

        ``frontend`` (a :class:`~repro.serving.frontend.FrontendConfig`)
        makes the returned view async-capable: ONE
        :class:`~repro.serving.frontend.AsyncSpikeFrontend` is hung off
        the group's shared server (co-resident views share its bounded
        request queue like they share slots), and the view grows
        ``submit``/``submit_events`` that enqueue model-local rasters
        against it. The frontend changes only WHEN work runs — async
        outputs stay byte-identical to synchronous ``feed``. Views served
        later without ``frontend=`` still see the group's existing
        frontend; a conflicting config raises.

        A later :meth:`deploy` changes the fused layout and invalidates
        outstanding views: using one afterwards raises (epoch check);
        call ``serve`` again after deploying. In-flight streams are NOT
        lost: deploy parks their carries in the session connector and the
        re-``serve`` restores them (byte-identical continuation).
        """
        from repro.serving.frontend import AsyncSpikeFrontend
        from repro.serving.snn import ModelStream, SpikeServer

        model = self.models[name]
        sig = self._lif_signature(model.program)
        group = [m for m in self.models.values()
                 if self._lif_signature(m.program) == sig]
        group_key = (tuple(m.name for m in group), sig, self.backend,
                     self.fuse_steps)
        # normalize gate=None to the engine's effective gate so a default
        # serve and an explicit-default serve alias to ONE server key
        gate = gate if gate is not None else self._fused_engine(group).gate
        key = group_key + (int(n_slots), int(chunk_steps), gate)
        server = self._stream_servers.get(key)
        if server is None:
            # one server per group: mismatched slot parameters would
            # silently split co-resident streams into independent carries
            for other in self._stream_servers:
                if other[: len(group_key)] == group_key:
                    n_slots_o, chunk_o, gate_o = other[len(group_key):]
                    raise ValueError(
                        f"group {group_key[0]} is already served with "
                        f"n_slots={n_slots_o}, chunk_steps={chunk_o}, "
                        f"gate={gate_o}; co-resident views must share "
                        f"one server"
                    )
            server = SpikeServer(self._fused_engine(group),
                                 n_slots=n_slots, chunk_steps=chunk_steps,
                                 gate=gate, metrics=self.metrics,
                                 tracer=self.tracer)
            self._stream_servers[key] = server
            self._restore_parked(sig, server)
        fe = self._frontends.get(key)
        if frontend is not None:
            cfg = frontend
            if fe is None:
                fe = AsyncSpikeFrontend(
                    server, queue_capacity=cfg.queue_capacity,
                    backpressure=cfg.backpressure,
                    deadline_ms=cfg.deadline_ms,
                    connector=(self.connector
                               if cfg.spill or (cfg.qos is not None
                                                and cfg.qos.preempt)
                               else None),
                    metrics=self.metrics, tracer=self.tracer,
                    slo=cfg.slo, qos=cfg.qos)
                self._frontends[key] = fe
            elif (fe.queue_capacity, fe.backpressure,
                  fe.default_deadline_ms, fe.qos,
                  fe.connector is not None) != (
                      cfg.queue_capacity, cfg.backpressure,
                      cfg.deadline_ms, cfg.qos,
                      cfg.spill or (cfg.qos is not None
                                    and cfg.qos.preempt)):
                raise ValueError(
                    f"group {group_key[0]} already has a frontend with "
                    f"queue_capacity={fe.queue_capacity}, "
                    f"backpressure={fe.backpressure!r}, "
                    f"deadline_ms={fe.default_deadline_ms}, "
                    f"spill={fe.connector is not None}, "
                    f"qos={fe.qos}; co-resident "
                    f"views must share one request queue")
        ext_offset = 0
        for m in group:
            if m.name == name:
                break
            ext_offset += m.program.n_inputs
        npc = self.geometry.neurons_per_cluster
        lo, hi = model.cluster_range
        epoch = self._serve_epoch
        return ModelStream(
            server,
            name=name,
            n_inputs=model.program.n_inputs,
            ext_offset=ext_offset,
            phys_slice=(lo * npc, hi * npc),
            output_map=model.program.output_map,
            stale_check=lambda: self._serve_epoch != epoch,
            frontend=fe,
        )

    def _restore_parked(self, sig, server) -> list:
        """Restore streams :meth:`_drain_streams` parked for this LIF
        group into the (new) server: FIFO, carries first-class via
        ``attach_stream``, still-waiting uids simply re-queued. Restores
        what fits the server's free slots; the rest stay parked for a
        later ``serve`` (or manual ``attach_stream``). Returns restored
        uids."""
        parked = self._parked_groups.pop(sig, [])
        restored, leftovers = [], []
        for uid, ckey in parked:
            if ckey is None:
                server.attach(uid)
                restored.append(uid)
            elif server.scheduler.free_slots > 0:
                snap = self.connector.select(ckey)
                server.attach_stream(snap, uid=uid)
                self.connector.evict(ckey)
                restored.append(uid)
            else:
                leftovers.append((uid, ckey))
        if leftovers:
            self._parked_groups[sig] = leftovers
        return restored

    def utilization(self) -> dict:
        geom = self.geometry
        used_neurons = sum(
            m.program.n_neurons for m in self.models.values()
        )
        used_rows = sum(
            int(np.sum(m.program.capacity_report["rows_per_group"]))
            for m in self.models.values()
        )
        return {
            "clusters_used": self._next_cluster,
            "clusters_total": geom.n_clusters,
            "neuron_utilization": used_neurons / geom.n_physical,
            "row_utilization": used_rows
            / (geom.n_groups * geom.rows_per_group),
            "models": list(self.models),
        }
