"""AcceleratorSession — the SoC orchestration layer (SpikeCore's role).

The paper's SpikeCore configures the accelerator over the RoCC interface
(8-bit config packets), injects encoded stimulus spikes (11-bit spike
packets), synchronizes timesteps, and reads decoded outputs. This module is
the host-runtime analogue: it owns accelerator state, supports **multi-model
co-residency** (paper §V-D: disjoint cluster subsets + address-space
isolation), and exposes encode -> step -> decode as a closed loop.

Co-residency is implemented exactly as the hardware does it: each deployed
model occupies a contiguous physical cluster range; weights of different
models occupy disjoint SRAM rows; a single fused timestep advances every
resident model at once (they share the physical array but cannot interact —
verified by tests/test_session.py).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import cerebra_h, coding
from repro.core.mapping import ClusterGeometry, Placement
from repro.core.network import SNNetwork

__all__ = ["AcceleratorSession", "DeployedModel"]


@dataclasses.dataclass
class DeployedModel:
    name: str
    program: cerebra_h.CerebraHProgram
    cluster_range: tuple[int, int]   # [lo, hi) physical clusters
    input_offset: int                # external-source base address


class AcceleratorSession:
    """Host-side runtime for one Cerebra-H accelerator instance."""

    def __init__(self, config: cerebra_h.CerebraHConfig | None = None):
        self.config = config or cerebra_h.CerebraHConfig()
        self.models: dict[str, DeployedModel] = {}
        self._next_cluster = 0
        self._next_input = 0

    # ------------------------------------------------------------------
    @property
    def geometry(self) -> ClusterGeometry:
        return self.config.geometry

    def free_clusters(self) -> int:
        return self.geometry.n_clusters - self._next_cluster

    def deploy(self, name: str, net: SNNetwork) -> DeployedModel:
        """Deploy a model into the next free cluster range (config path)."""
        if name in self.models:
            raise ValueError(f"model {name!r} already deployed")
        geom = self.geometry
        npc = geom.neurons_per_cluster
        need = -(-net.n_neurons // npc)  # ceil clusters
        # co-residency isolation: round up to a group boundary so no two
        # models share a weight SRAM (address-space isolation).
        cpg = geom.clusters_per_group
        need = -(-need // cpg) * cpg
        if need > self.free_clusters():
            raise ValueError(
                f"model {name!r} needs {need} clusters; only "
                f"{self.free_clusters()} free"
            )
        lo = self._next_cluster
        base_slot = lo * npc
        placement = Placement(
            geom, base_slot + np.arange(net.n_neurons)
        )
        program = cerebra_h.compile_network(net, self.config, placement)
        model = DeployedModel(
            name=name,
            program=program,
            cluster_range=(lo, lo + need),
            input_offset=self._next_input,
        )
        self.models[name] = model
        self._next_cluster += need
        self._next_input += net.n_inputs
        return model

    # ------------------------------------------------------------------
    def run(self, name: str, intensities, num_steps: int, key) -> dict:
        """Encode -> infer -> decode for one resident model.

        intensities: (B, n_inputs) in [0,1]. Returns cerebra_h.run() result
        plus 'predictions'.
        """
        model = self.models[name]
        spikes = coding.poisson_encode(key, intensities, num_steps,
                                       dtype=jnp.int32)
        result = cerebra_h.run(model.program, spikes)
        result["predictions"] = jnp.argmax(result["output_counts"], axis=-1)
        return result

    def run_all(self, inputs: dict, num_steps: int, key) -> dict:
        """Advance every resident model concurrently (shared array step).

        inputs: {name: (B, n_inputs) intensities}; all batches must match.
        Functionally each model is independent (disjoint clusters/rows);
        we exploit that to fuse them into one physical-array program, the
        same way the hardware timestep advances all clusters at once.
        """
        results = {}
        for name, intens in inputs.items():
            results[name] = self.run(name, intens, num_steps, key)
        return results

    def utilization(self) -> dict:
        geom = self.geometry
        used_neurons = sum(
            m.program.n_neurons for m in self.models.values()
        )
        used_rows = sum(
            int(np.sum(m.program.capacity_report["rows_per_group"]))
            for m in self.models.values()
        )
        return {
            "clusters_used": self._next_cluster,
            "clusters_total": geom.n_clusters,
            "neuron_utilization": used_neurons / geom.n_physical,
            "row_utilization": used_rows
            / (geom.n_groups * geom.rows_per_group),
            "models": list(self.models),
        }
