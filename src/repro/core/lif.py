"""Leaky Integrate-and-Fire neuron models.

Two parallel implementations, mirroring the paper's evaluation methodology:

* :func:`lif_step_float` — the *software reference* (float32, arbitrary
  decay beta, soft or hard reset). This plays the role of the paper's
  PyTorch/snnTorch reference models.
* :func:`lif_step_fixed` — the *hardware model* (bit-exact int32 Q16.16,
  shift-based decay restricted to the four hardware rates, three reset
  modes). This plays the role of the RTL simulation.

Both are pure functions over explicit state so they compose with
``jax.lax.scan`` over timesteps and with ``vmap``/``pjit`` over batch and
population axes.

Hardware semantics (paper §IV-B, §V-A):
  - Accumulator integrates incoming weighted events over a timestep.
  - Potential Decay Unit decays the *previous* membrane potential.
  - Potential Adder combines decayed potential + accumulated input, compares
    against threshold, emits spike, applies reset mode:
      * ``hold``        — membrane unchanged on spike,
      * ``zero``        — reset to 0,
      * ``subtract``    — subtract threshold.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import fixedpoint as fxp

__all__ = [
    "LIFParams",
    "LIFState",
    "lif_init",
    "fire_reset",
    "lif_step_float",
    "lif_step_fixed",
    "surrogate_spike",
]

ResetMode = Literal["hold", "zero", "subtract"]
RESET_MODES: tuple[str, ...] = ("hold", "zero", "subtract")


@dataclasses.dataclass(frozen=True)
class LIFParams:
    """Static LIF configuration (compile-time constants for the kernels)."""

    decay_rate: float = 0.25          # fraction of potential removed / step
    threshold: float = 1.0
    reset_mode: ResetMode = "zero"
    fmt: fxp.FixedPointFormat = fxp.Q16_16

    @property
    def beta(self) -> float:
        """Retain factor (snnTorch convention)."""
        return 1.0 - self.decay_rate

    @property
    def threshold_raw(self) -> int:
        return int(round(self.threshold * self.fmt.scale))


class LIFState:
    """Namespace marker; state is a plain dict pytree: {'v': array}."""


def lif_init(shape, *, fixed: bool = False):
    dtype = jnp.int32 if fixed else jnp.float32
    return {"v": jnp.zeros(shape, dtype)}


def fire_reset(v_new, threshold, reset_mode: str):
    """The hardware Potential-Adder epilogue: threshold compare + reset.

    This is THE single definition of fire/reset semantics. Every datapath
    (float software reference, int32 hardware model, the SpikeEngine
    backends, and the Pallas kernel bodies) calls this function, so the
    three reset modes can never drift apart between implementations.

    Args:
      v_new: (..., N) decayed-and-integrated membrane potential; float32
        for the software path, int32 raw fixed point for the hardware path.
      threshold: scalar of matching dtype (float threshold or raw Q-format
        int32 threshold).
    Returns:
      (v_out, spikes) with spikes in {0,1} of ``v_new``'s dtype.
    """
    spikes = (v_new >= threshold).astype(v_new.dtype)
    if reset_mode == "zero":
        v_out = jnp.where(spikes > 0, jnp.zeros_like(v_new), v_new)
    elif reset_mode == "subtract":
        v_out = v_new - spikes * threshold
    elif reset_mode == "hold":
        v_out = v_new
    else:
        raise ValueError(f"unknown reset mode {reset_mode!r}; "
                         f"expected one of {RESET_MODES}")
    return v_out, spikes


def lif_step_float(state, syn_input, params: LIFParams):
    """Software-reference LIF step (float32).

    Args:
      state: {'v': (..., N) float32} membrane potential from prev step.
      syn_input: (..., N) float32 accumulated synaptic current this step.
      params: LIFParams.
    Returns:
      (new_state, spikes float32 in {0,1})
    """
    v = state["v"]
    v_decayed = v * params.beta
    v_new = v_decayed + syn_input
    v_out, spikes = fire_reset(v_new, jnp.float32(params.threshold),
                               params.reset_mode)
    return {"v": v_out}, spikes


def lif_step_fixed(state, syn_input_raw, params: LIFParams):
    """Hardware-model LIF step (bit-exact int32, shift decay).

    Args:
      state: {'v': (..., N) int32 raw fixed point}.
      syn_input_raw: (..., N) int32 raw accumulated weights (the
        accumulator-unit output for this timestep).
      params: LIFParams. ``decay_rate`` must be one of the hardware rates.
    Returns:
      (new_state, spikes int32 in {0,1})
    """
    v = state["v"]
    v_decayed = fxp.shift_decay(v, params.decay_rate)
    # Hardware adders wrap; jnp int32 add wraps too.
    v_new = v_decayed + syn_input_raw
    v_out, spikes = fire_reset(v_new, jnp.int32(params.threshold_raw),
                               params.reset_mode)
    return {"v": v_out}, spikes


# --------------------------------------------------------------------------
# Surrogate gradient (training substrate; paper trains offline in snnTorch —
# we train offline in JAX with the fast-sigmoid surrogate of Zenke & Ganguli)
# --------------------------------------------------------------------------

@jax.custom_vjp
def surrogate_spike(v_minus_thr, slope: float = 25.0):
    """Heaviside spike with fast-sigmoid surrogate gradient."""
    del slope
    return (v_minus_thr >= 0.0).astype(jnp.float32)


def _surrogate_fwd(v_minus_thr, slope=25.0):
    return surrogate_spike(v_minus_thr, slope), (v_minus_thr, slope)


def _surrogate_bwd(res, g):
    v, slope = res
    denom = (1.0 + slope * jnp.abs(v)) ** 2
    return (g / denom, None)


surrogate_spike.defvjp(_surrogate_fwd, _surrogate_bwd)


def lif_step_train(state, syn_input, params: LIFParams, slope: float = 25.0):
    """Differentiable LIF step used for BPTT surrogate-gradient training."""
    v = state["v"]
    v_new = v * params.beta + syn_input
    spikes = surrogate_spike(v_new - params.threshold, slope)
    if params.reset_mode == "zero":
        # straight-through on reset: detach the reset gate
        gate = jax.lax.stop_gradient(spikes)
        v_out = v_new * (1.0 - gate)
    elif params.reset_mode == "subtract":
        v_out = v_new - jax.lax.stop_gradient(spikes) * params.threshold
    else:  # hold
        v_out = v_new
    return {"v": v_out}, spikes
