"""Cerebra-H — the clustered, hierarchical-NoC accelerator (paper §V).

Functional model (bit-exact int32) + cycle cost model + energy hooks.

Hardware semantics modeled:
  * 32 clusters x 32 neurons; cluster groups of 4 share a single-port
    weight SRAM (2048 rows x 1024 b). The Weight Resolver arbitrates four
    per-cluster request queues at one grant per cycle.
  * Incoming Forwarder looks up (src cluster-ID, src neuron-ID) -> row
    address, fetches the 32-wide weight row and delivers weights to its
    cluster's neurons.
  * Neurons: accumulator + SHIFT-based decay (rates {.125,.25,.5,.75}) +
    configurable reset (hold / zero / subtract).
  * Two-layer NoC: L1 router per 4 clusters, central L2 over 8 L1s; spike
    path is pipelined/buffered, config path is bufferless.
  * Multi-model co-residency via disjoint cluster subsets.

TPU adaptation: the blocked weight layout (source, dst_cluster, 32) is the
SRAM row structure; the functional timestep runs on the shared
:class:`~repro.core.engine.SpikeEngine` — whose ``"pallas"`` backend is the
event-gated kernel in ``repro.kernels.spike_timestep`` (cluster-gated block
skipping ON the inference path) and whose ``"reference"`` backend is the
pure-jnp blocked matmul. This module contributes the compile step and the
cycle/energy cost model, applied as a pure pass over the spike raster.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp
from repro.core.engine import DecaySpec, SpikeEngine, sources_raster
from repro.core.lif import LIFParams
from repro.core.mapping import (
    ClusterGeometry,
    Placement,
    check_capacity,
    communication_profile,
    place_contiguous,
)
from repro.core.network import SNNetwork

__all__ = [
    "CerebraHConfig",
    "CerebraHProgram",
    "compile_network",
    "make_engine",
    "cost_model",
    "run",
]

MAX_FREQ_MHZ = 96.24  # paper §VII-B: Cerebra-H critical path 10.3904 ns


@dataclasses.dataclass(frozen=True)
class CerebraHConfig:
    geometry: ClusterGeometry = dataclasses.field(default_factory=ClusterGeometry)
    fmt: fxp.FixedPointFormat = fxp.Q16_16
    row_mode: str = "external_broadcast"
    # NoC micro-timing (paper Table II + §V-D): spike path is pipelined —
    # throughput 1 packet/cycle/link after `spike_pipeline_depth` cycles.
    spike_pipeline_depth: int = 2
    l2_hop_cycles: int = 2
    sync_overhead_cycles: int = 4  # timestep-boundary completion handshake


@dataclasses.dataclass
class CerebraHProgram:
    config: CerebraHConfig
    params: LIFParams
    placement: Placement
    n_inputs: int
    n_neurons: int
    # blocked SRAM image: (n_sources, n_clusters, neurons_per_cluster) int32
    weights_raw: jnp.ndarray
    # row incidence: (n_sources, n_clusters) bool — a row exists for this
    # (source, dst cluster) pair (drives resolver cost + gated kernel)
    row_exists: np.ndarray
    # per-source nonzero synapse count (SOPs per spike of that source)
    fanout: np.ndarray
    output_map: np.ndarray        # physical slots of output neurons, ordered
    decay_rate: float             # snapped to hardware-supported rate
    capacity_report: dict
    comm_profile: dict
    # per-program engine cache: one compiled scan per backend
    _engines: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def n_sources(self) -> int:
        return self.n_inputs + self.config.geometry.n_physical


def compile_network(
    net: SNNetwork,
    config: CerebraHConfig | None = None,
    placement: Placement | None = None,
) -> CerebraHProgram:
    """Place, check capacity, quantize and block a logical network."""
    config = config or CerebraHConfig()
    geom = config.geometry
    net.validate()
    placement = placement or place_contiguous(net, geom)
    capacity = check_capacity(net, placement, config.row_mode)
    comm = communication_profile(net, placement)

    n_phys = geom.n_physical
    n_in = net.n_inputs
    # scatter logical weights into the physical array layout
    W = np.zeros((n_in + n_phys, n_phys), np.float32)
    phys = placement.neuron_to_physical
    W[:n_in, phys] = net.weights[:n_in]
    # neuron-to-neuron: source neuron i lives at phys[i]
    W[n_in + phys[:, None], phys[None, :]] = net.weights[n_in:]
    w_raw = fxp.np_to_fixed(W, config.fmt)
    blocked = w_raw.reshape(
        n_in + n_phys, geom.n_clusters, geom.neurons_per_cluster
    )
    row_exists = (blocked != 0).any(axis=-1)

    # deployment-time snapping of the trained decay to a hardware rate —
    # one of the two quantization effects the accuracy study measures.
    decay_rate = fxp.nearest_shift_decay(net.params.decay_rate)

    lo, hi = net.output_slice
    return CerebraHProgram(
        config=config,
        params=net.params,
        placement=placement,
        n_inputs=n_in,
        n_neurons=net.n_neurons,
        weights_raw=jnp.asarray(blocked),
        row_exists=np.asarray(row_exists),
        fanout=np.count_nonzero(W, axis=1),
        output_map=phys[lo:hi],
        decay_rate=decay_rate,
        capacity_report=capacity,
        comm_profile=comm,
    )


def make_engine(program: CerebraHProgram,
                backend: str = "reference") -> SpikeEngine:
    """The program's SpikeEngine for ``backend`` (built once, then cached).

    The blocked SRAM image (S, C, n) flattens to the engine's (S, P) weight
    matrix; the H generation decays with the arithmetic-shift PDU.
    """
    engine = program._engines.get(backend)
    if engine is None:
        Wb = program.weights_raw
        engine = SpikeEngine(
            Wb.reshape(Wb.shape[0], -1),
            program.n_inputs,
            decay=DecaySpec.shift(program.decay_rate),
            threshold_raw=program.params.threshold_raw,
            reset_mode=program.params.reset_mode,
            backend=backend,
        )
        program._engines[backend] = engine
    return engine


def cost_model(program: CerebraHProgram, ext_spikes, spikes) -> dict:
    """Pure cycle/SOP/row-fetch accounting from a spike raster.

    Mirrors the hardware, as a vectorized pass over all T steps at once:

    * Weight Resolver: every spiking source requests one SRAM row per
      destination cluster it connects to; the single-port SRAM serves one
      row/cycle per group (arbitration), groups run in parallel.
    * NoC spike path: each spiking neuron emits one packet per destination
      cluster (Outgoing Encoder serializes one per cycle); L1 routers run
      in parallel; crossing L2 adds hop latency. Packets of step t come
      from the previous timestep boundary.

    Args:
      ext_spikes: (T, B, n_inputs) external stimulus in {0,1}.
      spikes: (T, B, n_physical) raster produced by the engine.
    Returns:
      {'cycles', 'sops', 'row_fetches'}: each (T, B) int32.
    """
    cfg = program.config
    geom = cfg.geometry
    sources = sources_raster(ext_spikes, spikes)  # (T, B, S)
    T, B = sources.shape[0], sources.shape[1]

    row_exists = jnp.asarray(program.row_exists, jnp.int32)  # (S, C)
    rows_active = jnp.einsum(
        "tbs,sc->tbc", sources, row_exists,
        preferred_element_type=jnp.int32,
    )  # (T, B, C) row fetches destined to each cluster
    rows_per_group = rows_active.reshape(
        T, B, geom.n_groups, geom.clusters_per_group
    ).sum(-1)
    group_cycles = rows_per_group.max(axis=-1)  # (T, B) parallel groups

    neuron_rows = row_exists[program.n_inputs:]  # (P, C)
    pkt_per_neuron = neuron_rows.sum(-1)  # (P,) packets a spike generates
    prev = sources[:, :, program.n_inputs:]  # spikes of the prev boundary
    pkts_by_cluster = (
        (prev * pkt_per_neuron[None, None, :])
        .reshape(T, B, geom.n_clusters, geom.neurons_per_cluster)
        .sum(-1)
    )  # (T, B, C)
    l1_cycles = pkts_by_cluster.reshape(
        T, B, geom.n_l1_routers, geom.clusters_per_l1
    ).sum(-1).max(-1)  # serialize per L1 router, routers in parallel
    noc_cycles = l1_cycles + cfg.spike_pipeline_depth + cfg.l2_hop_cycles

    cycles = jnp.maximum(group_cycles, noc_cycles) + cfg.sync_overhead_cycles
    fanout = jnp.asarray(program.fanout, jnp.int32)
    sops = jnp.sum(sources * fanout[None, None, :], axis=-1)
    row_fetches = rows_active.sum(-1)  # (T, B) SRAM row reads per step
    return {"cycles": cycles, "sops": sops, "row_fetches": row_fetches}


def run(program: CerebraHProgram, ext_spikes, backend: str = "reference"):
    """Run inference. ext_spikes: (T, B, n_inputs) in {0,1}.

    ``backend`` selects the SpikeEngine backend ("reference" | "pallas" |
    "pallas-mxu"); all are bit-exact (the mxu bound is checked at engine
    build). Returns dict with spike raster (physical layout), logical
    output counts, and per-step cycles / SOPs / SRAM row fetches.
    """
    engine = make_engine(program, backend)
    out = engine.run(ext_spikes)
    spikes = out["spikes"]
    cost = cost_model(program, ext_spikes, spikes)
    out_counts = jnp.sum(spikes[:, :, jnp.asarray(program.output_map)], axis=0)
    return {
        "spikes": spikes,
        "output_counts": out_counts,
        "cycles": cost["cycles"],
        "sops": cost["sops"],
        "row_fetches": cost["row_fetches"],
    }
