"""repro.core — the paper's contribution: Cerebra accelerators in JAX.

Public surface:
  fixedpoint   — Q16.16 emulation, shift decay
  engine       — SpikeEngine: the one timestep core (scan + carries +
                 backend dispatch: reference / pallas / pallas-mxu)
  lif          — LIF neuron (float reference / fixed hardware / trainable)
  coding       — Poisson rate encoder, spike decoders
  network      — logical SNN description (adjacency-matrix form)
  mapping      — placement compiler + SRAM capacity checks + NoC profile
  cerebra_s    — bus-based baseline accelerator (functional + cost model)
  cerebra_h    — clustered NoC accelerator (functional + cost model)
  software     — float software-reference inference
  energy       — Table-V-calibrated power/energy model
  timing       — cycle -> wall-time model (10.17 / 96.24 MHz)
  session      — SoC orchestration: deploy/run, multi-model co-residency
"""

from repro.core import (  # noqa: F401
    cerebra_h,
    cerebra_s,
    coding,
    energy,
    engine,
    fixedpoint,
    lif,
    mapping,
    network,
    session,
    software,
    timing,
)
