"""Spike encoding / decoding — the SoC's Coding Hardware Unit, in JAX.

The paper's SNAP-V SoC performs neural coding in dedicated hardware:

* **Encoder**: Poisson rate coding — sensor intensities in [0,1] become
  Bernoulli spike trains over T discrete timesteps (spike prob per step =
  intensity). Hardware uses an LFSR-style PRNG; we use JAX's counter-based
  threefry so encodings are deterministic given (seed, timestep, neuron) —
  the same reproducibility contract an LFSR provides.
* **Decoder**: integrates output spikes over the inference window and emits
  the argmax class (classification) or a rate-scaled analog value
  (actuation).

All functions are jittable, vmappable, and shardable over batch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "poisson_encode",
    "latency_encode",
    "rate_decode",
    "classify_decode",
    "analog_decode",
]


def poisson_encode(key, intensities, num_steps: int, dtype=jnp.float32):
    """Poisson (Bernoulli per-step) rate coding.

    Args:
      key: PRNG key.
      intensities: (..., D) floats in [0, 1].
      num_steps: T discrete timesteps.
    Returns:
      spikes: (T, ..., D) in {0,1} of ``dtype``.
    """
    intensities = jnp.clip(jnp.asarray(intensities), 0.0, 1.0)
    u = jax.random.uniform(key, (num_steps,) + intensities.shape)
    return (u < intensities[None]).astype(dtype)


def latency_encode(intensities, num_steps: int, dtype=jnp.float32):
    """Time-to-first-spike coding: stronger input -> earlier (single) spike.

    Provided for completeness (paper §II-A discusses TTFS); deterministic.
    """
    intensities = jnp.clip(jnp.asarray(intensities), 0.0, 1.0)
    # intensity 1 -> fires at t=0; intensity ~0 -> never fires.
    t_fire = jnp.where(
        intensities > 0,
        jnp.round((1.0 - intensities) * (num_steps - 1)).astype(jnp.int32),
        jnp.int32(num_steps),  # out of range: silent
    )
    t_axis = jnp.arange(num_steps, dtype=jnp.int32)
    t_shape = (num_steps,) + (1,) * intensities.ndim
    return (t_axis.reshape(t_shape) == t_fire[None]).astype(dtype)


def rate_decode(spikes):
    """Sum spikes over the leading time axis -> (..., D) counts."""
    return jnp.sum(spikes, axis=0)


def classify_decode(spikes):
    """Spike-count classification: argmax over the last axis of counts."""
    return jnp.argmax(rate_decode(spikes), axis=-1)


def analog_decode(spikes, lo: float = 0.0, hi: float = 1.0):
    """Reconstruct an analog value from firing rate (actuator command)."""
    num_steps = spikes.shape[0]
    rate = rate_decode(spikes) / num_steps
    return lo + rate * (hi - lo)
