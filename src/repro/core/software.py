"""Float software-reference inference — the paper's snnTorch baseline role.

Runs a logical :class:`~repro.core.network.SNNetwork` in float32 with the
exact trained decay (not snapped to hardware rates) and unquantized
weights. The accuracy-deviation experiments (paper Table IV) compare this
against the bit-exact Cerebra-H hardware model on identical spike trains.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lif import lif_step_float
from repro.core.network import SNNetwork

__all__ = ["run_software"]


def run_software(net: SNNetwork, ext_spikes):
    """Float32 inference. ext_spikes: (T, B, n_inputs) in {0,1}.

    Returns {'spikes': (T,B,N) f32, 'output_counts': (B, n_out) f32}.
    """
    W = jnp.asarray(net.weights)  # (n_in + N, N) float32
    ext_spikes = jnp.asarray(ext_spikes, jnp.float32)
    B = ext_spikes.shape[1]
    N = net.n_neurons

    def step(carry, x_t):
        v, prev = carry
        sources = jnp.concatenate([x_t, prev], axis=-1)  # (B, n_in + N)
        syn = sources @ W
        state, spikes = lif_step_float({"v": v}, syn, net.params)
        return (state["v"], spikes), spikes

    carry = (jnp.zeros((B, N)), jnp.zeros((B, N)))
    _, spikes = jax.lax.scan(step, carry, ext_spikes)
    lo, hi = net.output_slice
    return {
        "spikes": spikes,
        "output_counts": jnp.sum(spikes[:, :, lo:hi], axis=0),
    }
