"""Analytic power/energy model calibrated to the paper's Table V.

The paper measures (45 nm CMOS OpenNand, Synopsys PrimePower, MNIST
workload @ 96.24 MHz):

    Weight Memory              479.95 mW   (95.97 %)
    Neuron Clusters             17.00 mW   ( 3.40 %)
    Spike Packet Paths           2.44 mW   ( 0.49 %)
    Data/Control Packet Paths    0.72 mW   ( 0.14 %)
    Total                      500.10 mW
    Compute-path energy          1.05 pJ/SOP
    Area                        25.74 mm^2

We decompose each subsystem into static power + per-event energy and solve
the per-event constants so that the model reproduces Table V exactly at the
paper's reference operating point. The reference activity rates are derived
from the paper's own numbers:

  * neuron compute: P_nc = 17.00 mW at 1.05 pJ/SOP
        => SOP rate S_ref = 16.19 GSOP/s  (168.2 SOPs/cycle @96.24 MHz —
           66 % of the architectural max of 256 SOPs/cycle, a plausible
           MNIST duty cycle)
  * SRAM row rate R_ref = S_ref / 32 (one row delivers 32 weights)
  * spike packet rate K_ref = R_ref (one packet per row fetch)

Static/dynamic splits for the SRAM macros and NoC are stated assumptions
(OpenRAM 45 nm leakage-dominated; see DESIGN.md changed-assumptions).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TABLE_V", "EnergyModel", "WorkloadCounts",
           "counts_from_registry", "counts_from_run"]

# paper constants ----------------------------------------------------------
TABLE_V = {
    "weight_memory_mw": 479.95,
    "neuron_clusters_mw": 17.00,
    "spike_paths_mw": 2.44,
    "data_control_paths_mw": 0.72,
    "total_mw": 500.10,
}
E_SOP_PJ = 1.05
FREQ_H_MHZ = 96.24
AREA_MM2 = 25.74
SOPS_PER_ROW = 32  # one SRAM row carries a full cluster-wide weight vector


@dataclasses.dataclass
class WorkloadCounts:
    """Event counts over an inference window (from the cost model)."""

    sops: float            # synaptic operations
    row_fetches: float     # SRAM row reads
    spike_packets: float   # NoC spike-path packets
    cycles: float          # total accelerator cycles


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    freq_mhz: float = FREQ_H_MHZ
    e_sop_pj: float = E_SOP_PJ
    e_row_pj: float = 180.0        # per 1024-bit row read (assumption)
    e_packet_pj: float = 2.9       # per spike packet hop (assumption)
    p_mem_static_mw: float = 0.0   # solved by `calibrated`
    p_neuron_static_mw: float = 0.0
    p_spike_static_mw: float = 0.0
    p_ctrl_static_mw: float = TABLE_V["data_control_paths_mw"]

    # ------------------------------------------------------------------
    @classmethod
    def calibrated(cls) -> "EnergyModel":
        """Solve static terms so Table V is reproduced at the ref point."""
        f = FREQ_H_MHZ * 1e6
        s_ref = TABLE_V["neuron_clusters_mw"] * 1e-3 / (E_SOP_PJ * 1e-12)
        r_ref = s_ref / SOPS_PER_ROW
        k_ref = r_ref
        e_row = 180.0
        e_pkt = 2.9
        p_mem_static = TABLE_V["weight_memory_mw"] - r_ref * e_row * 1e-9
        p_spk_static = TABLE_V["spike_paths_mw"] - k_ref * e_pkt * 1e-9
        # neuron clusters: fully activity-proportional at 1.05 pJ/SOP
        del f
        return cls(
            e_row_pj=e_row,
            e_packet_pj=e_pkt,
            p_mem_static_mw=p_mem_static,
            p_neuron_static_mw=0.0,
            p_spike_static_mw=max(p_spk_static, 0.0),
        )

    # ------------------------------------------------------------------
    @property
    def reference_rates(self) -> dict:
        s_ref = TABLE_V["neuron_clusters_mw"] * 1e-3 / (self.e_sop_pj * 1e-12)
        return {
            "sops_per_s": s_ref,
            "rows_per_s": s_ref / SOPS_PER_ROW,
            "packets_per_s": s_ref / SOPS_PER_ROW,
            "sops_per_cycle": s_ref / (self.freq_mhz * 1e6),
        }

    def breakdown_mw(self, counts: WorkloadCounts) -> dict:
        """Average power over the workload window, per subsystem (mW)."""
        t_s = counts.cycles / (self.freq_mhz * 1e6)
        t_s = max(t_s, 1e-30)
        dyn = lambda n, e_pj: n * e_pj * 1e-12 / t_s * 1e3  # -> mW
        mem = self.p_mem_static_mw + dyn(counts.row_fetches, self.e_row_pj)
        neu = self.p_neuron_static_mw + dyn(counts.sops, self.e_sop_pj)
        spk = self.p_spike_static_mw + dyn(counts.spike_packets,
                                           self.e_packet_pj)
        ctl = self.p_ctrl_static_mw
        total = mem + neu + spk + ctl
        return {
            "weight_memory_mw": mem,
            "neuron_clusters_mw": neu,
            "spike_paths_mw": spk,
            "data_control_paths_mw": ctl,
            "total_mw": total,
            "weight_memory_pct": 100 * mem / total,
            "compute_pj_per_sop": self.e_sop_pj,
        }

    def energy_uj(self, counts: WorkloadCounts) -> dict:
        """Total energy over the window (microjoules), per subsystem."""
        t_s = counts.cycles / (self.freq_mhz * 1e6)
        static_uj = (
            (self.p_mem_static_mw + self.p_neuron_static_mw
             + self.p_spike_static_mw + self.p_ctrl_static_mw)
            * 1e-3 * t_s * 1e6
        )
        dyn_uj = (
            counts.sops * self.e_sop_pj
            + counts.row_fetches * self.e_row_pj
            + counts.spike_packets * self.e_packet_pj
        ) * 1e-12 * 1e6
        return {
            "static_uj": static_uj,
            "dynamic_uj": dyn_uj,
            "total_uj": static_uj + dyn_uj,
            "pj_per_sop_compute": self.e_sop_pj,
            "pj_per_sop_system": (static_uj + dyn_uj) * 1e6 / max(counts.sops, 1),
        }


def counts_from_run(results: dict) -> WorkloadCounts:
    """Build WorkloadCounts from a cerebra_h.run() result dict.

    The batch axis is a software construct: one physical accelerator runs
    the B inferences sequentially, so cycles (and events) SUM over batch.
    """
    return WorkloadCounts(
        sops=float(np.sum(np.asarray(results["sops"]))),
        row_fetches=float(np.sum(np.asarray(results.get("row_fetches", 0)))),
        spike_packets=float(np.sum(np.asarray(results.get("row_fetches", 0)))),
        cycles=float(np.sum(np.asarray(results["cycles"]))),
    )


def counts_from_registry(registry, *, cycles: float | None = None
                         ) -> WorkloadCounts:
    """Build WorkloadCounts from a live instrumented server's registry.

    An instrumented :class:`~repro.serving.snn.SpikeServer` maintains
    measured ``snn_server_sops_total`` / ``snn_server_row_fetches_total``
    counters with ``events.trace`` semantics, so the analytic model can
    price a LIVE serving process the same way it prices an offline run.
    One spike packet per row fetch, as in :func:`counts_from_run`.

    ``cycles`` defaults to the reference-duty estimate: the calibrated
    model's SOPs/cycle at the paper's Table-V operating point, i.e. the
    live workload is priced as if the accelerator sustained the paper's
    MNIST duty cycle. Pass explicit cycles to price a different duty.
    """
    sops = float(registry.counter("snn_server_sops_total").value)
    rows = float(registry.counter("snn_server_row_fetches_total").value)
    if cycles is None:
        per_cycle = EnergyModel.calibrated().reference_rates["sops_per_cycle"]
        cycles = sops / per_cycle
    return WorkloadCounts(sops=sops, row_fetches=rows, spike_packets=rows,
                          cycles=float(cycles))
