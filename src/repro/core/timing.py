"""Timing model: cycles -> wall time at the synthesized clock frequencies.

Paper §V / §VII-B: Cerebra-S f_max = 10.17 MHz (long combinational bus +
multiplier path); Cerebra-H f_max = 96.24 MHz (critical path 10.3904 ns),
a 9.46x clock improvement. Combined with the per-timestep cycle counts from
the two cost models this yields end-to-end latency and the S-vs-H speedup
benchmark (benchmarks/speedup_s_vs_h.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

FREQ_S_MHZ = 10.17
FREQ_H_MHZ = 96.24
CRITICAL_PATH_H_NS = 10.3904


@dataclasses.dataclass(frozen=True)
class TimingReport:
    cycles_s: float
    cycles_h: float
    time_s_us: float
    time_h_us: float
    cycle_speedup: float
    clock_speedup: float
    total_speedup: float


def wall_time_us(cycles: float, freq_mhz: float) -> float:
    return float(cycles) / freq_mhz  # cycles / (MHz) == microseconds


def speedup_report(cycles_s, cycles_h) -> TimingReport:
    """cycles_*: per-step cycle arrays or totals from the cost models."""
    cs = float(np.sum(np.asarray(cycles_s, dtype=np.float64)))
    ch = float(np.sum(np.asarray(cycles_h, dtype=np.float64)))
    ts = wall_time_us(cs, FREQ_S_MHZ)
    th = wall_time_us(ch, FREQ_H_MHZ)
    return TimingReport(
        cycles_s=cs,
        cycles_h=ch,
        time_s_us=ts,
        time_h_us=th,
        cycle_speedup=cs / max(ch, 1e-12),
        clock_speedup=FREQ_H_MHZ / FREQ_S_MHZ,
        total_speedup=ts / max(th, 1e-12),
    )
