"""Cerebra-S — the bus-based baseline accelerator (paper §IV).

Functional model + cycle-accurate cost model.

Hardware semantics being modeled:
  * 1024 physical neurons on a flat tagged bus; adjacency matrix in a
    central SRAM.
  * At each timestep boundary, spikes from the array + external stimulus
    are captured; for every spiking source the interconnect walks its
    outgoing synapses and emits ONE weighted event PER CLOCK CYCLE
    (dst address + weight) on the shared bus; each neuron snoops and
    accumulates matching events.
  * Neurons: accumulator (wrapping int32 add), potential-decay unit
    (fixed-point MULTIPLY by a decay factor — Cerebra-S kept the
    multiplier), potential adder (threshold compare + reset).

TPU adaptation (DESIGN.md §2): the serial bus walk is functionally a
spike-vector × adjacency-matrix product; the functional timestep runs on
the shared :class:`~repro.core.engine.SpikeEngine` (backend-selectable:
pure-jnp int32 matmul or the event-gated Pallas kernel), while the cost
model here retains the serial event count as a pure pass over the spike
raster — cycles(t) = Σ_sources fanout(spiking sources at t).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp
from repro.core.engine import DecaySpec, SpikeEngine, sources_raster
from repro.core.lif import LIFParams
from repro.core.network import SNNetwork

__all__ = [
    "CerebraSConfig",
    "CerebraSProgram",
    "compile_network",
    "make_engine",
    "cost_model",
    "run",
]

MAX_FREQ_MHZ = 10.17  # paper §V: Cerebra-S critical path


@dataclasses.dataclass(frozen=True)
class CerebraSConfig:
    n_physical_neurons: int = 1024
    fmt: fxp.FixedPointFormat = fxp.Q16_16
    # Central SRAM capacity: full adjacency over the physical array plus
    # external sources; the paper gives no explicit row budget for S, so the
    # limit is the square adjacency over physical neurons + stimuli.
    max_external_sources: int = 1024


@dataclasses.dataclass
class CerebraSProgram:
    """A network compiled (placed + quantized) for Cerebra-S."""

    config: CerebraSConfig
    params: LIFParams
    n_inputs: int
    n_neurons: int                 # logical neurons in use
    weights_raw: jnp.ndarray       # (n_sources, n_physical) int32
    fanout: np.ndarray             # (n_sources,) int — bus events per spike
    output_slice: tuple[int, int]
    decay_raw: int                 # fixed-point retain factor for the PDU
    # per-program engine cache: one compiled scan per backend
    _engines: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def n_sources(self) -> int:
        return self.n_inputs + self.config.n_physical_neurons


def compile_network(
    net: SNNetwork, config: CerebraSConfig | None = None
) -> CerebraSProgram:
    """Quantize + place a logical network onto the Cerebra-S array.

    Logical neuron i -> physical neuron i (the paper's one-to-one
    initialization mapping); unused physical neurons get zero fan-in and
    never spike.
    """
    config = config or CerebraSConfig()
    net.validate()
    if net.n_neurons > config.n_physical_neurons:
        raise ValueError(
            f"network has {net.n_neurons} neurons > "
            f"{config.n_physical_neurons} physical neurons"
        )
    if net.n_inputs > config.max_external_sources:
        raise ValueError(
            f"{net.n_inputs} external sources exceed SRAM budget "
            f"{config.max_external_sources}"
        )
    n_phys = config.n_physical_neurons
    W = np.zeros((net.n_inputs + n_phys, n_phys), np.float32)
    W[: net.n_inputs, : net.n_neurons] = net.weights[: net.n_inputs]
    W[net.n_inputs : net.n_inputs + net.n_neurons, : net.n_neurons] = (
        net.weights[net.n_inputs :]
    )
    w_raw = fxp.np_to_fixed(W, config.fmt)
    # Cerebra-S keeps the fixed-point multiplier: the retain factor itself is
    # quantized to Q16.16 but otherwise arbitrary.
    decay_raw = int(round(net.params.beta * config.fmt.scale))
    return CerebraSProgram(
        config=config,
        params=net.params,
        n_inputs=net.n_inputs,
        n_neurons=net.n_neurons,
        weights_raw=jnp.asarray(w_raw),
        fanout=np.count_nonzero(W, axis=1),
        output_slice=net.output_slice,
        decay_raw=decay_raw,
    )


def make_engine(program: CerebraSProgram,
                backend: str = "reference") -> SpikeEngine:
    """The program's SpikeEngine for ``backend`` (built once, then cached).

    Cerebra-S kept the fixed-point multiplier, so the engine decays with
    ``DecaySpec.mul`` — the truncating Q16.16 multiply — instead of the
    H generation's shift decay.
    """
    engine = program._engines.get(backend)
    if engine is None:
        engine = SpikeEngine(
            program.weights_raw,
            program.n_inputs,
            decay=DecaySpec.mul(program.decay_raw),
            threshold_raw=program.params.threshold_raw,
            reset_mode=program.params.reset_mode,
            backend=backend,
        )
        program._engines[backend] = engine
    return engine


def cost_model(program: CerebraSProgram, ext_spikes, spikes) -> dict:
    """Pure cycle/SOP accounting from a spike raster (no functional state).

    Bus cost: the interconnect walks one outgoing synapse per clock, so
    cycles(t) = Σ over spiking sources of their fanout, and every bus
    event is exactly one synaptic operation.

    Args:
      ext_spikes: (T, B, n_inputs) external stimulus in {0,1}.
      spikes: (T, B, n_physical) raster produced by the engine.
    Returns:
      {'cycles': (T, B) int32, 'sops': (T, B) int32}
    """
    sources = sources_raster(ext_spikes, spikes)
    fanout = jnp.asarray(program.fanout, jnp.int32)
    cycles = jnp.sum(sources * fanout[None, None, :], axis=-1)
    return {"cycles": cycles, "sops": cycles}


def run(program: CerebraSProgram, ext_spikes, backend: str = "reference"):
    """Run inference over a spike train.

    Args:
      program: compiled network.
      ext_spikes: (T, B, n_inputs) in {0,1} (any int/float dtype).
      backend: SpikeEngine backend ("reference" | "pallas" | "pallas-mxu").
    Returns:
      dict with:
        'spikes': (T, B, n_physical) int32 spike raster,
        'output_counts': (B, n_out) spike counts over the output slice,
        'cycles': (T, B) bus cycles per timestep,
        'sops': (T, B) synaptic ops per timestep.
    """
    engine = make_engine(program, backend)
    out = engine.run(ext_spikes)
    spikes = out["spikes"]
    cost = cost_model(program, ext_spikes, spikes)
    lo, hi = program.output_slice
    return {
        "spikes": spikes,
        "output_counts": jnp.sum(spikes[:, :, lo:hi], axis=0),
        "cycles": cost["cycles"],
        "sops": cost["sops"],
    }
