"""Cerebra-S — the bus-based baseline accelerator (paper §IV).

Functional model + cycle-accurate cost model.

Hardware semantics being modeled:
  * 1024 physical neurons on a flat tagged bus; adjacency matrix in a
    central SRAM.
  * At each timestep boundary, spikes from the array + external stimulus
    are captured; for every spiking source the interconnect walks its
    outgoing synapses and emits ONE weighted event PER CLOCK CYCLE
    (dst address + weight) on the shared bus; each neuron snoops and
    accumulates matching events.
  * Neurons: accumulator (wrapping int32 add), potential-decay unit
    (fixed-point MULTIPLY by a decay factor — Cerebra-S kept the
    multiplier), potential adder (threshold compare + reset).

TPU adaptation (DESIGN.md §2): the serial bus walk is functionally a
spike-vector × adjacency-matrix product; we compute it as an int32 matmul
(the MXU *is* the broadcast/accumulate fabric) while the cost model retains
the serial event count — cycles(t) = Σ_sources fanout(spiking sources at t).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp
from repro.core.lif import LIFParams, lif_init
from repro.core.network import SNNetwork

__all__ = ["CerebraSConfig", "CerebraSProgram", "compile_network", "run"]

MAX_FREQ_MHZ = 10.17  # paper §V: Cerebra-S critical path


@dataclasses.dataclass(frozen=True)
class CerebraSConfig:
    n_physical_neurons: int = 1024
    fmt: fxp.FixedPointFormat = fxp.Q16_16
    # Central SRAM capacity: full adjacency over the physical array plus
    # external sources; the paper gives no explicit row budget for S, so the
    # limit is the square adjacency over physical neurons + stimuli.
    max_external_sources: int = 1024


@dataclasses.dataclass
class CerebraSProgram:
    """A network compiled (placed + quantized) for Cerebra-S."""

    config: CerebraSConfig
    params: LIFParams
    n_inputs: int
    n_neurons: int                 # logical neurons in use
    weights_raw: jnp.ndarray       # (n_sources, n_physical) int32
    fanout: np.ndarray             # (n_sources,) int — bus events per spike
    output_slice: tuple[int, int]
    decay_raw: int                 # fixed-point retain factor for the PDU

    @property
    def n_sources(self) -> int:
        return self.n_inputs + self.config.n_physical_neurons


def compile_network(
    net: SNNetwork, config: CerebraSConfig | None = None
) -> CerebraSProgram:
    """Quantize + place a logical network onto the Cerebra-S array.

    Logical neuron i -> physical neuron i (the paper's one-to-one
    initialization mapping); unused physical neurons get zero fan-in and
    never spike.
    """
    config = config or CerebraSConfig()
    net.validate()
    if net.n_neurons > config.n_physical_neurons:
        raise ValueError(
            f"network has {net.n_neurons} neurons > "
            f"{config.n_physical_neurons} physical neurons"
        )
    if net.n_inputs > config.max_external_sources:
        raise ValueError(
            f"{net.n_inputs} external sources exceed SRAM budget "
            f"{config.max_external_sources}"
        )
    n_phys = config.n_physical_neurons
    W = np.zeros((net.n_inputs + n_phys, n_phys), np.float32)
    W[: net.n_inputs, : net.n_neurons] = net.weights[: net.n_inputs]
    W[net.n_inputs : net.n_inputs + net.n_neurons, : net.n_neurons] = (
        net.weights[net.n_inputs :]
    )
    w_raw = fxp.np_to_fixed(W, config.fmt)
    # Cerebra-S keeps the fixed-point multiplier: the retain factor itself is
    # quantized to Q16.16 but otherwise arbitrary.
    decay_raw = int(round(net.params.beta * config.fmt.scale))
    return CerebraSProgram(
        config=config,
        params=net.params,
        n_inputs=net.n_inputs,
        n_neurons=net.n_neurons,
        weights_raw=jnp.asarray(w_raw),
        fanout=np.count_nonzero(W, axis=1),
        output_slice=net.output_slice,
        decay_raw=decay_raw,
    )


def _timestep(program: CerebraSProgram, carry, ext_spikes_t):
    """One accelerator timestep for a batch of ext spike vectors.

    carry: {'v': (B, P) int32, 'spikes': (B, P) int32}
    ext_spikes_t: (B, n_inputs) int32 in {0,1}
    """
    v, prev_spikes = carry["v"], carry["spikes"]
    sources = jnp.concatenate(
        [ext_spikes_t.astype(jnp.int32), prev_spikes], axis=-1
    )  # (B, S)
    # Accumulator: sum of weights of active sources. Spikes are 0/1 so this
    # is exactly the bus's event-by-event accumulation, order-independent
    # because int32 adds are associative (wrapping).
    syn = jax.lax.dot_general(
        sources,
        program.weights_raw,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    # Potential decay unit: fixed-point multiply (truncating).
    v_decayed = fxp.fx_mul(v, jnp.int32(program.decay_raw), program.config.fmt)
    v_new = v_decayed + syn
    thr = jnp.int32(program.params.threshold_raw)
    spikes = (v_new >= thr).astype(jnp.int32)
    if program.params.reset_mode == "zero":
        v_out = jnp.where(spikes > 0, jnp.int32(0), v_new)
    elif program.params.reset_mode == "subtract":
        v_out = v_new - spikes * thr
    else:  # hold
        v_out = v_new
    # Bus cost: one cycle per outgoing synapse of every spiking source.
    fanout = jnp.asarray(program.fanout, jnp.int32)
    cycles = jnp.sum(sources * fanout[None, :], axis=-1)  # (B,)
    sops = cycles  # every bus event is one synaptic operation
    return {"v": v_out, "spikes": spikes}, (spikes, cycles, sops)


def run(program: CerebraSProgram, ext_spikes):
    """Run inference over a spike train.

    Args:
      program: compiled network.
      ext_spikes: (T, B, n_inputs) in {0,1} (any int/float dtype).
    Returns:
      dict with:
        'spikes': (T, B, n_physical) int32 spike raster,
        'output_counts': (B, n_out) spike counts over the output slice,
        'cycles': (T, B) bus cycles per timestep,
        'sops': (T, B) synaptic ops per timestep.
    """
    ext_spikes = jnp.asarray(ext_spikes)
    T, B = ext_spikes.shape[0], ext_spikes.shape[1]
    del T
    n_phys = program.config.n_physical_neurons
    carry = {
        "v": lif_init((B, n_phys), fixed=True)["v"],
        "spikes": jnp.zeros((B, n_phys), jnp.int32),
    }
    step = lambda c, x: _timestep(program, c, x)
    _, (spikes, cycles, sops) = jax.lax.scan(step, carry, ext_spikes)
    lo, hi = program.output_slice
    output_counts = jnp.sum(spikes[:, :, lo:hi], axis=0)
    return {
        "spikes": spikes,
        "output_counts": output_counts,
        "cycles": cycles,
        "sops": sops,
    }
