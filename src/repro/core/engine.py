"""SpikeEngine — the single timestep core every accelerator model runs on.

The paper's central claim is that ONE fused accelerator timestep
(event-gated weight fetch + accumulate + LIF fire/reset) serves both
Cerebra generations and multiple co-resident models. This module is that
timestep, in software: it owns the scan loop over time, the carries
(membrane potential + previous-boundary spikes), and per-program jit
caching, and dispatches the inner accumulate+fire to a pluggable backend:

  ``"reference"``   pure-jnp int32 matmul + shared LIF epilogue. Bit-exact
                    oracle semantics; fastest on CPU.
  ``"pallas"``      the event-gated Pallas kernel
                    (:func:`repro.kernels.ops.spike_timestep`): silent
                    source blocks skip both compute and weight traffic.
                    Bit-exact vs ``"reference"``. Interpreted on CPU,
                    compiled Mosaic on TPU.
  ``"pallas-mxu"``  same kernel with the f32 MXU accumulate. Exact only
                    while per-output partial sums stay below 2^24; the
                    bound is enforced AT ENGINE BUILD TIME from the weight
                    image (worst-case per-block column sums), so a program
                    that could ever produce an inexact sum refuses to
                    compile instead of silently mis-accumulating.

Frontends (``cerebra_s``, ``cerebra_h``, ``session``) contribute only a
compile step (placement + quantized weight image + decay spec) and a pure
cost-model pass over the resulting spike raster; the functional semantics
live here, once.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp
from repro.core.lif import fire_reset, lif_init

__all__ = [
    "BACKENDS",
    "GATES",
    "MXU_EXACT_BOUND",
    "DecaySpec",
    "SpikeEngine",
    "mxu_partial_sum_bound",
    "sources_raster",
]

BACKENDS: tuple[str, ...] = ("reference", "pallas", "pallas-mxu")

# Event-gate granularity of the Pallas kernels (the Incoming Forwarder):
#   "batch-tile"   one activity scalar per (8-example batch tile, source
#                  block) — a fetch is skipped only when the WHOLE tile is
#                  silent on that block (high-throughput batch inference).
#   "per-example"  batch tile = 1: one activity scalar per (example,
#                  source block), so each stream's silence skips its own
#                  weight traffic — the serving mode, where slot batches
#                  are mostly idle. Bit-identical outputs either way; the
#                  gate only changes which already-zero work is skipped.
GATES: tuple[str, ...] = ("batch-tile", "per-example")

_GATE_TILE_BATCH = 8  # batch rows per activity scalar under "batch-tile"

# f32 has a 24-bit significand: integer-valued accumulation stays exact
# while every partial sum's magnitude is < 2^24.
MXU_EXACT_BOUND: int = 1 << 24

_MXU_BLOCK_SRC = 128  # source-block size the MXU accumulate reduces over


@dataclasses.dataclass(frozen=True)
class DecaySpec:
    """Which Potential-Decay Unit the program compiled for.

    ``kind='shift'`` — Cerebra-H arithmetic-shift decay; ``rate`` must be a
    hardware-supported rate. ``kind='mul'`` — Cerebra-S truncating
    fixed-point multiply; ``raw`` is the Q16.16 retain factor.
    """

    kind: str
    rate: float = 0.0
    raw: int = 0

    @classmethod
    def shift(cls, rate: float) -> "DecaySpec":
        if rate not in fxp.SHIFT_DECAY_RATES:
            raise ValueError(
                f"shift decay rate {rate} not in {fxp.SHIFT_DECAY_RATES}"
            )
        return cls(kind="shift", rate=float(rate))

    @classmethod
    def mul(cls, raw: int) -> "DecaySpec":
        # raw == 2^16 is beta = 1.0: fx_mul's hi/lo split is the exact
        # identity there (a_hi*2^16 + a_lo == a), so leak-free IF neurons
        # (decay_rate = 0.0) are a valid Cerebra-S configuration.
        if not 0 <= raw <= (1 << 16):
            raise ValueError(
                f"mul retain factor {raw} outside [0, 2^16]"
            )
        return cls(kind="mul", raw=int(raw))

    def apply(self, v):
        if self.kind == "shift":
            return fxp.shift_decay(v, self.rate)
        if self.kind == "mul":
            return fxp.fx_mul(v, jnp.int32(self.raw))
        raise ValueError(f"unknown decay kind {self.kind!r}")


def mxu_partial_sum_bound(weights_raw: np.ndarray,
                          block_src: int = _MXU_BLOCK_SRC, *,
                          fuse_steps: int = 1) -> int:
    """Worst-case f32 partial-sum magnitude of the MXU accumulate.

    Both kernels reduce over source blocks of ``block_src`` rows; sources
    are {0,1}, so the worst case for an output column is the sum of |w|
    over one block. Inter-block accumulation happens in int32 and is
    always exact, so only the intra-block bound matters.

    ``fuse_steps`` is accepted so callers state the K they validate for:
    the bound is K-INVARIANT by construction. The K-step fused kernel
    stacks the window along the dot's BATCH axis (K*Bb rows of {0,1}
    sources against one block), and its per-step recurrent accumulate is
    chunked at ``block_src`` rows with int32 inter-chunk adds — no f32
    reduction ever spans more than one ``block_src`` block, for any K.
    """
    if fuse_steps < 1:
        raise ValueError(f"fuse_steps must be >= 1, got {fuse_steps}")
    w = np.abs(np.asarray(weights_raw, np.int64))
    S = w.shape[0]
    pad = (-S) % block_src
    if pad:
        w = np.pad(w, ((0, pad), (0, 0)))
    blocks = w.reshape(-1, block_src, w.shape[1]).sum(axis=1)
    return int(blocks.max()) if blocks.size else 0


def sources_raster(ext_spikes, spikes):
    """(T, B, S) source activity: external spikes + PREVIOUS-step spikes.

    The accelerator captures array spikes at the timestep boundary, so the
    sources of step t are the spikes of step t-1 (none before step 0).
    The cost models consume this instead of re-running the scan.
    """
    ext = jnp.asarray(ext_spikes).astype(jnp.int32)
    spk = jnp.asarray(spikes, jnp.int32)
    prev = jnp.concatenate([jnp.zeros_like(spk[:1]), spk[:-1]], axis=0)
    return jnp.concatenate([ext, prev], axis=-1)


class SpikeEngine:
    """One physical neuron array stepping under a fixed LIF configuration.

    The engine is the only owner of the functional timestep:

        sources_t = concat(external_t, spikes_{t-1})          # (B, S)
        syn_t     = sources_t @ W_raw                         # backend
        v_t, spikes_t = fire_reset(decay(v_{t-1}) + syn_t)    # shared LIF

    Construction validates the backend (including the pallas-mxu 2^24
    exactness bound); :meth:`run` jit-compiles the whole scan once per
    engine and reuses it across calls (per-program jit caching).
    """

    def __init__(
        self,
        weights_raw,
        n_inputs: int,
        *,
        decay: DecaySpec,
        threshold_raw: int,
        reset_mode: str,
        backend: str = "reference",
        interpret: bool | None = None,
        gate: str = "batch-tile",
        fuse_steps: int = 1,
    ):
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; expected one of {BACKENDS}"
            )
        if gate not in GATES:
            raise ValueError(
                f"unknown event gate {gate!r}; expected one of {GATES}"
            )
        fuse_steps = int(fuse_steps)
        if fuse_steps < 1:
            raise ValueError(f"fuse_steps must be >= 1, got {fuse_steps}")
        weights_raw = jnp.asarray(weights_raw, jnp.int32)
        if weights_raw.ndim != 2:
            raise ValueError(
                f"weights must be a flat (n_sources, n_phys) SRAM image, "
                f"got shape {weights_raw.shape}"
            )
        n_sources, n_phys = weights_raw.shape
        if not 0 <= n_inputs <= n_sources:
            raise ValueError(
                f"n_inputs={n_inputs} outside [0, {n_sources}]"
            )
        if n_inputs + n_phys != n_sources:
            raise ValueError(
                f"source axis {n_sources} != n_inputs {n_inputs} + "
                f"n_phys {n_phys}: recurrent spikes could not be fed back"
            )
        if backend == "pallas-mxu":
            worst = mxu_partial_sum_bound(np.asarray(weights_raw),
                                          fuse_steps=fuse_steps)
            if worst >= MXU_EXACT_BOUND:
                w_max = int(np.abs(np.asarray(weights_raw)).max())
                raise ValueError(
                    f"pallas-mxu backend rejected at compile time: "
                    f"worst-case f32 partial sum {worst} >= 2^24 "
                    f"({MXU_EXACT_BOUND}) for max |w| = {w_max} raw Q16.16, "
                    f"per-block source fan-in {_MXU_BLOCK_SRC}, "
                    f"fuse_steps K = {fuse_steps} (the bound is "
                    f"K-invariant: the fused window stacks along the dot's "
                    f"batch axis, never its reduction axis); the MXU "
                    f"accumulate would not be bit-exact for this weight "
                    f"image. Reduce fan-in or weight magnitudes, or use "
                    f"backend='pallas'."
                )
        self.weights_raw = weights_raw
        self.n_inputs = int(n_inputs)
        self.n_phys = int(n_phys)
        self.n_sources = int(n_sources)
        self.decay = decay
        self.threshold_raw = int(threshold_raw)
        self.reset_mode = str(reset_mode)
        self.backend = backend
        self.interpret = interpret
        self.gate = gate
        # K timesteps per kernel invocation (the fused Pallas window);
        # part of the engine identity, so the lazily-built jit caches
        # below are keyed by it structurally — one compiled program per
        # (engine, K). fuse_steps == 1 keeps the single-step kernels.
        self.fuse_steps = fuse_steps
        self._run_jit = None  # compiled scan, built lazily once per engine
        self._chunk_jit = None  # compiled masked chunk step (streaming path)

    # ------------------------------------------------------------------
    def _scan_weights(self):
        """The weight image :meth:`run`/:meth:`step_chunk` dispatch with.

        Subclasses may substitute an equivalent re-hosted image (the mesh
        engine hands back its padded, device-sharded SRAM slices); the
        logical program — and therefore the numbers — must not change.
        """
        return self.weights_raw

    def to_mesh(self, mesh):
        """Drop-in scale-out: this engine's program re-hosted on a device
        mesh (:class:`repro.distributed.spike_mesh.MeshSpikeEngine`), with
        bit-identical ``run``/``step_chunk`` semantics."""
        from repro.distributed.spike_mesh import MeshSpikeEngine

        return MeshSpikeEngine.from_engine(self, mesh)

    def with_gate(self, gate: str) -> "SpikeEngine":
        """This engine's program re-hosted under another event-gate
        granularity (bit-identical outputs; only skipped-zero work
        differs). Returns ``self`` when the gate already matches."""
        if gate == self.gate:
            return self
        return SpikeEngine(
            self.weights_raw, self.n_inputs, decay=self.decay,
            threshold_raw=self.threshold_raw, reset_mode=self.reset_mode,
            backend=self.backend, interpret=self.interpret, gate=gate,
            fuse_steps=self.fuse_steps,
        )

    def with_fuse_steps(self, fuse_steps: int) -> "SpikeEngine":
        """This engine's program re-hosted under another K-step fusion
        window (bit-identical outputs; only kernel granularity and weight
        traffic differ). Returns ``self`` when K already matches."""
        if int(fuse_steps) == self.fuse_steps:
            return self
        return SpikeEngine(
            self.weights_raw, self.n_inputs, decay=self.decay,
            threshold_raw=self.threshold_raw, reset_mode=self.reset_mode,
            backend=self.backend, interpret=self.interpret, gate=self.gate,
            fuse_steps=fuse_steps,
        )

    # ------------------------------------------------------------------
    def init_carry(self, batch: int) -> dict:
        """The unified initial accelerator state: V = 0, no prior spikes.

        Both Cerebra generations power up with cleared membrane SRAM; this
        is the single definition (via :func:`repro.core.lif.lif_init`)
        that ``cerebra_s.run`` and ``cerebra_h.run`` previously duplicated
        inconsistently.
        """
        return {
            "v": lif_init((batch, self.n_phys), fixed=True)["v"],
            "spikes": jnp.zeros((batch, self.n_phys), jnp.int32),
        }

    # ------------------------------------------------------------------
    def _step(self, weights, carry, ext_t):
        """One fused timestep for a batch of external spike vectors."""
        sources = jnp.concatenate(
            [ext_t.astype(jnp.int32), carry["spikes"]], axis=-1
        )  # (B, S)
        if self.backend == "reference":
            syn = jax.lax.dot_general(
                sources,
                weights,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            v_new = self.decay.apply(carry["v"]) + syn
            v_out, spikes = fire_reset(
                v_new, jnp.int32(self.threshold_raw), self.reset_mode
            )
        else:
            from repro.kernels import ops  # deferred: breaks import cycle

            v_out, spikes = ops.spike_timestep(
                sources,
                weights,
                carry["v"],
                decay_kind=self.decay.kind,
                decay_rate=self.decay.rate,
                decay_raw=self.decay.raw,
                threshold_raw=self.threshold_raw,
                reset_mode=self.reset_mode,
                use_mxu=(self.backend == "pallas-mxu"),
                block_batch=(1 if self.gate == "per-example"
                             else _GATE_TILE_BATCH),
                interpret=self.interpret,
            )
        return {"v": v_out, "spikes": spikes}, spikes

    def step(self, carry, ext_t):
        """Public single-step entry (closed-loop / streaming callers).

        One timestep of the batch scan body, un-jitted: ``(carry,
        ext_t (B, n_inputs)) -> (carry', spikes_t)``. Chaining ``step`` T
        times is bit-identical to one :meth:`run` over the stacked train
        (same backend dispatch, same shared epilogue).
        """
        return self._step(self.weights_raw, carry, ext_t)

    # ------------------------------------------------------------------
    # Streaming path: a fixed slot batch advanced T steps under a
    # per-(step, slot) activity mask. Inactive slots keep their carry
    # bit-for-bit (a paused stream must resume exactly where it stopped),
    # which is what lets one compiled program serve churning traffic:
    # the serving layer pins (chunk_steps, n_slots) and pads with
    # active = 0 instead of recompiling per request shape.
    # ------------------------------------------------------------------
    def _masked_chunk_scan(self, step_fn, carry, ext, active):
        """THE masked-slot scan: advance via ``step_fn`` where active,
        keep the carry bit-for-bit (and report zero spikes) where not.
        Single definition — the mesh engine scans the same body with its
        spike-exchange step, so the paused-stream contract cannot drift
        between the single-device and sharded paths."""

        def body(c, xs):
            ext_t, act_t = xs
            new, spikes = step_fn(c, ext_t)
            keep = act_t[:, None] != 0
            c_out = {
                "v": jnp.where(keep, new["v"], c["v"]),
                "spikes": jnp.where(keep, new["spikes"], c["spikes"]),
            }
            return c_out, jnp.where(keep, spikes, 0)

        return jax.lax.scan(body, carry, (ext, active))

    # ------------------------------------------------------------------
    # K-step fused path: with fuse_steps > 1 on a Pallas backend, run /
    # step_chunk scan over K-step WINDOWS, each one fused kernel call
    # (weight blocks fetched once per window instead of once per step).
    # A ragged T pads up to a K multiple with active = 0 — the kernel's
    # in-body masked-slot contract makes the remainder byte-identical to
    # the unfused masked scan, so no separate remainder program exists.
    # ------------------------------------------------------------------
    @property
    def _use_fused(self) -> bool:
        return self.fuse_steps > 1 and self.backend != "reference"

    def _window(self, weights, carry, ext_w, act_w):
        """One fused K-step window: (carry, (K,B,*) inputs) -> (carry',
        (K,B,P) emitted raster)."""
        from repro.kernels import ops  # deferred: breaks import cycle

        v_out, spk_carry, raster = ops.spike_timestep_fused(
            ext_w, carry["spikes"], weights, carry["v"], act_w,
            n_inputs=self.n_inputs,
            decay_kind=self.decay.kind,
            decay_rate=self.decay.rate,
            decay_raw=self.decay.raw,
            threshold_raw=self.threshold_raw,
            reset_mode=self.reset_mode,
            use_mxu=(self.backend == "pallas-mxu"),
            block_batch=(1 if self.gate == "per-example"
                         else _GATE_TILE_BATCH),
            interpret=self.interpret,
        )
        return {"v": v_out, "spikes": spk_carry}, raster

    def _fused_scan(self, weights, carry, ext, active):
        K = self.fuse_steps
        T, B = ext.shape[0], ext.shape[1]
        pad = (-T) % K
        if pad:
            ext = jnp.pad(ext, ((0, pad), (0, 0), (0, 0)))
            active = jnp.pad(active, ((0, pad), (0, 0)))
        nw = (T + pad) // K
        ext_w = ext.reshape(nw, K, B, self.n_inputs)
        act_w = active.reshape(nw, K, B)
        body = lambda c, xs: self._window(weights, c, xs[0], xs[1])
        final, raster = jax.lax.scan(body, carry, (ext_w, act_w))
        return final, raster.reshape(nw * K, B, self.n_phys)[:T]

    def _chunk_impl(self, weights, carry, ext, active):
        if self._use_fused:
            return self._fused_scan(weights, carry, ext, active)
        step = lambda c, x: self._step(weights, c, x)
        return self._masked_chunk_scan(step, carry, ext, active)

    def step_chunk(self, carry, ext, active=None):
        """Advance a slot batch over a chunk of timesteps, with masking.

        Args:
          carry: {'v': (B, n_phys), 'spikes': (B, n_phys)} int32 slot state.
          ext: (T, B, n_inputs) external spikes; rows of inactive slots are
            ignored (conventionally zero).
          active: (T, B) mask; slot b consumes step t iff active[t, b] != 0.
            None means all slots active every step (the batch semantics).
        Returns:
          (carry', spikes (T, B, n_phys)): active slots advance exactly as
          :meth:`run`'s scan body would; inactive slots keep their carry
          unchanged and report zero spikes.

        The jitted chunk step is cached on the engine; XLA reuses one
        compiled program per (T, B) shape, so a serving layer that fixes
        its slot-batch shape compiles exactly once.
        """
        ext = jnp.asarray(ext).astype(jnp.int32)
        if ext.ndim != 3 or ext.shape[2] != self.n_inputs:
            raise ValueError(
                f"ext must be (T, B, {self.n_inputs}), got {ext.shape}"
            )
        if active is None:
            active = jnp.ones(ext.shape[:2], jnp.int32)
        active = jnp.asarray(active, jnp.int32)
        if active.shape != ext.shape[:2]:
            raise ValueError(
                f"active mask must be {ext.shape[:2]}, got {active.shape}"
            )
        if self._chunk_jit is None:
            self._chunk_jit = jax.jit(self._chunk_impl)
        return self._chunk_jit(self._scan_weights(), carry, ext, active)

    # ------------------------------------------------------------------
    def _run_impl(self, weights, ext_spikes):
        carry = self.init_carry(ext_spikes.shape[1])
        if self._use_fused:
            active = jnp.ones(ext_spikes.shape[:2], jnp.int32)
            final, spikes = self._fused_scan(
                weights, carry, ext_spikes, active)
        else:
            step = lambda c, x: self._step(weights, c, x)
            final, spikes = jax.lax.scan(step, carry, ext_spikes)
        return {"spikes": spikes, "v_final": final["v"]}

    def run(self, ext_spikes, *, events_capacity: int | None = None,
            events_policy: str = "error") -> dict:
        """Scan the engine over a spike train.

        Args:
          ext_spikes: (T, B, n_inputs) in {0,1} (any int/float dtype), or
            an :class:`~repro.events.aer.AERStream` addressing that shape
            (the sparse external-input path; decoded by one jitted op).
          events_capacity: when set, the output raster is ALSO returned as
            an AER stream of at most this many events under
            ``events_policy`` ("error" refuses a lossy encode, "drop"
            keeps the earliest events and flags overflow).
        Returns:
          {'spikes': (T, B, n_phys) int32 raster,
           'v_final': (B, n_phys) int32 membrane state after step T,
           'events': AERStream of 'spikes' (only with events_capacity)}.

        Exactness: every backend returns bit-identical rasters (the
        pallas-mxu 2^24 bound is enforced at engine build, so an engine
        that constructs cannot mis-accumulate), under any ``gate`` and
        any ``fuse_steps`` (the K-step fused window applies the same
        int32 accumulate + LIF epilogue per step inside the kernel).
        Static shapes: the whole scan is jitted once per engine and
        reused across calls; one XLA program serves every call of the
        same ``(T, B)`` shape (AER inputs decode through one jitted op at
        the stream's fixed capacity — no retrace per spike count).
        """
        from repro.events.aer import AERStream, aer_to_dense, dense_to_aer

        if isinstance(ext_spikes, AERStream):
            if ext_spikes.shape[2] != self.n_inputs:
                raise ValueError(
                    f"AER stream addresses {ext_spikes.shape[2]} sources; "
                    f"engine expects {self.n_inputs} inputs"
                )
            ext_spikes = aer_to_dense(ext_spikes)
        ext_spikes = jnp.asarray(ext_spikes).astype(jnp.int32)
        if ext_spikes.ndim != 3 or ext_spikes.shape[2] != self.n_inputs:
            raise ValueError(
                f"ext_spikes must be (T, B, {self.n_inputs}), "
                f"got {ext_spikes.shape}"
            )
        if self._run_jit is None:
            self._run_jit = jax.jit(self._run_impl)
        out = self._run_jit(self._scan_weights(), ext_spikes)
        if events_capacity is not None:
            out["events"] = dense_to_aer(
                out["spikes"], events_capacity, policy=events_policy)
        return out
