"""Decoder-only LM stack parameterized over the assigned families.

One module covers: mixtral (SWA+MoE), llama4-scout (MoE), gemma3
(local:global), granite-20b (MQA), minicpm3 (MLA), granite-3 (GQA),
qwen2-vl (M-RoPE, embedding frontend), rwkv6 (attention-free) and the
mamba2 backbone used by zamba2 (the zamba2 hybrid wrapper lives in
zamba2.py; whisper's enc-dec lives in whisper.py).

Homogeneous stacks are ``lax.scan``-stacked (one layer body in HLO —
bounded compile time at 48 layers x 512 devices). Per-layer heterogeneity
(gemma3's 5:1 local:global) is expressed as a scanned int32 ``window``
vector (0 = full attention) so a single code path serves both layer kinds.

Three entry points share the layer body:
  * ``forward``      — teacher-forced logits (train / eval)
  * ``prefill``      — forward + populate KV caches, return last logits
  * ``decode_step``  — one token with stacked caches
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
import numpy as np

from repro.distributed.partition import constrain_batch
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rk
from repro.models.common import (
    TransformerConfig, cross_entropy_loss, dense_init, rms_norm,
)

__all__ = ["DecoderLM", "init_mlp", "mlp_forward"]


# --------------------------------------------------------------------------
def init_mlp(key, cfg: TransformerConfig, *, bias: bool = False) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d, f)),
            "w_up": dense_init(k2, (d, f)),
            "w_down": dense_init(k3, (f, d)),
        }
    p = {"w_up": dense_init(k1, (d, f)), "w_down": dense_init(k2, (f, d))}
    if bias:
        p["b_up"] = jnp.zeros((f,))
        p["b_down"] = jnp.zeros((d,))
    return p


def mlp_forward(p: dict, x, cfg: TransformerConfig):
    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        h = act(x @ p["w_gate"].astype(x.dtype)) * (
            x @ p["w_up"].astype(x.dtype))
        return h @ p["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype) + p.get("b_up", 0.0))
    return h @ p["w_down"].astype(x.dtype) + p.get("b_down", 0.0)


# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DecoderLM:
    cfg: TransformerConfig

    # ---------------- parameters ----------------
    def init(self, key) -> dict:
        cfg = self.cfg
        k_embed, k_layers, k_out = jax.random.split(key, 3)
        params: dict = {
            "embed": {"table": dense_init(k_embed,
                                          (cfg.padded_vocab, cfg.d_model))},
            "final_norm": {"scale": jnp.zeros((cfg.d_model,))},
        }
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        stacked = jax.vmap(self._layer_init)(layer_keys)
        params["layers"] = stacked
        if not cfg.tie_embeddings:
            params["unembed"] = {
                "table": dense_init(k_out, (cfg.d_model, cfg.padded_vocab))}
        return jax.tree.map(lambda x: x.astype(cfg.dtype), params)

    def _layer_init(self, key) -> dict:
        cfg = self.cfg
        k_attn, k_mlp = jax.random.split(key)
        layer: dict = {"pre_norm": {"scale": jnp.zeros((cfg.d_model,))},
                       "pre_mlp_norm": {"scale": jnp.zeros((cfg.d_model,))}}
        if cfg.block_kind == "attn":
            if cfg.mla is not None:
                layer["attn"] = attn.init_mla(k_attn, cfg)
            else:
                layer["attn"] = attn.init_gqa(k_attn, cfg,
                                              bias=cfg.attn_bias)
            layer["moe" if cfg.moe else "mlp"] = (
                moe_mod.init_moe(k_mlp, cfg) if cfg.moe
                else init_mlp(k_mlp, cfg))
        elif cfg.block_kind == "mamba2":
            layer["ssm"] = m2.init_mamba2(k_attn, cfg)
            del layer["pre_mlp_norm"]  # mamba2 block has no separate MLP
        elif cfg.block_kind == "rwkv6":
            layer["rwkv"] = rk.init_rwkv6(k_attn, cfg)
            layer["ffn"] = rk.init_rwkv6_ffn(k_mlp, cfg)
        else:
            raise ValueError(cfg.block_kind)
        return layer

    # ---------------- layer schedule ----------------
    def layer_windows(self) -> np.ndarray:
        """(L,) int32 attention window per layer; 0 = full attention."""
        cfg = self.cfg
        w = np.zeros(cfg.n_layers, np.int32)
        if cfg.sliding_window:
            w[:] = cfg.sliding_window
            if cfg.global_every:
                w[cfg.global_every - 1::cfg.global_every] = 0
        return w

    def cache_len(self, seq_len: int) -> int:
        """Uniform per-layer cache length (baseline; §Perf explores
        per-kind split caches). Ring-buffer caches shrink to the window
        when EVERY layer is windowed."""
        cfg = self.cfg
        w = self.layer_windows()
        if cfg.sliding_window and (w > 0).all():
            return min(seq_len, int(w.max()))
        return seq_len

    # ---------------- caches ----------------
    def _split_geometry(self):
        """(n_groups, locals_per_group) for the split-cache layout."""
        cfg = self.cfg
        g = cfg.global_every
        assert cfg.split_cache and cfg.sliding_window and g
        assert cfg.n_layers % g == 0, "split_cache needs a regular pattern"
        w = self.layer_windows()
        per = w.reshape(-1, g)
        assert (per[:, :-1] > 0).all() and (per[:, -1] == 0).all(), (
            "split_cache expects [local x (g-1), global] groups")
        return cfg.n_layers // g, g - 1

    def init_cache(self, batch: int, seq_len: int) -> dict:
        cfg = self.cfg

        def one_attn(clen):
            def f(_):
                if cfg.mla is not None:
                    return attn.init_mla_cache(cfg, batch, clen)
                return attn.init_gqa_cache(cfg, batch, clen)
            return f

        if (cfg.split_cache and cfg.block_kind == "attn"
                and cfg.sliding_window and cfg.global_every):
            G, nloc = self._split_geometry()
            w = int(cfg.sliding_window)
            return {
                # (G, nloc, ...) ring caches for the windowed layers
                "local": jax.vmap(jax.vmap(one_attn(min(seq_len, w))))(
                    jnp.zeros((G, nloc))),
                # (G, ...) full caches only for the global layers
                "global": jax.vmap(one_attn(seq_len))(jnp.zeros((G,))),
            }

        L = cfg.n_layers
        clen = self.cache_len(seq_len)

        def one(_):
            if cfg.block_kind == "attn":
                return one_attn(clen)(None)
            if cfg.block_kind == "mamba2":
                return m2.init_mamba2_cache(cfg, batch)
            return rk.init_rwkv6_cache(cfg, batch)

        return jax.vmap(one)(jnp.arange(L))

    # ---------------- core ----------------
    def _embed(self, params, batch_in):
        cfg = self.cfg
        if cfg.frontend == "embeddings" and "embeds" in batch_in:
            # stub modality frontend supplies merged patch/frame embeddings
            # at prefill; decode falls through to the token table below
            x = batch_in["embeds"].astype(cfg.dtype)
        else:
            x = jnp.take(params["embed"]["table"], batch_in["tokens"],
                         axis=0)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
        return constrain_batch(x)

    def _layer_body(self, x, layer_p, window, cache, write_pos, positions,
                    mrope_positions):
        """One block; cache may be None. Returns (x, new_cache, aux)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if cfg.block_kind == "attn":
            h = rms_norm(x, layer_p["pre_norm"]["scale"], cfg.norm_eps)
            if cfg.mla is not None:
                a_out, new_cache = attn.mla_forward(
                    layer_p["attn"], h, cfg=cfg, positions=positions,
                    cache=cache, write_pos=write_pos)
            else:
                a_out, new_cache = attn.gqa_forward(
                    layer_p["attn"], h, cfg=cfg, positions=positions,
                    window=window, cache=cache, write_pos=write_pos,
                    mrope_positions=mrope_positions)
            a_out = jax.ad_checkpoint.checkpoint_name(a_out, "attn_out")
            x = x + a_out
            h = rms_norm(x, layer_p["pre_mlp_norm"]["scale"], cfg.norm_eps)
            if cfg.moe:
                # decode steps route droplessly (bit-exact, C=T=batch is
                # small); train/prefill keep GShard capacity semantics
                dropless = cache is not None and h.shape[1] == 1
                fwd = (moe_mod.moe_forward_ep if cfg.moe_ep
                       else moe_mod.moe_forward)
                m_out, aux = fwd(layer_p["moe"], h, cfg, dropless=dropless)
            else:
                m_out = mlp_forward(layer_p["mlp"], h, cfg)
            x = x + m_out
        elif cfg.block_kind == "mamba2":
            h = rms_norm(x, layer_p["pre_norm"]["scale"], cfg.norm_eps)
            if cache is None:
                s_out, new_cache = m2.mamba2_scan(layer_p["ssm"], h, cfg=cfg)
            elif h.shape[1] == 1:
                s_out, new_cache = m2.mamba2_step(layer_p["ssm"], h, cache,
                                                  cfg=cfg)
            else:  # prefill: scan then keep final state
                s_out, new_cache = m2.mamba2_scan(layer_p["ssm"], h,
                                                  cfg=cfg, return_cache=True)
            x = x + s_out
        elif cfg.block_kind == "rwkv6":
            h = rms_norm(x, layer_p["pre_norm"]["scale"], cfg.norm_eps)
            if cache is None:
                t_out, new_cache = rk.rwkv6_scan(layer_p["rwkv"], h, cfg=cfg)
                new_ffn_prev = None
            elif h.shape[1] == 1:
                t_out, tm_cache = rk.rwkv6_step(layer_p["rwkv"], h, cache,
                                                cfg=cfg)
                new_cache = dict(cache, **tm_cache)
            else:
                t_out, tm_cache = rk.rwkv6_scan(
                    layer_p["rwkv"], h, cfg=cfg, x_prev=cache["x_att"],
                    return_cache=True)
                new_cache = dict(cache, **tm_cache)
            x = x + t_out
            h = rms_norm(x, layer_p["pre_mlp_norm"]["scale"], cfg.norm_eps)
            if cache is None:
                f_out = rk.rwkv6_ffn(layer_p["ffn"], h)
            else:
                f_out, ffn_prev = rk.rwkv6_ffn_step(
                    layer_p["ffn"], h, new_cache["x_ffn"])
                new_cache["x_ffn"] = h[:, -1]
            x = x + f_out
        else:
            raise ValueError(cfg.block_kind)
        if cache is None and cfg.block_kind == "attn":
            new_cache = new_cache  # may be None
        return x, (new_cache if cache is not None else None), aux

    def _run_stack(self, params, x, positions, mrope_positions, cache,
                   write_pos, *, remat: bool = False):
        cfg = self.cfg
        if (cache is not None and isinstance(cache, dict)
                and "local" in cache):
            return self._run_stack_split(params, x, positions, cache,
                                         write_pos)
        windows = jnp.asarray(self.layer_windows())

        policies = {
            "nothing": jax.checkpoint_policies.nothing_saveable,
            "attn_out": jax.checkpoint_policies.save_only_these_names(
                "attn_out"),
            "dots": jax.checkpoint_policies.dots_saveable,
        }

        def body(carry, scanned):
            x = carry
            layer_p, window, layer_cache = scanned
            fn = self._layer_body
            if remat:
                fn = jax.checkpoint(fn, policy=policies[cfg.remat_policy])
            x, new_cache, aux = fn(x, layer_p, window, layer_cache,
                                   write_pos, positions, mrope_positions)
            return x, (new_cache, aux)

        scanned = (params["layers"], windows, cache)
        x, (new_cache, auxs) = jax.lax.scan(body, x, scanned)
        return x, new_cache, jnp.sum(auxs)

    def _run_stack_split(self, params, x, positions, cache, write_pos):
        """Split-cache decode/prefill path (§Perf cell C): scan over
        [local x (g-1), global] layer groups; windowed layers carry ring
        caches, global layers full caches."""
        cfg = self.cfg
        G, nloc = self._split_geometry()
        w = int(cfg.sliding_window)

        # reshape the stacked layer params (L, ...) -> (G, g, ...)
        grouped = jax.tree.map(
            lambda p: p.reshape((G, nloc + 1) + p.shape[1:]),
            params["layers"])
        p_local = jax.tree.map(lambda p: p[:, :nloc], grouped)
        p_global = jax.tree.map(lambda p: p[:, nloc], grouped)

        def local_body(carry, scanned):
            x = carry
            layer_p, layer_cache = scanned
            x, new_cache, _ = self._layer_body(
                x, layer_p, jnp.int32(w), layer_cache, write_pos,
                positions, None)
            return x, new_cache

        def group_body(carry, scanned):
            x = carry
            pl, pg, cl, cg = scanned
            x, new_local = jax.lax.scan(local_body, x, (pl, cl))
            x, new_global, _ = self._layer_body(
                x, pg, jnp.int32(0), cg, write_pos, positions, None)
            return x, (new_local, new_global)

        x, (new_local, new_global) = jax.lax.scan(
            group_body, x,
            (p_local, p_global, cache["local"], cache["global"]))
        return x, {"local": new_local, "global": new_global}, jnp.zeros(
            (), jnp.float32)

    # ---------------- public entry points ----------------
    def forward(self, params, batch_in, *, remat: bool = False):
        """Teacher-forced logits. batch_in: {'tokens' (B,S) | 'embeds',
        optional 'positions', 'mrope_positions'}."""
        cfg = self.cfg
        x = self._embed(params, batch_in)
        B, S = x.shape[0], x.shape[1]
        positions = batch_in.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, _, aux = self._run_stack(
            params, x, positions, batch_in.get("mrope_positions"),
            None, None, remat=remat)
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        logits = self._unembed(params, x)
        return logits, aux

    def _unembed(self, params, x):
        cfg = self.cfg
        x = constrain_batch(x)
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["table"].T
        else:
            logits = x @ params["unembed"]["table"]
        if cfg.padded_vocab != cfg.vocab_size:
            # mask pad columns to -inf: softmax/argmax never select them,
            # and the mask fuses into the matmul epilogue
            pad = jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, logits.ndim - 1) >= cfg.vocab_size
            logits = jnp.where(pad, jnp.asarray(-2.0 ** 20, logits.dtype),
                               logits)
        # keep (B, S, V) batch-sharded: its cotangent is the largest f32
        # buffer in the backward pass
        return constrain_batch(logits)

    def loss(self, params, batch_in, *, remat: bool = False):
        logits, aux = self.forward(params, batch_in, remat=remat)
        ce, parts = cross_entropy_loss(logits, batch_in["targets"])
        return ce + aux, dict(parts, aux=aux)

    def prefill(self, params, batch_in, cache):
        """Populate caches; returns (last-token logits, cache)."""
        cfg = self.cfg
        x = self._embed(params, batch_in)
        B, S = x.shape[0], x.shape[1]
        positions = batch_in.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, new_cache, _ = self._run_stack(
            params, x, positions, batch_in.get("mrope_positions"),
            cache, jnp.int32(0))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        return self._unembed(params, x[:, -1:]), new_cache

    def decode_step(self, params, token_in, pos, cache):
        """One decode step. token_in: {'tokens' (B,1) | 'embeds' (B,1,d)};
        pos: scalar int32 absolute position. Returns (logits (B,1,V),
        new_cache)."""
        cfg = self.cfg
        x = self._embed(params, token_in)
        B = x.shape[0]
        positions = jnp.broadcast_to(
            jnp.asarray(pos, jnp.int32)[None, None], (B, 1))
        mrope = token_in.get("mrope_positions")
        x, new_cache, _ = self._run_stack(
            params, x, positions, mrope, cache, jnp.asarray(pos, jnp.int32))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        return self._unembed(params, x), new_cache
