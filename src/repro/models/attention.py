"""Attention blocks: GQA/MQA (+SWA, local:global, M-RoPE, cross) and MLA.

One code path serves train, prefill and decode:
  * train/prefill: full (B,S) sequence, causal (+ window) mask, returns
    the updated KV cache when one is passed.
  * decode: x is (B,1,d); K/V are written at ``write_pos`` into the cache
    (ring-buffer slot ``pos % cache_len``) and attention runs over the
    cache with validity masks derived from per-slot position ids — this
    uniformly supports full caches and sliding-window ring caches (the
    sub-quadratic decode path for mixtral/gemma3 at 500k context).

Caches are dicts of arrays so they shard under pjit (seq -> model axis by
default; see repro.distributed.partition).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.partition import constrain_batch, constrain_seq
from repro.models.common import (
    TransformerConfig, apply_mrope, apply_rope, dense_init, make_rope,
    rms_norm,
)

__all__ = [
    "init_gqa", "gqa_forward", "init_gqa_cache",
    "init_mla", "mla_forward", "init_mla_cache",
]

_NEG_INF = -2.0 ** 30


# --------------------------------------------------------------------------
# GQA family
# --------------------------------------------------------------------------

def init_gqa(key, cfg: TransformerConfig, *, bias: bool = False) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, (d, cfg.n_heads * hd)),
        "wk": dense_init(kk, (d, cfg.n_kv_heads * hd)),
        "wv": dense_init(kv, (d, cfg.n_kv_heads * hd)),
        "wo": dense_init(ko, (cfg.n_heads * hd, d)),
    }
    if bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,))
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,))
        p["bo"] = jnp.zeros((d,))
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.zeros((hd,))}
        p["k_norm"] = {"scale": jnp.zeros((hd,))}
    return p


def init_gqa_cache(cfg: TransformerConfig, batch: int, cache_len: int,
                   dtype=None):
    hd = cfg.resolved_head_dim
    dtype = dtype or cfg.dtype
    return {
        "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dtype),
        "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
    }


def _split_heads(x, n_heads, hd):
    B, S = x.shape[0], x.shape[1]
    return x.reshape(B, S, n_heads, hd)


# Query-chunk length for the flash-style outer loop. Bounds the score
# buffer at (B, H, CHUNK, T) f32 instead of (B, H, S, T) — the difference
# between 536 MB and 137 GB per device on the prefill_32k cells.
SDPA_CHUNK = 1024


def _sdpa_block(q, k, v, mask, q_group: int, scores_bf16: bool = False):
    """q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd); mask: (B,1,S,T)."""
    B, S, Hq, hd = q.shape
    g = q_group
    Hkv = k.shape[2]
    qg = q.reshape(B, S, Hkv, g, hd)
    score_t = jnp.bfloat16 if scores_bf16 else jnp.float32
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=score_t)
    scores = scores / np.asarray(np.sqrt(hd), score_t)
    scores = scores + mask[:, :, None].astype(score_t)  # (B,1,1,S,T)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, Hq, hd).astype(q.dtype)


def sdpa(q, k, v, pos_q, pos_k, *, causal, window, q_group,
         chunk: int = SDPA_CHUNK, scores_bf16: bool = False):
    """Chunked SDPA: masks are built PER QUERY CHUNK (never a full (S,T)
    mask in memory), and the score buffer is bounded by the chunk size."""
    B, S, Hq, hd = q.shape
    if S <= chunk or S % chunk != 0:
        mask = _full_mask(pos_q, pos_k, causal=causal, window=window)
        return _sdpa_block(q, k, v, mask, q_group, scores_bf16)
    nc = S // chunk
    qs = jnp.moveaxis(q.reshape(B, nc, chunk, Hq, hd), 1, 0)
    ps = jnp.moveaxis(pos_q.reshape(B, nc, chunk), 1, 0)

    def body(_, qp):
        q_c, p_c = qp
        mask = _full_mask(p_c, pos_k, causal=causal, window=window)
        return None, _sdpa_block(q_c, k, v, mask, q_group, scores_bf16)

    _, outs = jax.lax.scan(body, None, (qs, ps))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq, hd)


def _full_mask(positions_q, positions_k, *, causal: bool, window):
    """(B,S),(B,T) -> additive mask (B,1,S,T).

    ``window`` may be None (full), a Python int (static SWA), or a traced
    int32 scalar from the per-layer schedule where 0 means "full
    attention" (gemma3's 5:1 local:global inside one lax.scan body).
    """
    pq = positions_q[:, None, :, None]  # (B,1,S,1)
    pk = positions_k[:, None, None, :]  # (B,1,1,T)
    ok = jnp.ones(jnp.broadcast_shapes(pq.shape, pk.shape), bool)
    if causal:
        ok &= pk <= pq
    if window is not None:
        if isinstance(window, (int, np.integer)):
            if window > 0:
                ok &= pk > pq - window
        else:  # traced: 0 disables the window dynamically
            ok &= jnp.where(window > 0, pk > pq - window, True)
    ok &= pk >= 0  # invalid (unwritten) cache slots carry pos -1
    return jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)


def gqa_forward(
    p: dict,
    x,
    *,
    cfg: TransformerConfig,
    positions,                 # (B, S) int32 absolute positions of x
    window: int | None = None,
    causal: bool = True,
    cache: dict | None = None,
    write_pos=None,            # scalar int32: decode slot base (pos of x[:,0])
    mrope_positions=None,      # (3, B, S) when cfg.mrope
    kv_x=None,                 # cross-attention source (B, T, d)
    kv_positions=None,
):
    """Returns (out (B,S,d), new_cache)."""
    hd = cfg.resolved_head_dim
    B, S = x.shape[0], x.shape[1]
    q = _split_heads(x @ p["wq"] + p.get("bq", 0.0), cfg.n_heads, hd)
    src = kv_x if kv_x is not None else x
    k = _split_heads(src @ p["wk"], cfg.n_kv_heads, hd)
    v = _split_heads(src @ p["wv"] + p.get("bv", 0.0), cfg.n_kv_heads, hd)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)

    if kv_x is None:  # self-attention: rotary on q and k
        if cfg.mrope and mrope_positions is not None:
            q = apply_mrope(q, mrope_positions, hd, cfg.rope_theta)
            k = apply_mrope(k, mrope_positions, hd, cfg.rope_theta)
        elif not cfg.attn_bias:  # whisper uses learned abs pos, no rope
            cos, sin = make_rope(positions, hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)

    new_cache = cache
    if cache is not None and kv_x is None:
        cache_len = cache["k"].shape[1]
        if write_pos is None:
            raise ValueError("cache updates require write_pos")
        if S > 1:
            # Prefill: attend over the IN-CALL K/V (ring eviction must not
            # shadow tokens still inside their window), then persist only
            # the last cache_len entries into the ring.
            n_keep = min(S, cache_len)
            tail = write_pos + S - n_keep + jnp.arange(n_keep,
                                                       dtype=jnp.int32)
            slots = tail % cache_len
            k_c = cache["k"].at[:, slots].set(
                k[:, S - n_keep:].astype(cache["k"].dtype))
            v_c = cache["v"].at[:, slots].set(
                v[:, S - n_keep:].astype(cache["v"].dtype))
            slot_pos = cache["slot_pos"].at[slots].set(tail)
            new_cache = {"k": k_c, "v": v_c, "slot_pos": slot_pos}
            k_att, v_att = k, v
            pos_k = positions
        else:
            # Decode: write this token's slot, attend over the ring.
            slots = (write_pos + jnp.arange(S, dtype=jnp.int32)) % cache_len
            k_c = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
            v_c = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
            slot_pos = cache["slot_pos"].at[slots].set(
                write_pos + jnp.arange(S, dtype=jnp.int32))
            new_cache = {"k": k_c, "v": v_c, "slot_pos": slot_pos}
            k_att, v_att = k_c, v_c
            pos_k = jnp.broadcast_to(slot_pos[None], (B, cache_len))
    else:
        k_att, v_att = k, v
        pos_k = (kv_positions if kv_positions is not None else
                 (positions if kv_x is None else
                  jnp.broadcast_to(
                      jnp.arange(src.shape[1], dtype=jnp.int32)[None],
                      (B, src.shape[1]))))

    if cfg.seq_parallel_attn and cache is None and S > 1:
        # context parallelism: queries sharded over `model`, K/V gathered.
        # Avoids the partial-head resharding all-reduces when n_heads
        # doesn't divide the TP axis (DESIGN.md §Perf, llama4 cell).
        q = constrain_seq(q, 1)
        mask_src = constrain_seq(positions, 1)
        out = _sdpa_block(
            q, k_att.astype(q.dtype), v_att.astype(q.dtype),
            _full_mask(mask_src, pos_k, causal=causal and kv_x is None,
                       window=window), cfg.q_group, cfg.attn_scores_bf16)
        out = constrain_batch(out)  # gather S back before the TP wo
    else:
        out = sdpa(q, k_att.astype(q.dtype), v_att.astype(q.dtype),
                   positions, pos_k, causal=causal and kv_x is None,
                   window=window, q_group=cfg.q_group,
                   scores_bf16=cfg.attn_scores_bf16)
    out = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"] + p.get("bo", 0.0)
    return out.astype(x.dtype), new_cache


# --------------------------------------------------------------------------
# MLA (Multi-head Latent Attention) — minicpm3 family
# --------------------------------------------------------------------------

def init_mla(key, cfg: TransformerConfig) -> dict:
    m = cfg.mla
    d = cfg.d_model
    H = cfg.n_heads
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        "wq_a": dense_init(k1, (d, m.q_lora_rank)),
        "q_a_norm": {"scale": jnp.zeros((m.q_lora_rank,))},
        "wq_b": dense_init(k2, (m.q_lora_rank, H * qd)),
        # joint latent: compressed kv + decoupled rope key
        "wkv_a": dense_init(k3, (d, m.kv_lora_rank + m.rope_head_dim)),
        "kv_a_norm": {"scale": jnp.zeros((m.kv_lora_rank,))},
        "wkv_b": dense_init(
            k4, (m.kv_lora_rank, H * (m.nope_head_dim + m.v_head_dim))),
        "wo": dense_init(k5, (H * m.v_head_dim, d)),
    }


def init_mla_cache(cfg: TransformerConfig, batch: int, cache_len: int,
                   dtype=None):
    m = cfg.mla
    dtype = dtype or cfg.dtype
    return {
        "ckv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, m.rope_head_dim), dtype),
        "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
    }


def mla_forward(
    p: dict,
    x,
    *,
    cfg: TransformerConfig,
    positions,
    cache: dict | None = None,
    write_pos=None,
    window: int | None = None,
):
    """MLA with latent cache: only (ckv, k_rope) are cached — the paper's
    memory-dominance lens applied to decode (cache bytes shrink ~8x vs MHA).
    """
    m = cfg.mla
    H = cfg.n_heads
    B, S = x.shape[0], x.shape[1]

    q = x @ p["wq_a"]
    q = rms_norm(q, p["q_a_norm"]["scale"], cfg.norm_eps)
    q = (q @ p["wq_b"]).reshape(B, S, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]

    kv = x @ p["wkv_a"]  # (B,S, kv_lora + rope)
    ckv = rms_norm(kv[..., :m.kv_lora_rank], p["kv_a_norm"]["scale"],
                   cfg.norm_eps)
    k_rope_in = kv[..., m.kv_lora_rank:]  # (B,S,rope_dim) single shared head

    cos, sin = make_rope(positions, m.rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope_in[:, :, None, :], cos, sin)[:, :, 0]

    new_cache = cache
    if cache is not None:
        cache_len = cache["ckv"].shape[1]
        slots = (write_pos + jnp.arange(S, dtype=jnp.int32)) % cache_len
        ckv_c = cache["ckv"].at[:, slots].set(ckv.astype(cache["ckv"].dtype))
        kr_c = cache["k_rope"].at[:, slots].set(
            k_rope.astype(cache["k_rope"].dtype))
        slot_pos = cache["slot_pos"].at[slots].set(
            write_pos + jnp.arange(S, dtype=jnp.int32))
        new_cache = {"ckv": ckv_c, "k_rope": kr_c, "slot_pos": slot_pos}
        ckv_att, kr_att = ckv_c.astype(x.dtype), kr_c.astype(x.dtype)
        pos_k = jnp.broadcast_to(slot_pos[None], (B, cache_len))
    else:
        ckv_att, kr_att = ckv, k_rope
        pos_k = positions

    # expand latent -> per-head K_nope and V
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H,
                               m.nope_head_dim + m.v_head_dim)
    k_nope = jnp.einsum("btc,chd->bthd", ckv_att, wkv_b[..., :m.nope_head_dim])
    v = jnp.einsum("btc,chd->bthd", ckv_att, wkv_b[..., m.nope_head_dim:])

    scale = 1.0 / np.sqrt(m.nope_head_dim + m.rope_head_dim)

    def block(qn_c, qr_c, pos_c):
        scores = (jnp.einsum("bshd,bthd->bhst", qn_c, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshd,btd->bhst", qr_c, kr_att,
                               preferred_element_type=jnp.float32)) * scale
        mask = _full_mask(pos_c, pos_k, causal=True, window=window)
        scores = scores + mask
        w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(
            x.dtype)
        return jnp.einsum("bhst,bthd->bshd", w, v,
                          preferred_element_type=jnp.float32).astype(x.dtype)

    chunk = SDPA_CHUNK
    if S <= chunk or S % chunk != 0:
        out = block(q_nope, q_rope, positions)
    else:
        nc = S // chunk
        qns = jnp.moveaxis(
            q_nope.reshape(B, nc, chunk, H, m.nope_head_dim), 1, 0)
        qrs = jnp.moveaxis(
            q_rope.reshape(B, nc, chunk, H, m.rope_head_dim), 1, 0)
        pss = jnp.moveaxis(positions.reshape(B, nc, chunk), 1, 0)

        def body(_, args):
            return None, block(*args)

        _, outs = jax.lax.scan(body, None, (qns, qrs, pss))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, m.v_head_dim)
    out = out.reshape(B, S, H * m.v_head_dim) @ p["wo"]
    return out.astype(x.dtype), new_cache
