"""Zamba2 hybrid: Mamba2 backbone + one SHARED attention block.

38 Mamba2 layers; a single weight-shared (attention + MLP) block is
invoked every 6 layers (after layers 5, 11, 17, 23, 29, 35) with a
per-invocation LoRA delta on the QKV projections — the Zamba2 trick that
buys attention quality at ~1/6 the attention parameter cost. Simplified
vs the HF checkpoint (no embedding-concat input to the shared block);
noted in DESIGN.md §Arch-applicability.

Decode state: 38 Mamba (conv, ssm) states + 6 shared-attention KV caches
(one per invocation). The backbone is O(1) in context, so zamba2 runs the
long_500k cell; only the 6 shared-attn caches scale with context.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.partition import constrain_batch
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models.common import (
    TransformerConfig, cross_entropy_loss, dense_init, rms_norm,
)
from repro.models.transformer import init_mlp, mlp_forward

__all__ = ["Zamba2LM"]

LORA_RANK = 64


@dataclasses.dataclass(frozen=True)
class Zamba2LM:
    cfg: TransformerConfig

    @property
    def shared_layers(self) -> tuple[int, ...]:
        k = self.cfg.shared_attn_every or 6
        return tuple(range(k - 1, self.cfg.n_layers, k))

    # ------------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        layer_keys = jax.random.split(ks[0], cfg.n_layers)

        def mamba_layer(k):
            return {"pre_norm": {"scale": jnp.zeros((cfg.d_model,))},
                    "ssm": m2.init_mamba2(k, cfg)}

        n_inv = len(self.shared_layers)
        hd = cfg.resolved_head_dim
        lora_keys = jax.random.split(ks[1], n_inv)

        def lora(k):
            k1, k2 = jax.random.split(k)
            return {
                "lora_a": dense_init(k1, (cfg.d_model, LORA_RANK)),
                "lora_b": jnp.zeros((LORA_RANK, cfg.n_heads * hd)),
            }

        params = {
            "embed": {"table": dense_init(ks[2],
                                          (cfg.vocab_size, cfg.d_model))},
            "layers": jax.vmap(mamba_layer)(layer_keys),
            "shared": {
                "pre_norm": {"scale": jnp.zeros((cfg.d_model,))},
                "attn": attn.init_gqa(ks[3], cfg),
                "pre_mlp_norm": {"scale": jnp.zeros((cfg.d_model,))},
                "mlp": init_mlp(ks[4], cfg),
            },
            "lora": jax.vmap(lora)(lora_keys),
            "final_norm": {"scale": jnp.zeros((cfg.d_model,))},
        }
        return jax.tree.map(lambda x: x.astype(cfg.dtype), params)

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int) -> dict:
        cfg = self.cfg
        mamba = jax.vmap(lambda _: m2.init_mamba2_cache(cfg, batch))(
            jnp.arange(cfg.n_layers))
        attn_c = jax.vmap(
            lambda _: attn.init_gqa_cache(cfg, batch, seq_len))(
            jnp.arange(len(self.shared_layers)))
        return {"mamba": mamba, "attn": attn_c}

    def _shared_block(self, params, x, inv_idx, positions, cache,
                      write_pos):
        cfg = self.cfg
        lora = jax.tree.map(lambda a: a[inv_idx], params["lora"])
        sp = params["shared"]
        # LoRA delta on the fused Q projection for this invocation
        wq_eff = sp["attn"]["wq"] + (
            lora["lora_a"] @ lora["lora_b"]).astype(sp["attn"]["wq"].dtype)
        attn_p = dict(sp["attn"], wq=wq_eff)
        h = rms_norm(x, sp["pre_norm"]["scale"], cfg.norm_eps)
        a, new_cache = attn.gqa_forward(
            attn_p, h, cfg=cfg, positions=positions, cache=cache,
            write_pos=write_pos)
        x = x + a
        h = rms_norm(x, sp["pre_mlp_norm"]["scale"], cfg.norm_eps)
        x = x + mlp_forward(sp["mlp"], h, cfg)
        return x, new_cache

    def _run(self, params, x, positions, cache, write_pos,
             *, remat: bool = False):
        cfg = self.cfg
        shared_at = set(self.shared_layers)
        new_mamba = []
        new_attn = []
        inv = 0

        def mamba_fwd(lp, h):
            out, _ = m2.mamba2_scan(lp["ssm"], h, cfg=cfg)
            return out

        if remat:
            mamba_fwd = jax.checkpoint(
                mamba_fwd, policy=jax.checkpoint_policies.nothing_saveable)
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            h = rms_norm(x, lp["pre_norm"]["scale"], cfg.norm_eps)
            if cache is None:
                s_out, nc = mamba_fwd(lp, h), None
            elif x.shape[1] == 1:
                mc = jax.tree.map(lambda a: a[li], cache["mamba"])
                s_out, nc = m2.mamba2_step(lp["ssm"], h, mc, cfg=cfg)
            else:
                s_out, nc = m2.mamba2_scan(lp["ssm"], h, cfg=cfg,
                                           return_cache=True)
            x = x + s_out
            if cache is not None:
                new_mamba.append(nc)
            if li in shared_at:
                ac = (None if cache is None else
                      jax.tree.map(lambda a: a[inv], cache["attn"]))
                x, nac = self._shared_block(params, x, inv, positions, ac,
                                            write_pos)
                if cache is not None:
                    new_attn.append(nac)
                inv += 1
        new_cache = None
        if cache is not None:
            stack = lambda items: jax.tree.map(
                lambda *xs: jnp.stack(xs), *items)
            new_cache = {"mamba": stack(new_mamba),
                         "attn": stack(new_attn)}
        return x, new_cache

    # ---------------- public API ----------------
    def forward(self, params, batch_in, *, remat: bool = False):
        cfg = self.cfg
        tokens = batch_in["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, _ = self._run(params, x, positions, None, None, remat=remat)
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        x = constrain_batch(x)
        logits = constrain_batch(x @ params["embed"]["table"].T)  # tied
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch_in, *, remat: bool = False):
        logits, aux = self.forward(params, batch_in, remat=remat)
        ce, parts = cross_entropy_loss(logits, batch_in["targets"])
        return ce + aux, dict(parts, aux=aux)

    def prefill(self, params, batch_in, cache):
        cfg = self.cfg
        tokens = batch_in["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x, new_cache = self._run(params, x, positions, cache, jnp.int32(0))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        return (x[:, -1:] @ params["embed"]["table"].T), new_cache

    def decode_step(self, params, token_in, pos, cache):
        cfg = self.cfg
        tokens = token_in["tokens"]
        B = tokens.shape[0]
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
        positions = jnp.broadcast_to(
            jnp.asarray(pos, jnp.int32)[None, None], (B, 1))
        x, new_cache = self._run(params, x, positions, cache,
                                 jnp.asarray(pos, jnp.int32))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        return (x @ params["embed"]["table"].T), new_cache
