"""LM model zoo: shared layers + family blocks + assembled models."""

from repro.models import (  # noqa: F401
    attention,
    common,
    mamba2,
    moe,
    rwkv6,
    transformer,
    whisper,
    zamba2,
)
