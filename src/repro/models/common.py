"""Shared building blocks + config schema for the LM model zoo.

Ten assigned architectures share one parameterized decoder stack
(``repro.models.transformer``) plus family-specific blocks (MoE, MLA,
Mamba2, RWKV6, enc-dec). Parameters are plain nested dicts; every leaf
has an entry in the LOGICAL-AXIS registry below, which the partitioner
(repro.distributed.partition) resolves to mesh PartitionSpecs. Models are
pure functions: ``init(key, cfg)`` / ``apply(params, batch, cfg)``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MoEConfig", "MLAConfig", "SSMConfig", "RWKVConfig", "TransformerConfig",
    "rms_norm", "layer_norm", "make_rope", "apply_rope", "apply_mrope",
    "cross_entropy_loss", "AXES", "axes_of", "dense_init",
]

# --------------------------------------------------------------------------
# Config schema
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    shared_expert: bool = False      # llama4: always-on shared expert
    router_aux_weight: float = 0.01  # load-balance loss


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    rope_head_dim: int = 32
    nope_head_dim: int = 64
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128  # SSD chunked-scan block length


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    gate_lora: int = 32


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    # --- attention pattern ---
    block_kind: str = "attn"                  # attn | mamba2 | rwkv6
    sliding_window: int | None = None         # SWA width for local layers
    global_every: int | None = None           # every k-th layer is global
    rope_theta: float = 10_000.0
    mrope: bool = False                       # qwen2-vl 3D rope
    shared_attn_every: int | None = None      # zamba2 shared block period
    # --- family-specific blocks ---
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    # --- misc ---
    qk_norm: bool = False                     # gemma3-style q/k RMSNorm
    attn_bias: bool = False                   # whisper uses biased projections
    mlp_kind: str = "swiglu"                  # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    embed_scale: bool = False                 # gemma: x *= sqrt(d)
    tie_embeddings: bool = False
    frontend: str = "tokens"                  # tokens | embeddings
    dtype: Any = jnp.bfloat16
    # long-context capability flag (set for SWA/SSM/hybrid archs) — decides
    # whether the long_500k cell runs (DESIGN.md §4)
    subquadratic: bool = False
    # §Perf lever: context-parallel attention (queries sharded over the
    # model axis). Used when n_heads doesn't divide the TP axis (llama4).
    seq_parallel_attn: bool = False
    # §Perf lever (cell A forward path): explicit shard_map expert
    # parallelism — experts on data-axis rows, one all_to_all each way,
    # ffn TP over model. Requires n_experts % data-axis == 0 (llama4);
    # ineligible configs fall back to the dense dispatch transparently.
    moe_ep: bool = False
    # §Perf lever (cell C): split decode caches by layer kind — windowed
    # layers get ring caches of `sliding_window` slots, only the global
    # layers keep full-context caches. Without it gemma3's 5:1 local:
    # global pattern allocates 48 full 500k-token caches (49 GiB/device —
    # does not fit); with it, 40 of 48 shrink to 1024 slots. Requires a
    # regular pattern: L % global_every == 0, globals at k*global_every-1.
    split_cache: bool = False
    # §Perf lever: store attention scores in bf16 (T5X-style attn-logits-
    # in-bf16): halves the O(S*T) score traffic, softmax still reduces in
    # f32 inside the fusion. Quantizes logits to ~3 decimal digits.
    attn_scores_bf16: bool = False
    # §Perf lever: activation-checkpoint policy for the layer scan.
    #   "nothing"  — recompute everything in bwd (min live memory)
    #   "attn_out" — save attention outputs (skips recomputing the O(S^2)
    #                score matmuls in bwd; +16 MB/layer/microbatch live)
    #   "dots"     — XLA dots_saveable (max save, min recompute)
    remat_policy: str = "nothing"
    # §Perf lever: pad the embedding/unembedding vocab dim to a multiple
    # (Megatron-style) so it shards over the model axis. Archs whose vocab
    # doesn't divide the 16-way axis (granite-3: 49155, minicpm3: 73448,
    # whisper: 51866) otherwise REPLICATE every (B,S,V) f32 logits/softmax
    # buffer per device. Padded logits are masked to -inf in _unembed.
    vocab_pad_to: int | None = None

    @property
    def padded_vocab(self) -> int:
        if not self.vocab_pad_to:
            return self.vocab_size
        m = self.vocab_pad_to
        return -(-self.vocab_size // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (drives 6ND roofline maths)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.block_kind == "rwkv6" and self.rwkv:
            att = d * d * 4 + d * (self.rwkv.decay_lora * 2
                                   + self.rwkv.gate_lora * 2) + 6 * d
        elif self.mla:
            m = self.mla
            att = (d * m.q_lora_rank
                   + m.q_lora_rank * self.n_heads
                   * (m.nope_head_dim + m.rope_head_dim)
                   + d * (m.kv_lora_rank + m.rope_head_dim)
                   + m.kv_lora_rank * self.n_heads
                   * (m.nope_head_dim + m.v_head_dim)
                   + self.n_heads * m.v_head_dim * d)
        else:
            att = d * (self.n_heads * hd) * 2 + d * (
                self.n_kv_heads * hd) * 2
        if self.moe:
            gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
            mlp = self.moe.n_experts * gates * d * f + d * self.moe.n_experts
            if self.moe.shared_expert:
                mlp += gates * d * f
        else:
            gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
            mlp = gates * d * f
        if self.block_kind == "mamba2" and self.ssm:
            din = self.ssm.expand * d
            nh = din // self.ssm.head_dim
            att = 0
            mlp_ssm = (d * (2 * din + 2 * self.ssm.d_state + nh)
                       + din * d + din * self.ssm.d_conv + 2 * nh)
            mlp = mlp_ssm + mlp  # zamba-style models add their own MLP? no:
            mlp = mlp_ssm if self.moe is None and self.mlp_kind == "none" \
                else mlp_ssm + gates * d * f
        return emb + L * (att + mlp + 2 * d) + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        gates = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        total = self.param_count()
        expert_params = self.moe.n_experts * gates * d * f * self.n_layers
        active_expert = self.moe.top_k * gates * d * f * self.n_layers
        return total - expert_params + active_expert


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0) -> jnp.ndarray:
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std)


def _rms_norm_fwd_math(x, scale, eps):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1,
                                 keepdims=True) + eps)
    y = xf * inv * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm: f32 math INSIDE, input-dtype tensors at the boundaries.

    The custom VJP keeps the backward's boundary cotangents in the input
    dtype (bf16): with the default VJP the f32 upcast chain leaks f32
    residual-stream cotangents across fusion boundaries — measured as THE
    dominant HBM-traffic term in the train cells (§Perf cell B, hypothesis
    B3: ~4.4 TB/device/step of f32[.,S,d] fusion traffic on granite-3).
    Numerics are unchanged: every internal reduction still runs in f32.
    """
    return _rms_norm_fwd_math(x, scale, eps)


def _rms_norm_fwd(x, scale, eps):
    return _rms_norm_fwd_math(x, scale, eps), (x, scale)


def _rms_norm_bwd(eps, res, g):
    x, scale = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1,
                                 keepdims=True) + eps)
    xn = xf * inv
    a = 1.0 + scale.astype(jnp.float32)
    ag = a * gf
    dscale = jnp.sum((gf * xn).reshape(-1, x.shape[-1]), axis=0)
    dx = inv * (ag - xn * jnp.mean(ag * xn, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(dtype)


def make_rope(positions, head_dim: int, theta: float):
    """positions: (..., S) int -> (cos, sin) of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def apply_mrope(x, positions3, head_dim: int, theta: float,
                sections=(1, 1, 2)):
    """Qwen2-VL multimodal RoPE: positions3 (3, B, S) = (t, h, w) ids.

    head_dim//2 rotary freqs are split across the three position streams in
    ratio ``sections`` (temporal gets the low-frequency end).
    """
    half = head_dim // 2
    total = sum(sections)
    bounds = np.cumsum([0] + [half * s // total for s in sections])
    bounds[-1] = half
    cos_parts, sin_parts = [], []
    for i in range(3):
        lo, hi = int(bounds[i]), int(bounds[i + 1])
        freqs = 1.0 / (theta ** (jnp.arange(lo, hi, dtype=jnp.float32)
                                 / half))
        ang = positions3[i].astype(jnp.float32)[..., None] * freqs
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
    cos = jnp.concatenate(cos_parts, axis=-1)  # (B, S, half)
    sin = jnp.concatenate(sin_parts, axis=-1)
    return apply_rope(x, cos, sin)


def cross_entropy_loss(logits, targets, z_weight: float = 1e-4):
    """Token-mean CE + z-loss (stabilizes the sharded softmax).

    The gold logit is extracted with a one-hot einsum, NOT take_along_axis:
    the gather's backward is a scatter over the vocab axis, which GSPMD
    replicates (a 12 GiB/device f32 buffer at batch 256 x 4k x 49k vocab).
    The einsum's backward is elementwise and keeps the batch sharding.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("...v,...v->...", logits, onehot)
    ce = jnp.mean(logz - gold)
    zl = z_weight * jnp.mean(jnp.square(logz))
    return ce + zl, {"ce": ce, "z_loss": zl}


# --------------------------------------------------------------------------
# Logical-axis registry: leaf path suffix -> logical axes (no stacked dim;
# the partitioner prepends "layers" when leaf rank == len(axes)+1).
# --------------------------------------------------------------------------

AXES: dict[str, tuple[str | None, ...]] = {
    # embeddings / head
    "embed/table": ("vocab", "embed"),
    "unembed/table": ("embed", "vocab"),
    "final_norm/scale": (None,),
    # attention
    "attn/wq": ("embed", "heads"),
    "attn/wk": ("embed", "kv"),
    "attn/wv": ("embed", "kv"),
    "attn/wo": ("heads", "embed"),
    "attn/q_norm/scale": (None,),
    "attn/k_norm/scale": (None,),
    # MLA
    "attn/wq_a": ("embed", None),
    "attn/wq_b": (None, "heads"),
    "attn/wkv_a": ("embed", None),
    "attn/wkv_b": (None, "heads"),
    "attn/q_a_norm/scale": (None,),
    "attn/kv_a_norm/scale": (None,),
    # dense MLP
    "mlp/w_gate": ("embed", "ffn"),
    "mlp/w_up": ("embed", "ffn"),
    "mlp/w_down": ("ffn", "embed"),
    # MoE. The router is tiny (d x E) — replicate it: sharding its embed
    # dim over data makes the routing matmul contract a sharded dim and
    # all-reduce (T, E) f32 per layer (§Perf cell A, hypothesis A5).
    "moe/router": (None, None),
    "moe/w_gate": ("expert", "embed", "ffn"),
    "moe/w_up": ("expert", "embed", "ffn"),
    "moe/w_down": ("expert", "ffn", "embed"),
    "moe/shared_w_gate": ("embed", "ffn"),
    "moe/shared_w_up": ("embed", "ffn"),
    "moe/shared_w_down": ("ffn", "embed"),
    # mamba2
    "ssm/in_proj": ("embed", "ffn"),
    "ssm/out_proj": ("ffn", "embed"),
    "ssm/conv_w": (None, "ffn"),
    "ssm/A_log": ("ffn",),
    "ssm/D": ("ffn",),
    "ssm/dt_bias": ("ffn",),
    "ssm/norm/scale": ("ffn",),
    # rwkv6
    "rwkv/wr": ("embed", "heads"),
    "rwkv/wk": ("embed", "heads"),
    "rwkv/wv": ("embed", "heads"),
    "rwkv/wg": ("embed", "heads"),
    "rwkv/wo": ("heads", "embed"),
    "rwkv/decay_a": ("embed", None),
    "rwkv/decay_b": (None, "heads"),
    "rwkv/mix": (None, "embed"),
    "rwkv/u": ("heads",),
    "rwkv/ln_x/scale": ("heads",),
    "rwkv/wk_mlp": ("embed", "ffn"),
    "rwkv/wv_mlp": ("ffn", "embed"),
    "rwkv/wr_mlp": ("embed", None),
    # norms inside blocks
    "pre_norm/scale": (None,),
    "post_norm/scale": (None,),
    "pre_mlp_norm/scale": (None,),
    # layer norms with bias (whisper)
    "pre_norm/bias": (None,),
    "post_norm/bias": (None,),
    "final_norm/bias": (None,),
    # whisper cross-attn
    "xattn/wq": ("embed", "heads"),
    "xattn/wk": ("embed", "kv"),
    "xattn/wv": ("embed", "kv"),
    "xattn/wo": ("heads", "embed"),
    "pre_xattn_norm/scale": (None,),
    "pre_xattn_norm/bias": (None,),
    # whisper biases
    "attn/bq": ("heads",),
    "attn/bv": ("kv",),
    "attn/bo": (None,),
    "xattn/bq": ("heads",),
    "xattn/bv": ("kv",),
    "xattn/bo": (None,),
    "mlp/b_up": ("ffn",),
    "mlp/b_down": (None,),
    # positional embeddings (whisper)
    "pos_embed/table": (None, "embed"),
    # zamba2 lora adapters on the shared block
    "lora_a": ("embed", None),
    "lora_b": (None, "heads"),
}


def axes_of(path: str, leaf) -> tuple[str | None, ...]:
    """Resolve logical axes for a leaf by longest-suffix match in AXES."""
    parts = path.split("/")
    for take in range(min(3, len(parts)), 0, -1):
        suffix = "/".join(parts[-take:])
        if suffix in AXES:
            axes = AXES[suffix]
            if leaf.ndim == len(axes) + 1:
                return ("layers",) + tuple(axes)
            if leaf.ndim == len(axes):
                return tuple(axes)
    # sane default: replicate
    return (None,) * leaf.ndim
