"""Mixture-of-Experts layer with sort-based (gather) dispatch.

DESIGN.md §4: top-k expert gating is the architectural analogue of
Cerebra-H's event-gated weight-row fetch — only routed experts' weights
participate, so compiled FLOPs track *active* parameters. We therefore use
capacity-bounded gather dispatch (GShard-style, like MaxText) rather than
dense one-hot einsum: HLO FLOPs stay ~= top_k/n_experts of the dense cost,
which is what makes the MoE rooflines in EXPERIMENTS.md meaningful.

Baseline sharding runs experts tensor-parallel (ffn dim over ``model``);
the expert-parallel (experts over ``model`` + token all-to-all) variant is
explored in the §Perf hillclimb.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import MoEConfig, TransformerConfig, dense_init

__all__ = ["init_moe", "moe_forward", "moe_forward_ep"]


def init_moe(key, cfg: TransformerConfig) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.n_experts
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    p = {
        "router": dense_init(k1, (d, E)),
        "w_gate": dense_init(k2, (E, d, f), in_axis=1),
        "w_up": dense_init(k3, (E, d, f), in_axis=1),
        "w_down": dense_init(k4, (E, f, d), in_axis=1),
    }
    if m.shared_expert:
        p["shared_w_gate"] = dense_init(k5, (d, f))
        p["shared_w_up"] = dense_init(k6, (d, f))
        p["shared_w_down"] = dense_init(k7, (f, d))
    return p


def moe_forward(p: dict, x, cfg: TransformerConfig, *,
                dropless: bool = False):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar).

    ``dropless=True`` sizes expert buffers to hold EVERY routed token
    (C = T): bit-exact routing with static shapes. Used by the decode path,
    where T = batch is small and per-token exactness matters (capacity
    drops during decode are nondeterministic quality loss). Training and
    prefill keep GShard capacity semantics (C = T*k/E * capacity_factor).
    """
    m: MoEConfig = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)        # (T, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        jnp.ones((T * k,), jnp.float32)) / (T * k)
    aux = m.router_aux_weight * E * jnp.sum(me * ce)

    # ---- sort-based capacity dispatch -------------------------------------
    # (hypothesis A6 — GShard one-hot einsum dispatch — REFUTED for this
    # regime: the dense (T,E,C) dispatch/combine einsums cost T*E*C*d
    # flops, 10-30x the expert compute at top-1/top-2 capacities. The
    # sort+scatter form keeps HLO flops proportional to ACTIVE experts —
    # the Cerebra-H event-gating analogue; see §Perf log.)
    C = T if dropless else int(np.ceil(T * k / E * m.capacity_factor))
    C = max(8, -(-C // 8) * 8)  # pad to VPU sublane multiple
    expert_flat = gate_idx.reshape(-1)                   # (T*k,)
    token_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    weight_flat = gate_vals.reshape(-1)

    order = jnp.argsort(expert_flat)                     # stable in jnp
    se = expert_flat[order]
    st = token_flat[order]
    sw = weight_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
    keep = pos_in_e < C                                  # capacity drop
    slot = jnp.where(keep, se * C + pos_in_e, E * C)     # overflow -> trash

    # gather tokens into expert buffers (E*C+1 rows; last row = trash)
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xf[st], 0.0))
    eb = buf[: E * C].reshape(E, C, d)

    # ---- expert computation (batched einsum over experts) ----
    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", eb, p["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", eb, p["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(
            jnp.einsum("ecd,edf->ecf", eb, p["w_up"].astype(x.dtype)))
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    y = y.reshape(E * C, d)

    # ---- combine back to token order ----
    contrib = jnp.where(keep[:, None],
                        sw[:, None].astype(x.dtype)
                        * y[jnp.clip(slot, 0, E * C - 1)], 0.0)
    out = jnp.zeros((T, d), x.dtype).at[st].add(contrib)

    if m.shared_expert:
        act = jax.nn.silu
        hs = act(xf @ p["shared_w_gate"].astype(x.dtype)) * (
            xf @ p["shared_w_up"].astype(x.dtype))
        out = out + hs @ p["shared_w_down"].astype(x.dtype)

    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Explicit expert parallelism via shard_map (DESIGN.md §7 / §Perf cell A).
#
# GSPMD makes pathological choices for gather-based MoE dispatch under every
# sharding we measured (EXPERIMENTS.md cell A: six refuted hypotheses). This
# path takes the collectives out of GSPMD's hands: experts live on data-axis
# rows (E % n_data == 0), tokens move by ONE all_to_all each way, expert
# matmuls stay tensor-parallel over `model` with a single psum. Enabled with
# TransformerConfig.moe_ep=true (llama4: 16 experts on the 16-way data axis).
# ---------------------------------------------------------------------------

def _ep_eligible(cfg, mesh) -> bool:
    return (mesh is not None and not mesh.empty
            and "data" in mesh.axis_names
            and cfg.moe.n_experts % mesh.shape["data"] == 0
            and cfg.d_ff % max(mesh.shape.get("model", 1), 1) == 0)


def moe_forward_ep(p: dict, x, cfg: TransformerConfig, *,
                   dropless: bool = False):
    """Expert-parallel MoE block. x: (B, S, d) batch-sharded over data."""
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    if not _ep_eligible(cfg, mesh):
        return moe_forward(p, x, cfg, dropless=dropless)
    m: MoEConfig = cfg.moe
    E, k = m.n_experts, m.top_k
    n_ed = mesh.shape["data"]
    epr = E // n_ed                       # experts per data row
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    w_spec = jax.tree.map(lambda _: P(), p)
    for key in ("w_gate", "w_up"):
        w_spec[key] = P("data", None, "model")
    w_spec["w_down"] = P("data", "model", None)
    for key in ("shared_w_gate", "shared_w_up"):
        if key in p:
            w_spec[key] = P(None, "model")
    if "shared_w_down" in p:
        w_spec["shared_w_down"] = P("model", None)

    def block(xl, pl):
        # xl: (B_local, S, d) on this (data-row, model-col) device
        Bl, S, d = xl.shape
        T = Bl * S
        xf = xl.reshape(T, d)
        logits = (xf.astype(jnp.float32)
                  @ pl["router"].astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
            jnp.ones((T * k,), jnp.float32)) / (T * k)
        aux = m.router_aux_weight * E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, "data")

        # per-expert capacity of the LOCAL contribution (C = T is exactly
        # dropless per source shard: one expert can take every local token)
        C = T if dropless else int(np.ceil(T * k / E * m.capacity_factor))
        C = max(8, -(-C // 8) * 8)
        expert_flat = gate_idx.reshape(-1)
        token_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        weight_flat = gate_vals.reshape(-1)
        order = jnp.argsort(expert_flat)
        se, st, sw = expert_flat[order], token_flat[order], weight_flat[order]
        counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
        keep = pos_in_e < C
        slot = jnp.where(keep, se * C + pos_in_e, E * C)

        send = jnp.zeros((E * C + 1, d), xl.dtype)
        send = send.at[slot].set(jnp.where(keep[:, None], xf[st], 0.0))
        send = send[: E * C].reshape(n_ed, epr * C, d)
        # one all-to-all out: row j receives every shard's tokens for its
        # experts -> (n_ed src rows, epr*C, d)
        recv = jax.lax.all_to_all(send, "data", split_axis=0, concat_axis=0,
                                  tiled=False)
        eb = (recv.reshape(n_ed, epr, C, d)
              .transpose(1, 0, 2, 3).reshape(epr, n_ed * C, d))

        # local experts, ffn TP over `model` (single psum on the way out)
        wg, wu, wd = pl["w_gate"], pl["w_up"], pl["w_down"]
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", eb, wg.astype(xl.dtype)))
        h = h * jnp.einsum("ecd,edf->ecf", eb, wu.astype(xl.dtype))
        y = jnp.einsum("ecf,efd->ecd", h, wd.astype(xl.dtype))
        y = jax.lax.psum(y, "model")

        # route back: invert the transpose, all-to-all home
        y = (y.reshape(epr, n_ed, C, d).transpose(1, 0, 2, 3)
             .reshape(n_ed, epr * C, d))
        back = jax.lax.all_to_all(y, "data", split_axis=0, concat_axis=0,
                                  tiled=False)
        back = back.reshape(E * C, d)
        contrib = jnp.where(keep[:, None],
                            sw[:, None].astype(xl.dtype)
                            * back[jnp.clip(slot, 0, E * C - 1)], 0.0)
        out = jnp.zeros((T, d), xl.dtype).at[st].add(contrib)

        if m.shared_expert:
            hs = jax.nn.silu(xf @ pl["shared_w_gate"].astype(xl.dtype)) * (
                xf @ pl["shared_w_up"].astype(xl.dtype))
            out = out + jax.lax.psum(
                hs @ pl["shared_w_down"].astype(xl.dtype), "model")
        return out.reshape(Bl, S, d), aux

    if hasattr(jax, "shard_map"):
        smap, relax = jax.shard_map, {"check_vma": False}
    else:  # pre-0.6 jax spells it jax.experimental.shard_map
        from jax.experimental.shard_map import shard_map as smap
        relax = {"check_rep": False}
    fn = smap(
        block, mesh=mesh,
        in_specs=(P(batch_axes if len(batch_axes) > 1 else batch_axes[0]),
                  w_spec),
        out_specs=(P(batch_axes if len(batch_axes) > 1 else batch_axes[0]),
                   P()),
        **relax)
    return fn(x, p)
