"""Whisper-large-v3 backbone: encoder-decoder transformer.

Assignment note ([audio] tag): the conv/mel frontend is a STUB —
``input_specs()`` supplies precomputed frame embeddings (B, S_enc, d), the
tensor the conv stack would produce. The transformer backbone (32 enc +
32 dec layers, d=1280, 20 heads, ff=5120, vocab=51866) is implemented in
full: biased projections, LayerNorm (not RMS), sinusoidal encoder
positions, learned decoder positions, GELU MLPs, tied decoder unembedding.

Serving: cross-attention K/V are computed once at prefill and cached;
decode carries (self KV cache, cross KV cache).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.partition import constrain_batch
from repro.models import attention as attn
from repro.models.common import (
    TransformerConfig, cross_entropy_loss, dense_init, layer_norm,
)
from repro.models.transformer import init_mlp, mlp_forward

__all__ = ["WhisperLM"]


def _sinusoid(seq_len: int, d: int):
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10_000 ** (dim / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=1), jnp.float32)


def _ln(x, p, eps):
    return layer_norm(x, p["scale"], p["bias"], eps)


def _norm_init(d):
    return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}


@dataclasses.dataclass(frozen=True)
class WhisperLM:
    cfg: TransformerConfig
    max_dec_len: int = 1 << 15

    # ------------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        enc_keys = jax.random.split(ks[0], cfg.n_layers)
        dec_keys = jax.random.split(ks[1], cfg.n_layers)
        params = {
            "embed": {"table": dense_init(
                ks[2], (cfg.vocab_size, cfg.d_model))},
            "pos_embed": {"table": dense_init(
                ks[3], (self.max_dec_len, cfg.d_model)) * 0.01},
            "enc_layers": jax.vmap(self._enc_layer_init)(enc_keys),
            "dec_layers": jax.vmap(self._dec_layer_init)(dec_keys),
            "enc_final_norm": _norm_init(cfg.d_model),
            "final_norm": _norm_init(cfg.d_model),
        }
        return jax.tree.map(lambda x: x.astype(cfg.dtype), params)

    def _enc_layer_init(self, key) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "pre_norm": _norm_init(cfg.d_model),
            "attn": attn.init_gqa(k1, cfg, bias=True),
            "pre_mlp_norm": _norm_init(cfg.d_model),
            "mlp": init_mlp(k2, cfg, bias=True),
        }

    def _dec_layer_init(self, key) -> dict:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "pre_norm": _norm_init(cfg.d_model),
            "attn": attn.init_gqa(k1, cfg, bias=True),
            "pre_xattn_norm": _norm_init(cfg.d_model),
            "xattn": attn.init_gqa(k2, cfg, bias=True),
            "pre_mlp_norm": _norm_init(cfg.d_model),
            "mlp": init_mlp(k3, cfg, bias=True),
        }

    # ------------------------------------------------------------------
    def encode(self, params, enc_embeds, *, remat: bool = False):
        """enc_embeds: (B, S_enc, d) stub-frontend output -> memory."""
        cfg = self.cfg
        B, S, d = enc_embeds.shape
        x = enc_embeds.astype(cfg.dtype) + _sinusoid(S, d)[None].astype(
            cfg.dtype)
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))

        def body(x, layer_p):
            h = _ln(x, layer_p["pre_norm"], cfg.norm_eps)
            a, _ = attn.gqa_forward(layer_p["attn"], h, cfg=cfg,
                                    positions=positions, causal=False)
            x = x + a
            h = _ln(x, layer_p["pre_mlp_norm"], cfg.norm_eps)
            x = x + mlp_forward(layer_p["mlp"], h, cfg)
            return x, None

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return _ln(x, params["enc_final_norm"], cfg.norm_eps)

    def _dec_embed(self, params, tokens, pos0):
        cfg = self.cfg
        B, S = tokens.shape
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
        pos_ids = pos0 + jnp.arange(S, dtype=jnp.int32)
        x = x + jnp.take(params["pos_embed"]["table"],
                         pos_ids % self.max_dec_len, axis=0)[None]
        return x

    def _decoder(self, params, x, positions, memory, cache, write_pos,
                 *, remat: bool = False):
        cfg = self.cfg
        B = x.shape[0]

        def body(x, scanned):
            layer_p, layer_cache = scanned
            h = _ln(x, layer_p["pre_norm"], cfg.norm_eps)
            self_cache = (None if layer_cache is None
                          else layer_cache["self"])
            a, new_self = attn.gqa_forward(
                layer_p["attn"], h, cfg=cfg, positions=positions,
                cache=self_cache, write_pos=write_pos)
            x = x + a
            h = _ln(x, layer_p["pre_xattn_norm"], cfg.norm_eps)
            if memory is not None:
                xa, _ = attn.gqa_forward(layer_p["xattn"], h, cfg=cfg,
                                         positions=positions, kv_x=memory)
            else:  # decode: reuse cached cross K/V
                xa = self._xattn_cached(layer_p["xattn"], h, positions,
                                        layer_cache["cross"])
            x = x + xa
            h = _ln(x, layer_p["pre_mlp_norm"], cfg.norm_eps)
            x = x + mlp_forward(layer_p["mlp"], h, cfg)
            new_cache = (None if layer_cache is None else
                         {"self": new_self, "cross": layer_cache["cross"]})
            return x, new_cache

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
        x = _ln(x, params["final_norm"], cfg.norm_eps)
        x = constrain_batch(x)
        logits = constrain_batch(x @ params["embed"]["table"].T)  # tied
        return logits, new_cache

    def _xattn_cached(self, p, x, positions, cross):
        """Cross-attention against precomputed (k, v)."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        B, S = x.shape[0], x.shape[1]
        q = (x @ p["wq"] + p.get("bq", 0.0)).reshape(
            B, S, cfg.n_heads, hd)
        T = cross["k"].shape[1]
        pos_k = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                 (B, T))
        out = attn.sdpa(q, cross["k"].astype(q.dtype),
                        cross["v"].astype(q.dtype), positions, pos_k,
                        causal=False, window=None, q_group=cfg.q_group)
        return out.reshape(B, S, cfg.n_heads * hd) @ p["wo"] + p.get(
            "bo", 0.0)

    # ---------------- public API ----------------
    def forward(self, params, batch_in, *, remat: bool = False):
        """Training forward: {'enc_embeds', 'tokens'} -> logits."""
        memory = self.encode(params, batch_in["enc_embeds"], remat=remat)
        tokens = batch_in["tokens"]
        B, S = tokens.shape
        x = self._dec_embed(params, tokens, 0)
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        logits, _ = self._decoder(params, x, positions, memory, None, None,
                                  remat=remat)
        return logits, jnp.zeros((), jnp.float32)

    def loss(self, params, batch_in, *, remat: bool = False):
        logits, aux = self.forward(params, batch_in, remat=remat)
        ce, parts = cross_entropy_loss(logits, batch_in["targets"])
        return ce + aux, dict(parts, aux=aux)

    def init_cache(self, batch: int, self_len: int, cross_len: int) -> dict:
        cfg = self.cfg
        L = cfg.n_layers
        hd = cfg.resolved_head_dim

        def one(_):
            return {
                "self": attn.init_gqa_cache(cfg, batch, self_len),
                "cross": {
                    "k": jnp.zeros((batch, cross_len, cfg.n_kv_heads, hd),
                                   cfg.dtype),
                    "v": jnp.zeros((batch, cross_len, cfg.n_kv_heads, hd),
                                   cfg.dtype),
                },
            }

        return jax.vmap(one)(jnp.arange(L))

    def prefill(self, params, batch_in, cache):
        """Encode audio + prefill decoder prompt; fills self & cross caches."""
        cfg = self.cfg
        memory = self.encode(params, batch_in["enc_embeds"])
        hd = cfg.resolved_head_dim

        # precompute cross K/V per layer
        def cross_kv(layer_p):
            k = (memory @ layer_p["xattn"]["wk"]).reshape(
                memory.shape[0], memory.shape[1], cfg.n_kv_heads, hd)
            v = (memory @ layer_p["xattn"]["wv"]
                 + layer_p["xattn"].get("bv", 0.0)).reshape(
                memory.shape[0], memory.shape[1], cfg.n_kv_heads, hd)
            return {"k": k, "v": v}

        cross = jax.vmap(cross_kv)(params["dec_layers"])
        cache = {**cache} if isinstance(cache, dict) else cache
        cache = jax.tree.map(lambda x: x, cache)  # shallow copy
        cache = dict_replace_cross(cache, cross)

        tokens = batch_in["tokens"]
        B, S = tokens.shape
        x = self._dec_embed(params, tokens, 0)
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        logits, new_cache = self._decoder(params, x, positions, None,
                                          cache, jnp.int32(0))
        return logits[:, -1:], new_cache

    def decode_step(self, params, token_in, pos, cache):
        tokens = token_in["tokens"]
        B = tokens.shape[0]
        x = self._dec_embed(params, tokens, jnp.asarray(pos, jnp.int32))
        positions = jnp.broadcast_to(
            jnp.asarray(pos, jnp.int32)[None, None], (B, 1))
        return self._decoder(params, x, positions, None, cache,
                             jnp.asarray(pos, jnp.int32))


def dict_replace_cross(cache, cross):
    return {"self": cache["self"], "cross": cross} if "self" in cache else {
        **cache, "cross": cross}
