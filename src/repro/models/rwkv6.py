"""RWKV-6 "Finch" block — attention-free token mixing with data-dependent
decay (the assigned rwkv6-7b backbone).

Per head (k-dim x v-dim state S):

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T        w_t = exp(-exp(decay(x_t)))

``decay(x)`` is the low-rank data-dependent decay (the "Finch" novelty).
Decode carries (S, prev-token shift states) — O(1) per token in context
length, which is why rwkv6 runs the long_500k cell.

DESIGN.md §4 kinship: w_t is a learned, per-channel generalization of the
Cerebra-H shift-decay leak; state update and LIF update share the same
decay+integrate skeleton.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import RWKVConfig, TransformerConfig, dense_init

__all__ = ["init_rwkv6", "rwkv6_scan", "rwkv6_step", "init_rwkv6_cache",
           "init_rwkv6_ffn", "rwkv6_ffn", "rwkv6_ffn_step"]


def _dims(cfg: TransformerConfig):
    r: RWKVConfig = cfg.rwkv
    nh = cfg.d_model // r.head_dim
    return r, nh, r.head_dim


def init_rwkv6(key, cfg: TransformerConfig) -> dict:
    r, nh, hd = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "wr": dense_init(ks[0], (d, d)),
        "wk": dense_init(ks[1], (d, d)),
        "wv": dense_init(ks[2], (d, d)),
        "wg": dense_init(ks[3], (d, d)),
        "wo": dense_init(ks[4], (d, d)),
        "decay_a": dense_init(ks[5], (d, r.decay_lora)),
        "decay_b": dense_init(ks[6], (r.decay_lora, d)) * 0.1,
        "mix": jax.random.uniform(ks[7], (5, d)),  # r,k,v,g,w shift mixes
        "u": jnp.zeros((nh, hd)),
        "ln_x": {"scale": jnp.zeros((d,))},
    }


def init_rwkv6_ffn(key, cfg: TransformerConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wk_mlp": dense_init(k1, (d, f)),
        "wv_mlp": dense_init(k2, (f, d)),
        "wr_mlp": dense_init(k3, (d, d)),
        "mix": jax.random.uniform(jax.random.fold_in(key, 9), (2, d)),
    }


def init_rwkv6_cache(cfg: TransformerConfig, batch: int, dtype=None):
    r, nh, hd = _dims(cfg)
    dtype = dtype or cfg.dtype
    return {
        "state": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "x_att": jnp.zeros((batch, cfg.d_model), dtype),
        "x_ffn": jnp.zeros((batch, cfg.d_model), dtype),
    }


def _mix(x, x_prev, m):
    return x + (x_prev - x) * m[None, None]


def _decay(p, xw):
    return jnp.exp(-jnp.exp(
        (xw.astype(jnp.float32) @ p["decay_a"].astype(jnp.float32))
        @ p["decay_b"].astype(jnp.float32)))


def _group_norm(x, scale, nh, eps=1e-5):
    """per-head layer norm of the wkv output (RWKV's ln_x)."""
    B, S, d = x.shape
    xh = x.reshape(B, S, nh, d // nh).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(B, S, d)
            * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rwkv6_scan(p: dict, x, *, cfg: TransformerConfig,
               x_prev=None, return_cache: bool = False):
    """Time-mix over a sequence. x: (B,S,d)."""
    r, nh, hd = _dims(cfg)
    B, S, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)

    xr = _mix(x, shifted, p["mix"][0])
    xk = _mix(x, shifted, p["mix"][1])
    xv = _mix(x, shifted, p["mix"][2])
    xg = _mix(x, shifted, p["mix"][3])
    xw = _mix(x, shifted, p["mix"][4])

    rv = (xr @ p["wr"].astype(x.dtype)).reshape(B, S, nh, hd)
    kv = (xk @ p["wk"].astype(x.dtype)).reshape(B, S, nh, hd)
    vv = (xv @ p["wv"].astype(x.dtype)).reshape(B, S, nh, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    w = _decay(p, xw).reshape(B, S, nh, hd)  # f32 decay in (0,1)
    u = p["u"].astype(jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # (B,nh,hd) each
        kv_t = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                          v_t.astype(jnp.float32))
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                         state + u[None, :, :, None] * kv_t)
        state = w_t.astype(jnp.float32)[..., None] * state + kv_t
        return state, y_t

    state0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
    inputs = tuple(jnp.moveaxis(a, 1, 0) for a in (rv, kv, vv, w))
    state, ys = jax.lax.scan(step, state0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = _group_norm(y, p["ln_x"]["scale"], nh)
    out = (y * g) @ p["wo"].astype(x.dtype)
    if return_cache:
        return out, {"state": state, "x_att": x[:, -1]}
    return out, None


def rwkv6_step(p: dict, x, cache: dict, *, cfg: TransformerConfig):
    """Single-token decode. x: (B,1,d)."""
    r, nh, hd = _dims(cfg)
    B, _, d = x.shape
    shifted = cache["x_att"][:, None]
    xr = _mix(x, shifted, p["mix"][0])
    xk = _mix(x, shifted, p["mix"][1])
    xv = _mix(x, shifted, p["mix"][2])
    xg = _mix(x, shifted, p["mix"][3])
    xw = _mix(x, shifted, p["mix"][4])
    r_t = (xr @ p["wr"].astype(x.dtype)).reshape(B, nh, hd)
    k_t = (xk @ p["wk"].astype(x.dtype)).reshape(B, nh, hd)
    v_t = (xv @ p["wv"].astype(x.dtype)).reshape(B, nh, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))[:, 0]
    w_t = _decay(p, xw).reshape(B, nh, hd)
    u = p["u"].astype(jnp.float32)
    state = cache["state"]
    kv_t = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                      v_t.astype(jnp.float32))
    y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                   state + u[None, :, :, None] * kv_t)
    state = w_t.astype(jnp.float32)[..., None] * state + kv_t
    y = y.reshape(B, 1, d).astype(x.dtype)
    y = _group_norm(y, p["ln_x"]["scale"], nh)
    out = ((y[:, 0] * g) @ p["wo"].astype(x.dtype))[:, None]
    return out, {"state": state, "x_att": x[:, 0]}


def rwkv6_ffn(p: dict, x, *, x_prev=None):
    """Channel mix. x: (B,S,d)."""
    B, S, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xk = _mix(x, shifted, p["mix"][0])
    xr = _mix(x, shifted, p["mix"][1])
    k = jnp.square(jax.nn.relu(xk @ p["wk_mlp"].astype(x.dtype)))
    r = jax.nn.sigmoid(xr @ p["wr_mlp"].astype(x.dtype))
    return r * (k @ p["wv_mlp"].astype(x.dtype))


def rwkv6_ffn_step(p: dict, x, x_prev):
    """x: (B,1,d); x_prev: (B,d) -> (out, new_x_prev)."""
    out = rwkv6_ffn(p, x, x_prev=x_prev)
    return out, x[:, 0]
