"""Mamba2 (SSD) block — zamba2's backbone.

Selective state-space with scalar-per-head decay (the SSD formulation):

    dt_t   = softplus(dt_raw_t + dt_bias)            (B, nh)
    dA_t   = exp(-exp(A_log) * dt_t)                 (B, nh)
    state  = dA_t * state + (x_t * dt_t) outer B_t   (B, nh, hd, ds)
    y_t    = state . C_t + D * x_t

Two execution paths sharing one parameterization:
  * ``mamba2_scan``  — sequential lax.scan over time (train/prefill
    baseline; exact).
  * ``mamba2_step``  — single-token decode with carried (conv, ssm) state.

A chunked (block-parallel) SSD variant is a §Perf candidate; the scan is
the correctness oracle for it.

Conceptual kinship with the paper (DESIGN.md §4): the LIF membrane update
V' = decay*V + input IS a degenerate (non-selective, scalar-state) SSM;
Mamba2's learned, input-dependent dA generalizes Cerebra's fixed shift
decay.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import SSMConfig, TransformerConfig, dense_init, rms_norm

__all__ = ["init_mamba2", "mamba2_scan", "mamba2_step", "init_mamba2_cache"]


def _dims(cfg: TransformerConfig):
    s: SSMConfig = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return s, d_in, nh, conv_dim


def init_mamba2(key, cfg: TransformerConfig) -> dict:
    s, d_in, nh, conv_dim = _dims(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    # in_proj emits [z, x, B, C, dt]
    out_width = 2 * d_in + 2 * s.d_state + nh
    return {
        "in_proj": dense_init(k1, (cfg.d_model, out_width)),
        "conv_w": dense_init(k2, (s.d_conv, conv_dim)),
        "A_log": jnp.zeros((nh,)),
        "D": jnp.ones((nh,)),
        "dt_bias": jnp.zeros((nh,)),
        "norm": {"scale": jnp.zeros((d_in,))},
        "out_proj": dense_init(k3, (d_in, cfg.d_model)),
    }


def init_mamba2_cache(cfg: TransformerConfig, batch: int, dtype=None):
    s, d_in, nh, conv_dim = _dims(cfg)
    dtype = dtype or cfg.dtype
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def _split(cfg, zxbcdt):
    s, d_in, nh, _ = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xc = zxbcdt[..., d_in: 2 * d_in + 2 * s.d_state]  # conv input [x,B,C]
    dt = zxbcdt[..., 2 * d_in + 2 * s.d_state:]
    return z, xc, dt


def _post(cfg, p, y, z, x):
    _, d_in, _, _ = _dims(cfg)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm"]["scale"], cfg.norm_eps)
    return (y @ p["out_proj"].astype(y.dtype)).astype(x.dtype)


def mamba2_scan(p: dict, x, *, cfg: TransformerConfig,
                return_cache: bool = False):
    """x: (B, S, d_model) -> (out, cache|None). Causal depthwise conv +
    sequential SSD scan."""
    s, d_in, nh, conv_dim = _dims(cfg)
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xc, dt_raw = _split(cfg, zxbcdt)

    # causal depthwise conv over time
    pad = jnp.zeros((B, s.d_conv - 1, conv_dim), xc.dtype)
    xc_p = jnp.concatenate([pad, xc], axis=1)
    conv_w = p["conv_w"].astype(xc.dtype)
    xc_conv = sum(
        xc_p[:, i: i + S] * conv_w[i][None, None] for i in range(s.d_conv))
    xc_conv = jax.nn.silu(xc_conv)
    xs = xc_conv[..., :d_in].reshape(B, S, nh, s.head_dim)
    Bt = xc_conv[..., d_in: d_in + s.d_state]
    Ct = xc_conv[..., d_in + s.d_state:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,nh)
    dA = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dt)

    def step(state, inputs):
        xs_t, b_t, c_t, dA_t, dt_t = inputs
        upd = jnp.einsum("bhd,bs->bhds",
                         xs_t.astype(jnp.float32)
                         * dt_t[..., None], b_t.astype(jnp.float32))
        state = dA_t[..., None, None] * state + upd
        y_t = jnp.einsum("bhds,bs->bhd", state, c_t.astype(jnp.float32))
        return state, y_t

    state0 = jnp.zeros((B, nh, s.head_dim, s.d_state), jnp.float32)
    xs_t = jnp.moveaxis(xs, 1, 0)
    b_t = jnp.moveaxis(Bt, 1, 0)
    c_t = jnp.moveaxis(Ct, 1, 0)
    dA_t = jnp.moveaxis(dA, 1, 0)
    dt_t = jnp.moveaxis(dt, 1, 0)
    state, ys = jax.lax.scan(step, state0, (xs_t, b_t, c_t, dA_t, dt_t))
    y = jnp.moveaxis(ys, 0, 1)  # (B,S,nh,hd)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(
        jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    out = _post(cfg, p, y, z, x)
    if return_cache:
        tail = xc[:, -(s.d_conv - 1):] if S >= s.d_conv - 1 else (
            jnp.concatenate([pad, xc], axis=1)[:, -(s.d_conv - 1):])
        return out, {"conv": tail, "ssm": state}
    return out, None


def mamba2_step(p: dict, x, cache: dict, *, cfg: TransformerConfig):
    """Single-token decode. x: (B, 1, d_model)."""
    s, d_in, nh, conv_dim = _dims(cfg)
    B = x.shape[0]
    zxbcdt = x[:, 0] @ p["in_proj"].astype(x.dtype)  # (B, width)
    z, xc, dt_raw = _split(cfg, zxbcdt[:, None, :])
    z, xc, dt_raw = z[:, 0], xc[:, 0], dt_raw[:, 0]

    conv_hist = jnp.concatenate([cache["conv"].astype(xc.dtype),
                                 xc[:, None]], axis=1)  # (B, d_conv, cd)
    conv_w = p["conv_w"].astype(xc.dtype)
    xc_conv = jax.nn.silu(jnp.einsum("btc,tc->bc", conv_hist, conv_w))
    new_conv = conv_hist[:, 1:]

    xs = xc_conv[:, :d_in].reshape(B, nh, s.head_dim)
    b_t = xc_conv[:, d_in: d_in + s.d_state]
    c_t = xc_conv[:, d_in + s.d_state:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,nh)
    dA = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dt)

    state = cache["ssm"]
    upd = jnp.einsum("bhd,bs->bhds", xs.astype(jnp.float32) * dt[..., None],
                     b_t.astype(jnp.float32))
    state = dA[..., None, None] * state + upd
    y = jnp.einsum("bhds,bs->bhd", state, c_t.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, d_in).astype(x.dtype)
    out = _post(cfg, p, y[:, None], z[:, None], x)
    return out, {"conv": new_conv, "ssm": state}
