"""Observability layer: metrics registry + stream-lifecycle tracing.

The serving stack's telemetry lives here, in two halves:

- :mod:`repro.obs.metrics` — a process-wide but injectable
  :class:`MetricsRegistry` of counters, gauges, and fixed-bucket
  histograms with label sets, exportable as Prometheus text exposition
  or a JSON snapshot. ``METRIC_SPECS`` is the canonical catalogue of
  every metric the serving stack emits.
- :mod:`repro.obs.tracing` — a :class:`SpanTracer` recording typed
  stream-lifecycle spans (queued → admitted → chunk_step×N →
  parked/migrated/redeployed → retired) with JSONL export and optional
  ``jax.profiler`` trace annotations.

The hard contract of this package: observability READS the datapath and
never changes it. Every instrument hook is a pure host-side read of
values the serving layer already computes; the byte-identity suites
(async==sync, migration, fused steps) run with telemetry enabled to
prove it.
"""

from repro.obs.metrics import (
    METRIC_SPECS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    set_registry,
)
from repro.obs.tracing import Span, SpanTracer

__all__ = [
    "METRIC_SPECS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "SpanTracer",
    "get_registry",
    "set_registry",
]
