"""Observability layer: metrics registry + stream-lifecycle tracing.

The serving stack's telemetry lives here, in two halves:

- :mod:`repro.obs.metrics` — a process-wide but injectable
  :class:`MetricsRegistry` of counters, gauges, and fixed-bucket
  histograms with label sets, exportable as Prometheus text exposition
  or a JSON snapshot. ``METRIC_SPECS`` is the canonical catalogue of
  every metric the serving stack emits.
- :mod:`repro.obs.tracing` — a :class:`SpanTracer` recording typed
  stream-lifecycle spans (queued → admitted → chunk_step×N →
  parked/migrated/redeployed → retired) with JSONL export and optional
  ``jax.profiler`` trace annotations.

On top of the raw record sits the analysis tier:

- :mod:`repro.obs.timeline` — per-stream lifecycle timelines
  reconstructed from span streams, with a closed-state-machine auditor
  (:func:`reconstruct`) and per-device mesh-lane breakdowns.
- :mod:`repro.obs.slo` — declarative SLO objectives
  (:class:`SLObjective`) evaluated as rolling burn-rate windows by an
  :class:`SLOWatchdog` the frontend pump feeds.
- :mod:`repro.obs.flight` — a bounded :class:`FlightRecorder` ring of
  the last-N spans + metric deltas, dumping a post-mortem JSON on crash
  or SLO breach.

The hard contract of this package: observability READS the datapath and
never changes it. Every instrument hook is a pure host-side read of
values the serving layer already computes; the byte-identity suites
(async==sync, migration, fused steps) run with telemetry enabled to
prove it.
"""

from repro.obs.flight import FlightRecorder
from repro.obs.metrics import (
    METRIC_SPECS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    set_registry,
)
from repro.obs.slo import SLObjective, SLOStatus, SLOWatchdog
from repro.obs.timeline import (
    LifecycleViolation,
    StreamTimeline,
    TimelineReport,
    mesh_lanes,
    reconstruct,
    verify_shard_lanes,
)
from repro.obs.tracing import Span, SpanTracer

__all__ = [
    "METRIC_SPECS",
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "LifecycleViolation",
    "MetricsRegistry",
    "SLObjective",
    "SLOStatus",
    "SLOWatchdog",
    "Span",
    "SpanTracer",
    "StreamTimeline",
    "TimelineReport",
    "get_registry",
    "mesh_lanes",
    "reconstruct",
    "set_registry",
    "verify_shard_lanes",
]
