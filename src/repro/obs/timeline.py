"""Per-stream lifecycle timelines + auditor over ``SpanTracer`` output.

PR 8's tracer records *what happened*; this module turns that record
into *verdicts*. :func:`reconstruct` replays a span stream (an in-memory
:class:`~repro.obs.tracing.SpanTracer`, a list of spans/dicts, or a
``--trace`` JSONL file) through the closed lifecycle state machine::

    new ── queued ──> queued ── admitted ──> running ── retired ──> retired
              ^                  │   ^  │
              │    parked        v   │  └── migrated / chunk_step*
              └── resumed ──── parked ── retired

and emits one :class:`StreamTimeline` per ``(domain, uid)`` with exact
wait / service / park time splits plus admission / park / migration /
redeploy / chunk counts. The same replay is a correctness auditor: an
illegal transition, activity after retirement, a retire-without-admit,
a ``chunk_step`` naming a non-running stream, or a leaked stream (the
trace ends with it queued or running) is a :class:`LifecycleViolation`
hard error — so every suite that records a trace doubles as a
lifecycle audit.

Two uid namespaces share one tracer: the async frontend spans its
*request* ids (``attrs["domain"] == "request"``) while the server spans
its *stream* uids (no domain attr, the default ``"stream"``). Timelines
are keyed by ``(domain, uid)`` so rid 0 and stream uid 0 never alias.

Terminal states: ``retired`` is the only fully-closed end state, but a
trace may legally end with streams ``parked`` — their state lives on in
a connector (spill, rolling redeploy, checkpoint), which is the point
of parking. A request refused at the queue door (``outcome ==
"rejected"``) retires without ever being queued; every other
retire-from-nothing is the retire-without-admit error.

Mesh lanes: ``shard_step`` spans (recorded by the shard load watch /
``observe_from_registry``) carry the per-shard attributed times and
straggler flags of each dispatch; :func:`mesh_lanes` folds them into a
per-device barrier breakdown and :func:`verify_shard_lanes` replays the
times through a fresh pure ``StragglerDetector`` and demands exact flag
agreement with what was recorded live.

Read-only like the rest of ``repro.obs``: reconstruction consumes spans
after the fact and never touches the datapath.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

__all__ = [
    "AUX_KINDS",
    "LIFECYCLE_KINDS",
    "LifecycleViolation",
    "StreamTimeline",
    "TimelineReport",
    "load_jsonl",
    "mesh_lanes",
    "reconstruct",
    "verify_shard_lanes",
]

# Kinds that drive a stream's state machine, vs auxiliary spans that
# describe the process (dispatches, deploys, connector IO) and never
# create or mutate a stream.
LIFECYCLE_KINDS = frozenset({
    "queued", "admitted", "parked", "resumed", "migrated", "redeployed",
    "retired",
})
AUX_KINDS = frozenset({
    "chunk_step", "deploy", "snapshot", "restore", "shard_step",
})

# (state, kind) -> next state. Anything absent is an illegal
# transition, except the two documented special cases handled in
# reconstruct(): admitted-while-running with resumed=True (a restore
# over a live incarnation — crash recovery), and retired-from-new with
# outcome="rejected" (refused at the queue door).
_TRANSITIONS = {
    ("new", "queued"): "queued",
    ("queued", "queued"): "queued",      # re-queued (redeploy / resume)
    ("parked", "queued"): "queued",
    ("new", "admitted"): "running",
    ("queued", "admitted"): "running",
    ("parked", "admitted"): "running",   # restored from a carry
    ("running", "parked"): "parked",     # spill / migrate / drain
    ("queued", "parked"): "parked",      # parked before a slot arrived
    ("parked", "resumed"): "queued",
    ("queued", "resumed"): "queued",     # marker next to the re-queue
    ("running", "migrated"): "running",
    ("running", "redeployed"): "parked",
    ("queued", "retired"): "retired",
    ("running", "retired"): "retired",
    ("parked", "retired"): "retired",    # e.g. cancel-while-parked
}

_TIME_BUCKET = {"queued": "wait_s", "running": "service_s",
                "parked": "park_s"}


class LifecycleViolation(ValueError):
    """A span stream that no legal stream lifecycle can produce."""


def _freeze(x):
    """Hashable uid: JSONL round-trips tuples as lists."""
    return tuple(_freeze(v) for v in x) if isinstance(x, list) else x


def load_jsonl(path) -> list[dict]:
    """Load a ``--trace`` file (one span dict per line)."""
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


def _as_dicts(source) -> list[dict]:
    """Normalize any span source to a list of span dicts, in record
    order (the tracer appends under its lock, so list order — not
    timestamp sorting — is the authoritative event order; fake clocks
    legitimately produce ties)."""
    if isinstance(source, (str, Path)):
        return load_jsonl(source)
    if hasattr(source, "to_dicts"):          # SpanTracer
        return source.to_dicts()
    out = []
    for s in source:
        out.append(s.to_dict() if hasattr(s, "to_dict") else dict(s))
    return out


@dataclasses.dataclass
class StreamTimeline:
    """One stream's reconstructed lifecycle and time breakdown."""

    domain: str
    uid: object
    state: str = "new"            # final state after replay
    outcome: str | None = None    # retired outcome, when retired
    wait_s: float = 0.0           # time spent queued
    service_s: float = 0.0        # time spent running (slot-bound)
    park_s: float = 0.0           # time spent parked in a connector
    chunk_s: float = 0.0          # summed duration of chunks it ran in
    n_chunks: int = 0
    n_admissions: int = 0
    n_parks: int = 0
    n_migrations: int = 0
    n_redeploys: int = 0
    t_first: float = 0.0
    t_last: float = 0.0
    kinds: list = dataclasses.field(default_factory=list)
    _since: float = dataclasses.field(default=0.0, repr=False)

    @property
    def total_s(self) -> float:
        return self.t_last - self.t_first

    def to_dict(self) -> dict:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "_since"}
        d["total_s"] = self.total_s
        return d


@dataclasses.dataclass
class TimelineReport:
    """Every stream's timeline plus the violations the replay found."""

    streams: dict                 # {(domain, uid): StreamTimeline}
    violations: list
    n_spans: int
    n_chunk_steps: int

    def stream(self, uid, domain: str = "stream") -> StreamTimeline:
        return self.streams[(domain, _freeze(uid))]

    def by_state(self) -> dict:
        out: dict = {}
        for st in self.streams.values():
            out[st.state] = out.get(st.state, 0) + 1
        return out

    def to_dict(self) -> dict:
        return {
            "n_spans": self.n_spans,
            "n_chunk_steps": self.n_chunk_steps,
            "n_streams": len(self.streams),
            "by_state": self.by_state(),
            "violations": list(self.violations),
            "streams": [st.to_dict() for st in self.streams.values()],
        }


def reconstruct(source, *, validate: bool = True,
                allow_inflight: bool = False) -> TimelineReport:
    """Replay a span stream into per-stream timelines; audit it.

    Args:
      source: a ``SpanTracer``, a list of ``Span``/dicts, or a JSONL
        trace path.
      validate: raise :class:`LifecycleViolation` (all violations, one
        per line) instead of returning a report that carries them.
      allow_inflight: skip the leak check — for mid-run snapshots
        (the flight recorder's ring is a window, not a whole run), a
        stream still queued/running at the end of the window is not a
        leak.
    """
    spans = _as_dicts(source)
    streams: dict = {}
    violations: list[str] = []
    n_chunks = 0

    def _chunk_audit(i, d, attrs):
        uids = attrs.get("uids")
        if uids is None:
            return
        dur = d["t1"] - d["t0"]
        for u in uids:
            st = streams.get(("stream", _freeze(u)))
            if st is None or st.state != "running":
                violations.append(
                    f"chunk_step #{i} names stream uid {u!r} which is "
                    f"{'unknown' if st is None else st.state!r}, not "
                    f"running")
            else:
                st.n_chunks += 1
                st.chunk_s += dur

    for i, d in enumerate(spans):
        kind = d["kind"]
        attrs = d.get("attrs") or {}
        if kind == "chunk_step":
            n_chunks += 1
            _chunk_audit(i, d, attrs)
            continue
        if kind not in LIFECYCLE_KINDS:
            continue
        domain = attrs.get("domain", "stream")
        key = (domain, _freeze(d.get("uid")))
        t = d["t1"]
        st = streams.get(key)
        if st is None:
            st = StreamTimeline(domain=domain, uid=key[1],
                                t_first=t, t_last=t, _since=t)
            streams[key] = st
        where = f"{domain}:{st.uid!r} (span #{i})"

        old = st.state
        if old == "retired":
            violations.append(f"{where}: {kind!r} after retirement")
            continue
        if (kind, old) == ("admitted", "running") and attrs.get("resumed"):
            # crash-recovery restore over a live incarnation: the old
            # incarnation's spans stop, the restored one takes over.
            new = "running"
        elif (kind, old) == ("retired", "new"):
            if attrs.get("outcome") == "rejected":
                new = "retired"  # refused at the queue door
            else:
                violations.append(
                    f"{where}: retired (outcome="
                    f"{attrs.get('outcome')!r}) without ever being "
                    f"queued or admitted")
                new = "retired"
        else:
            new = _TRANSITIONS.get((old, kind))
            if new is None:
                violations.append(
                    f"{where}: illegal {kind!r} in state {old!r}")
                continue

        bucket = _TIME_BUCKET.get(old)
        if bucket is not None:
            setattr(st, bucket, getattr(st, bucket) + (t - st._since))
        st._since = t
        st.state = new
        st.t_last = t
        st.kinds.append(kind)
        if kind == "admitted":
            st.n_admissions += 1
        elif kind == "parked":
            st.n_parks += 1
        elif kind == "migrated":
            st.n_migrations += 1
        elif kind == "redeployed":
            st.n_redeploys += 1
        elif kind == "retired":
            st.outcome = attrs.get("outcome")

    if not allow_inflight:
        for (domain, uid), st in streams.items():
            if st.state in ("queued", "running"):
                violations.append(
                    f"{domain}:{uid!r}: leaked — trace ends with the "
                    f"stream {st.state!r} (never retired or parked)")

    report = TimelineReport(streams=streams, violations=violations,
                            n_spans=len(spans), n_chunk_steps=n_chunks)
    if validate and violations:
        raise LifecycleViolation(
            f"{len(violations)} lifecycle violation(s):\n"
            + "\n".join(violations))
    return report


# ---------------------------------------------------------------------
# mesh lanes: per-shard barrier breakdown from shard_step spans
# ---------------------------------------------------------------------

def _shard_spans(source) -> list[dict]:
    return [d for d in _as_dicts(source) if d["kind"] == "shard_step"]


def mesh_lanes(source) -> dict:
    """Fold ``shard_step`` spans into a per-device barrier breakdown.

    Each ``shard_step`` span records one sharded dispatch: the load
    watch's per-shard attributed times and the straggler flags that
    dispatch produced. The result is one lane per shard with its full
    time series, flag series, and total flagged-dispatch count.
    """
    spans = _shard_spans(source)
    if not spans:
        return {"n_dispatches": 0, "n_shards": 0, "lanes": []}
    n_shards = len(spans[0]["attrs"]["times"])
    lanes = [{"shard": i, "times": [], "flags": [], "flagged": 0}
             for i in range(n_shards)]
    for d in spans:
        attrs = d["attrs"]
        for i, (t, f) in enumerate(zip(attrs["times"], attrs["flags"])):
            lanes[i]["times"].append(float(t))
            lanes[i]["flags"].append(int(f))
            lanes[i]["flagged"] += int(f)
    return {"n_dispatches": len(spans), "n_shards": n_shards,
            "lanes": lanes}


def verify_shard_lanes(source, detector) -> int:
    """Replay recorded per-shard times through a fresh detector and
    demand *exact* flag agreement with what was recorded live.

    ``detector`` must be a new ``StragglerDetector`` configured like the
    one that ran live (same warmup/patience/thresholds) — the recorded
    flags came through the registry-transported
    ``observe_from_registry`` path, which is pinned to agree with the
    pure ``observe`` on the same vector, so a mismatch here means the
    live path and the pure path diverged. Returns the number of
    dispatches checked; raises :class:`LifecycleViolation` on the first
    disagreement.
    """
    spans = _shard_spans(source)
    for i, d in enumerate(spans):
        attrs = d["attrs"]
        flags = [int(bool(f)) for f in detector.observe(attrs["times"])]
        recorded = [int(bool(f)) for f in attrs["flags"]]
        if flags != recorded:
            raise LifecycleViolation(
                f"shard_step #{i}: replayed straggler flags {flags} "
                f"disagree with recorded flags {recorded}")
    return len(spans)
