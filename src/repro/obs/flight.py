"""Flight recorder: a bounded ring of recent spans + metric deltas.

A long-running serving process cannot keep (or ship) its whole span log,
but the moments before a crash or an SLO breach are exactly the ones
worth keeping. :class:`FlightRecorder` holds the **last N** lifecycle
spans and metric deltas in fixed-size rings and, on demand, dumps one
post-mortem JSON document — reason, recent spans, recent metric deltas,
and a partial-timeline summary — to a file.

Wiring (all optional, all read-only):

- As a tracer **sink**: ``SpanTracer(sink=recorder)`` streams every
  completed span through :meth:`write` (the same one-JSON-line protocol
  a file sink gets), so the ring always holds the freshest spans with no
  second recording path.
- On the metrics side, :meth:`note_metrics` diffs a registry's scalar
  samples (counters + gauges) against the previous call and appends the
  nonzero deltas — call it once per pump/serve round.
- As an :class:`SLOWatchdog` breach hook: ``on_breach=recorder.on_breach``
  dumps one post-mortem per breach onset.
- As a crash net: ``with recorder.armed("post_mortem.json"):`` dumps on
  any exception escaping the block, then re-raises it.

The dump's ``timeline`` block reuses :mod:`repro.obs.timeline` with
``allow_inflight=True`` — a ring is a window, not a whole run, so
streams still queued/running at the window's edge are expected, not
leaks; reconstruction violations are *reported* in the dump rather than
raised (a post-mortem must never mask the original failure).
"""

from __future__ import annotations

import collections
import contextlib
import json
import time

__all__ = ["FlightRecorder"]


class FlightRecorder:
    """Bounded last-N recorder of spans and metric deltas.

    Args:
      capacity: ring size for each of the span and delta rings.
      clock: injectable monotonic-seconds callable (stamps deltas and
        dumps).
      path: default dump path for :meth:`dump` / :meth:`on_breach` /
        :meth:`armed` when the call site does not name one.
    """

    def __init__(self, capacity: int = 512, *, clock=time.perf_counter,
                 path=None):
        if capacity <= 0:
            raise ValueError(
                f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self.clock = clock
        self.path = path
        self._spans: collections.deque = collections.deque(
            maxlen=self.capacity)
        self._deltas: collections.deque = collections.deque(
            maxlen=self.capacity)
        self._last_scalars: dict = {}
        self.n_dumps = 0

    # -- span intake (SpanTracer sink protocol) -----------------------
    def write(self, line: str) -> None:
        """Accept one completed span as its JSON line (the tracer's
        sink protocol), keeping only the last ``capacity`` spans."""
        self._spans.append(json.loads(line))

    @property
    def spans(self) -> list[dict]:
        return list(self._spans)

    # -- metric intake ------------------------------------------------
    def note_metrics(self, registry) -> int:
        """Record the scalar (counter/gauge) deltas since the previous
        call; returns how many series moved. Histograms are skipped —
        their stories are told by the latency spans already in the
        ring."""
        scalars: dict = {}
        for name, fam in registry.snapshot().items():
            if fam["type"] == "histogram":
                continue
            for sample in fam["samples"]:
                key = (name,) + tuple(sorted(sample["labels"].items()))
                scalars[key] = sample["value"]
        now = self.clock()
        moved = 0
        for key, value in scalars.items():
            prev = self._last_scalars.get(key)
            if prev is None or value != prev:
                name, *labels = key
                self._deltas.append({
                    "t": now, "metric": name,
                    "labels": dict(labels),
                    "value": value,
                    "delta": None if prev is None else value - prev,
                })
                moved += 1
        self._last_scalars = scalars
        return moved

    @property
    def deltas(self) -> list[dict]:
        return list(self._deltas)

    # -- post-mortem --------------------------------------------------
    def snapshot(self, *, reason: str, extra: dict | None = None) -> dict:
        """The post-mortem document (what :meth:`dump` writes)."""
        from repro.obs.timeline import reconstruct

        report = reconstruct(list(self._spans), validate=False,
                             allow_inflight=True)
        doc = {
            "reason": reason,
            "t": self.clock(),
            "capacity": self.capacity,
            "spans": list(self._spans),
            "metric_deltas": list(self._deltas),
            "timeline": report.to_dict(),
        }
        if extra:
            doc["extra"] = extra
        return doc

    def dump(self, path=None, *, reason: str,
             extra: dict | None = None) -> dict:
        """Write the post-mortem JSON to ``path`` (or the default);
        returns the document. With no path at all, the document is still
        built and returned — callers can route it themselves."""
        doc = self.snapshot(reason=reason, extra=extra)
        path = self.path if path is None else path
        if path is not None:
            with open(path, "w") as fh:
                json.dump(doc, fh, indent=2, default=str)
        self.n_dumps += 1
        return doc

    # -- hooks --------------------------------------------------------
    def on_breach(self, status) -> None:
        """``SLOWatchdog`` breach hook: one dump per breach onset."""
        self.dump(reason=f"slo-breach:{status.objective.name}",
                  extra=status.to_dict())

    @contextlib.contextmanager
    def armed(self, path=None):
        """Dump a post-mortem if an exception escapes the block, then
        re-raise it — the crash net around a serving loop."""
        try:
            yield self
        except BaseException as e:
            self.dump(path, reason=f"crash:{type(e).__name__}",
                      extra={"error": str(e)})
            raise
