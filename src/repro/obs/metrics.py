"""Metrics registry: counters, gauges, fixed-bucket histograms, exporters.

One :class:`MetricsRegistry` instance carries every instrument the
serving stack emits. The registry is process-wide by convention
(:func:`get_registry` / :func:`set_registry`) but explicitly injectable:
every instrumented component takes ``metrics=None`` (no instrumentation,
zero added work on the datapath) or a registry instance, and the clock
is injectable for deterministic tests — exactly like the async
frontend's.

``METRIC_SPECS`` is the canonical catalogue of metric names. It is the
single source of truth three consumers share:

- the registry pre-registers every spec, so the Prometheus exposition
  contains every documented metric name even before traffic arrives
  (the CI smoke asserts this);
- ``docs/observability.md`` documents the same table, and
  ``scripts/check_docs.py`` lints the two against each other both ways;
- the live energy bridge (:func:`repro.core.energy.counts_from_registry`)
  reads the measured-SOP counters by these names.

Exporters: :meth:`MetricsRegistry.to_prometheus` (text exposition
format: ``# HELP`` / ``# TYPE`` lines, cumulative ``le`` buckets,
``_sum`` / ``_count``) and :meth:`MetricsRegistry.snapshot` (a plain
JSON-able dict).

Histograms keep their fixed buckets AND a bounded rolling window of raw
samples, so callers that used to compute exact percentiles from their
own deques (the frontend's ``metrics()``) report unchanged values.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS",
    "BYTES_BUCKETS",
    "METRIC_SPECS",
    "MetricSpec",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
]

# Fixed bucket ladders (upper bounds, seconds / bytes). Chosen once here
# so every latency histogram in the stack is cross-comparable.
LATENCY_BUCKETS: tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
    5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)
BYTES_BUCKETS: tuple[float, ...] = (
    256.0, 1024.0, 4096.0, 16384.0, 65536.0, 262144.0,
    1048576.0, 4194304.0, 16777216.0,
)

# Rolling raw-sample window per histogram child — matches the async
# frontend's accounting window so its exact percentiles are unchanged.
SAMPLE_WINDOW = 100_000


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One catalogued metric: name, kind, help text, label names."""

    name: str
    kind: str  # "counter" | "gauge" | "histogram"
    help: str
    labels: tuple[str, ...] = ()
    buckets: tuple[float, ...] = LATENCY_BUCKETS


def _specs(*specs: MetricSpec) -> dict[str, MetricSpec]:
    return {s.name: s for s in specs}


# The canonical metric catalogue. docs/observability.md tables these
# names; scripts/check_docs.py lints the doc against this dict (and
# vice versa); the CI observability smoke asserts every name appears in
# a live exposition.
METRIC_SPECS: dict[str, MetricSpec] = _specs(
    # -- SpikeServer: datapath-adjacent counters ----------------------
    MetricSpec("snn_server_chunk_latency_seconds", "histogram",
               "Wall-clock latency of one SpikeServer.feed chunk step "
               "(one compiled masked step_chunk dispatch)."),
    MetricSpec("snn_server_slots_occupied", "gauge",
               "Slots currently bound to attached streams."),
    MetricSpec("snn_server_slots_total", "gauge",
               "Configured slot count of the server (n_slots)."),
    MetricSpec("snn_server_steps_total", "counter",
               "Active (slot, timestep) pairs consumed — masked-out "
               "slot steps are not counted."),
    MetricSpec("snn_server_chunks_total", "counter",
               "step_chunk dispatches issued by SpikeServer.feed."),
    MetricSpec("snn_server_spikes_total", "counter",
               "Output spikes emitted across all streams."),
    MetricSpec("snn_server_source_events_total", "counter",
               "Nonzero source events entering the accumulate, split "
               "external inputs vs recurrent (previous-step) spikes.",
               labels=("kind",)),
    MetricSpec("snn_server_sops_total", "counter",
               "Measured synaptic operations: each source event counts "
               "its row's nonzero fanout (trace.py semantics)."),
    MetricSpec("snn_server_row_fetches_total", "counter",
               "Weight-row fetches: nonzero SOPS_PER_ROW-wide row "
               "segments touched per source event (energy-model unit)."),
    MetricSpec("snn_server_weight_blocks_fetched_total", "counter",
               "128-source weight blocks fetched under the per-example "
               "event gate (tile_batch=1) across active steps."),
    MetricSpec("snn_server_weight_blocks_dense_total", "counter",
               "128-source weight blocks an ungated dense fetch would "
               "have moved across the same active steps."),
    # -- AsyncSpikeFrontend: request lifecycle ------------------------
    MetricSpec("snn_frontend_requests_total", "counter",
               "Requests by terminal-or-transition outcome: submitted, "
               "done, rejected, dropped, cancelled, expired, "
               "expired_queued, expired_running, parked, resumed, "
               "evicted.",
               labels=("outcome",)),
    MetricSpec("snn_frontend_class_outcomes_total", "counter",
               "Same outcomes split per tenant class (the QoS class / "
               "view name a request was submitted under).",
               labels=("stream_class", "outcome")),
    MetricSpec("snn_frontend_queue_depth", "gauge",
               "Requests waiting in the admission queue right now."),
    MetricSpec("snn_frontend_class_queue_depth", "gauge",
               "Per-tenant-class admission queue depth (QoS frontends "
               "only; every policy-declared class reports, zeros "
               "included).", labels=("stream_class",)),
    MetricSpec("snn_frontend_rounds_total", "counter",
               "pump() rounds executed."),
    MetricSpec("snn_frontend_queue_wait_seconds", "histogram",
               "Submit-to-admission wait per request class.",
               labels=("stream_class",)),
    MetricSpec("snn_frontend_service_seconds", "histogram",
               "Admission-to-retire service time per request class.",
               labels=("stream_class",)),
    MetricSpec("snn_frontend_total_seconds", "histogram",
               "Submit-to-retire total latency per request class.",
               labels=("stream_class",)),
    # -- Carry connector: snapshot / restore / migrate ----------------
    MetricSpec("snn_connector_ops_total", "counter",
               "Connector operations by kind: snapshot, restore, "
               "migrate.", labels=("op",)),
    MetricSpec("snn_connector_bytes_total", "counter",
               "Serialized CarrySnapshot bytes moved, by op "
               "(snapshot=written, restore=read).", labels=("op",)),
    MetricSpec("snn_connector_op_seconds", "histogram",
               "Latency of connector operations, by op.",
               labels=("op",)),
    # -- Mesh / straggler ---------------------------------------------
    MetricSpec("snn_shard_step_seconds", "gauge",
               "Most recent per-shard dispatch time attributed by the "
               "shard load watch.", labels=("shard",)),
    MetricSpec("snn_shard_straggler_flagged", "gauge",
               "1 while the straggler detector flags the shard, else 0.",
               labels=("shard",)),
    # -- Session lifecycle --------------------------------------------
    MetricSpec("snn_session_deploys_total", "counter",
               "AcceleratorSession.deploy calls (includes redeploys)."),
    MetricSpec("snn_session_redeploys_total", "counter",
               "Deploys that drained live streams through the "
               "connector (rolling redeploys)."),
    # -- SLO watchdog --------------------------------------------------
    MetricSpec("snn_slo_burn_rate", "gauge",
               "Most recent burn rate per SLO objective: observed value "
               "over threshold on the rolling window (> 1 = breaching).",
               labels=("objective",)),
    MetricSpec("snn_slo_breaches_total", "counter",
               "Breach onsets per SLO objective (counted on the "
               "transition into breach, not per evaluation).",
               labels=("objective",)),
)


# ---------------------------------------------------------------------
# Instruments. A *family* owns the metric name and its children, one
# child per label-value tuple; unlabeled metrics use the single
# default child, and the family proxies its methods for convenience.
# ---------------------------------------------------------------------
class _Child:
    __slots__ = ("labels",)

    def __init__(self, labels: tuple[tuple[str, str], ...]):
        self.labels = labels


class Counter(_Child):
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self, labels=()):
        super().__init__(labels)
        self.value = 0

    def inc(self, amount: float = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0: {amount}")
        self.value += amount


class Gauge(_Child):
    """Point-in-time value that can go up and down."""

    __slots__ = ("value",)

    def __init__(self, labels=()):
        super().__init__(labels)
        self.value = 0

    def set(self, value: float) -> None:
        self.value = value

    def inc(self, amount: float = 1) -> None:
        self.value += amount

    def dec(self, amount: float = 1) -> None:
        self.value -= amount


class Histogram(_Child):
    """Fixed-bucket histogram plus a rolling raw-sample window.

    The buckets serve the Prometheus exposition (cumulative ``le``
    counts); the bounded ``samples`` deque serves exact percentile
    reporting (the frontend's ``metrics()`` contract predates the
    registry and reports exact p50/p95 over its window — re-hosting it
    here must not change those numbers).
    """

    __slots__ = ("buckets", "bucket_counts", "sum", "count", "samples")

    def __init__(self, labels=(), buckets=LATENCY_BUCKETS):
        super().__init__(labels)
        self.buckets = tuple(buckets)
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +Inf last
        self.sum = 0.0
        self.count = 0
        self.samples = collections.deque(maxlen=SAMPLE_WINDOW)

    def observe(self, value: float) -> None:
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                self.bucket_counts[i] += 1
                break
        else:
            self.bucket_counts[-1] += 1
        self.sum += value
        self.count += 1
        self.samples.append(value)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """All children of one metric name, keyed by label values."""

    def __init__(self, spec: MetricSpec):
        self.spec = spec
        self._children: dict[tuple, _Child] = {}
        if not spec.labels:
            self._default = self._make(())
            self._children[()] = self._default
        else:
            self._default = None

    def _make(self, key: tuple) -> _Child:
        labels = tuple(zip(self.spec.labels, key))
        if self.spec.kind == "histogram":
            return Histogram(labels, self.spec.buckets)
        return _KINDS[self.spec.kind](labels)

    def labels(self, *values, **kv):
        """The child for these label values (created on first use)."""
        if kv:
            if set(kv) != set(self.spec.labels):
                raise ValueError(
                    f"{self.spec.name} takes labels {self.spec.labels}, "
                    f"got {sorted(kv)}"
                )
            values = tuple(str(kv[name]) for name in self.spec.labels)
        else:
            values = tuple(str(v) for v in values)
        if len(values) != len(self.spec.labels):
            raise ValueError(
                f"{self.spec.name} takes labels {self.spec.labels}, "
                f"got {values}"
            )
        child = self._children.get(values)
        if child is None:
            child = self._children[values] = self._make(values)
        return child

    @property
    def children(self):
        return dict(self._children)

    # Unlabeled convenience: family proxies the single default child.
    def _require_default(self) -> _Child:
        if self._default is None:
            raise ValueError(
                f"{self.spec.name} is labeled {self.spec.labels}; "
                f"use .labels(...)"
            )
        return self._default

    def inc(self, amount: float = 1):
        self._require_default().inc(amount)

    def dec(self, amount: float = 1):
        self._require_default().dec(amount)

    def set(self, value: float):
        self._require_default().set(value)

    def observe(self, value: float):
        self._require_default().observe(value)

    @property
    def value(self):
        return self._require_default().value


# ---------------------------------------------------------------------
class MetricsRegistry:
    """Every instrument in the process, behind one injectable object.

    Args:
      clock: monotonic-seconds callable used by :meth:`timer`; inject a
        fake for deterministic tests (the frontend shares this clock so
        its latency accounting and the registry's agree).
      specs: metric catalogue to pre-register; defaults to the full
        ``METRIC_SPECS`` so exports always contain every documented
        name. Ad-hoc metrics can still be registered via
        :meth:`register`.
    """

    def __init__(self, clock=time.perf_counter, *,
                 specs: dict[str, MetricSpec] | None = None):
        self.clock = clock
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}
        for spec in (METRIC_SPECS if specs is None else specs).values():
            self.register(spec)

    # -- registration / lookup ---------------------------------------
    def register(self, spec: MetricSpec) -> _Family:
        with self._lock:
            have = self._families.get(spec.name)
            if have is not None:
                if have.spec != spec:
                    raise ValueError(
                        f"metric {spec.name!r} re-registered with a "
                        f"different spec"
                    )
                return have
            if spec.kind not in _KINDS:
                raise ValueError(f"unknown metric kind {spec.kind!r}")
            fam = self._families[spec.name] = _Family(spec)
            return fam

    def _get(self, name: str, kind: str) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            raise KeyError(f"unregistered metric {name!r}")
        if fam.spec.kind != kind:
            raise TypeError(
                f"{name} is a {fam.spec.kind}, not a {kind}"
            )
        return fam

    def counter(self, name: str) -> _Family:
        return self._get(name, "counter")

    def gauge(self, name: str) -> _Family:
        return self._get(name, "gauge")

    def histogram(self, name: str) -> _Family:
        return self._get(name, "histogram")

    def timer(self, name: str, **labels):
        """Context manager observing elapsed clock time into ``name``."""
        return _Timer(self, name, labels)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._families))

    # -- exporters ----------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able dump: {name: {type, help, samples: [...]}}.

        Histogram samples carry buckets/sum/count; counter and gauge
        samples carry a scalar ``value``. Labels ride each sample.
        """
        out = {}
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                samples = []
                for key in sorted(fam.children):
                    child = fam.children[key]
                    entry = {"labels": dict(child.labels)}
                    if isinstance(child, Histogram):
                        entry["buckets"] = dict(
                            zip(map(_fmt_le, child.buckets),
                                child.bucket_counts[:-1])
                        )
                        entry["buckets"]["+Inf"] = child.bucket_counts[-1]
                        entry["sum"] = child.sum
                        entry["count"] = child.count
                    else:
                        entry["value"] = child.value
                    samples.append(entry)
                out[name] = {
                    "type": fam.spec.kind,
                    "help": fam.spec.help,
                    "samples": samples,
                }
        return out

    def to_prometheus(self) -> str:
        """Text exposition format, one HELP/TYPE block per family.

        Every registered family appears (the CI smoke greps for each
        documented name); labeled families with no traffic yet expose
        just their HELP/TYPE lines, Prometheus-style.
        """
        lines = []
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                lines.append(
                    f"# HELP {name} {_escape_help(fam.spec.help)}")
                lines.append(f"# TYPE {name} {fam.spec.kind}")
                for key in sorted(fam.children):
                    child = fam.children[key]
                    if isinstance(child, Histogram):
                        cum = 0
                        for ub, n in zip(child.buckets,
                                         child.bucket_counts):
                            cum += n
                            lbl = _labelstr(child.labels
                                            + (("le", _fmt_le(ub)),))
                            lines.append(f"{name}_bucket{lbl} {cum}")
                        cum += child.bucket_counts[-1]
                        lbl = _labelstr(child.labels + (("le", "+Inf"),))
                        lines.append(f"{name}_bucket{lbl} {cum}")
                        base = _labelstr(child.labels)
                        lines.append(f"{name}_sum{base} {_fmt(child.sum)}")
                        lines.append(f"{name}_count{base} {child.count}")
                    else:
                        lbl = _labelstr(child.labels)
                        lines.append(f"{name}{lbl} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"


class _Timer:
    def __init__(self, registry: MetricsRegistry, name: str, labels):
        self._registry = registry
        self._name = name
        self._labels = labels
        self._t0 = None

    def __enter__(self):
        self._t0 = self._registry.clock()
        return self

    def __exit__(self, *exc):
        hist = self._registry.histogram(self._name)
        child = hist.labels(**self._labels) if self._labels else hist
        child.observe(self._registry.clock() - self._t0)
        return False


def _fmt(v) -> str:
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


def _fmt_le(ub: float) -> str:
    return _fmt(float(ub))


def _escape(v: str) -> str:
    """Label-VALUE escaping per the text exposition format: backslash,
    newline, and double-quote (in that order — escaping backslash first
    keeps the others' escapes intact)."""
    return (v.replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _escape_help(v: str) -> str:
    """HELP-line escaping: the exposition format escapes backslash and
    newline there (quotes stay literal — HELP text is not quoted)."""
    return v.replace("\\", r"\\").replace("\n", r"\n")


def _labelstr(labels: tuple[tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"' for k, v in labels)
    return "{" + inner + "}"


# ---------------------------------------------------------------------
# Process-wide default. Components never reach for this implicitly —
# instrumentation is always injected — but launchers and tools want one
# shared place to export from.
# ---------------------------------------------------------------------
_global_registry: MetricsRegistry | None = None
_global_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-wide registry (created on first use)."""
    global _global_registry
    with _global_lock:
        if _global_registry is None:
            _global_registry = MetricsRegistry()
        return _global_registry


def set_registry(registry: MetricsRegistry | None) -> MetricsRegistry | None:
    """Swap the process-wide registry; returns the previous one."""
    global _global_registry
    with _global_lock:
        prev, _global_registry = _global_registry, registry
        return prev
