"""Stream-lifecycle tracing: typed spans, JSONL export, profiler hooks.

A :class:`SpanTracer` records what happened to each request/stream as a
sequence of typed spans::

    queued -> admitted(slot) -> chunk_step x N
           -> parked | migrated | redeployed | resumed ...
           -> retired(outcome)

Span kinds are catalogued in ``SPAN_KINDS`` (docs/observability.md
tables the same schema). Spans are either *events* (a point in time,
``t1 == t0``) or *durations* (opened as a context manager). Every span
carries the stream/request uid it belongs to (or ``None`` for
process-level spans like session deploys) plus free-form attributes.

Export is JSONL — one span per line, stable keys — so traces stream to
a file during a run and load with one ``json.loads`` per line.

When built with ``annotate=True`` and ``jax.profiler`` is importable,
duration spans also wrap their body in a
``jax.profiler.TraceAnnotation``, so kernel time shows up under named
lifecycle spans in a profiler trace captured via
:func:`profile_trace` (the ``serve_snn --profile DIR`` path).

Like the metrics registry, the tracer is injectable and clocked by an
injectable callable; components take ``tracer=None`` (no tracing, no
work) by default. Tracing reads the datapath and never changes it.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import time

__all__ = ["SPAN_KINDS", "Span", "SpanTracer", "profile_trace"]

# The lifecycle vocabulary. Tracers accept only these kinds, so a typo
# in an instrumentation site fails loudly instead of minting a new
# span type the docs don't know about.
SPAN_KINDS: tuple[str, ...] = (
    "queued",      # request entered the admission queue
    "admitted",    # bound to a slot (attrs: slot; resumed=True if from park)
    "chunk_step",  # one masked step_chunk dispatch (attrs: steps, slots)
    "parked",      # spilled to the connector mid-flight
    "resumed",     # re-admitted from a parked snapshot
    "migrated",    # carry moved between servers/slots via the connector
    "redeployed",  # drained + restored across a session redeploy
    "retired",     # terminal (attrs: outcome = done|cancelled|expired|...)
    "deploy",      # session (re)deploy of compiled programs
    "snapshot",    # connector snapshot write (attrs: nbytes)
    "restore",     # connector snapshot read (attrs: nbytes)
    "shard_step",  # one sharded dispatch (attrs: per-shard times, flags)
)


@dataclasses.dataclass
class Span:
    """One recorded span. ``t1 == t0`` for instantaneous events."""

    kind: str
    uid: int | str | None
    t0: float
    t1: float
    attrs: dict

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {
            "kind": self.kind,
            "uid": self.uid,
            "t0": self.t0,
            "t1": self.t1,
            "dur": self.t1 - self.t0,
            "attrs": self.attrs,
        }


class SpanTracer:
    """Record typed lifecycle spans; export as JSONL.

    Args:
      clock: monotonic-seconds callable (injectable for determinism).
      annotate: also wrap duration spans in
        ``jax.profiler.TraceAnnotation`` when jax is importable, so a
        captured profiler trace nests kernel time under lifecycle
        spans. Off by default — annotation costs a little per span.
      sink: optional open text file; when set, each completed span is
        written through immediately (one JSON line) as well as kept in
        memory. Lets ``--trace FILE`` stream during long runs.
    """

    def __init__(self, clock=time.perf_counter, *,
                 annotate: bool = False, sink=None):
        self.clock = clock
        self.annotate = annotate
        self._sink = sink
        self._lock = threading.Lock()
        self._spans: list[Span] = []

    # -- recording ----------------------------------------------------
    def _record(self, span: Span) -> Span:
        with self._lock:
            self._spans.append(span)
            if self._sink is not None:
                self._sink.write(json.dumps(span.to_dict()) + "\n")
        return span

    def event(self, kind: str, uid=None, **attrs) -> Span:
        """An instantaneous lifecycle event (t1 == t0)."""
        self._check(kind)
        now = self.clock()
        return self._record(Span(kind, uid, now, now, attrs))

    @contextlib.contextmanager
    def span(self, kind: str, uid=None, **attrs):
        """A duration span around the ``with`` body.

        Attributes added to the yielded dict inside the body are kept
        (e.g. ``s["steps"] = n`` once known).
        """
        self._check(kind)
        t0 = self.clock()
        ann = self._annotation(kind, uid)
        try:
            if ann is not None:
                with ann:
                    yield attrs
            else:
                yield attrs
        finally:
            self._record(Span(kind, uid, t0, self.clock(), attrs))

    def _check(self, kind: str) -> None:
        if kind not in SPAN_KINDS:
            raise ValueError(
                f"unknown span kind {kind!r}; expected one of {SPAN_KINDS}"
            )

    def _annotation(self, kind: str, uid):
        if not self.annotate:
            return None
        try:
            from jax.profiler import TraceAnnotation
        except Exception:  # pragma: no cover - jax always present here
            return None
        name = kind if uid is None else f"{kind}:{uid}"
        return TraceAnnotation(name)

    # -- reading / export ---------------------------------------------
    @property
    def spans(self) -> list[Span]:
        with self._lock:
            return list(self._spans)

    def spans_for(self, uid) -> list[Span]:
        return [s for s in self.spans if s.uid == uid]

    def to_dicts(self) -> list[dict]:
        return [s.to_dict() for s in self.spans]

    def export_jsonl(self, path) -> int:
        """Write every span as one JSON line; returns the span count.

        ``path`` may be a filesystem path or an open text file.
        """
        spans = self.to_dicts()
        if hasattr(path, "write"):
            for d in spans:
                path.write(json.dumps(d) + "\n")
        else:
            with open(path, "w") as fh:
                for d in spans:
                    fh.write(json.dumps(d) + "\n")
        return len(spans)


@contextlib.contextmanager
def profile_trace(log_dir: str | None):
    """``jax.profiler`` capture around a block (no-op when dir is None).

    The ``serve_snn --profile DIR`` path: combined with a tracer built
    with ``annotate=True``, the captured trace nests device/kernel time
    under the lifecycle span names.
    """
    if log_dir is None:
        yield
        return
    import jax

    jax.profiler.start_trace(log_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
