"""Declarative SLO objectives evaluated as rolling burn-rate windows.

An :class:`SLObjective` states a promise about the serving front door —
"p99 total latency under 80 ms", "deadline-miss ratio under 2%",
"admission queue never deeper than 16" — and an :class:`SLOWatchdog`
holds a set of them against live traffic. The frontend's pump feeds the
watchdog (total latencies on retire, misses on deadline expiry, queue
depth each round) and calls :meth:`SLOWatchdog.check` once per round;
the watchdog prunes its rolling windows on the injectable clock,
computes each objective's **burn rate** — observed value over threshold,
the classic error-budget-consumption number, > 1 while breaching — and
fires ``on_breach`` callbacks on the *transition into* breach (one dump
per incident, not one per evaluation).

Like everything in ``repro.obs``, the watchdog is observational: it
never touches admission, and with a registry attached it mirrors burn
rates into the ``snn_slo_burn_rate`` gauges and breach onsets into
``snn_slo_breaches_total``.

Objective kinds:

- ``"latency_p99"`` — p99 of recorded total latencies (seconds) in the
  window vs a seconds threshold.
- ``"miss_ratio"`` — deadline misses / (misses + dones) in the window vs
  a ratio threshold. Every deadline expiry counts as a miss, whether the
  request was refused while queued, evicted mid-stream, or spilled to a
  connector: the deadline was missed either way.
- ``"queue_depth"`` — max recorded queue depth in the window vs a depth
  ceiling.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

__all__ = ["SLObjective", "SLOStatus", "SLOWatchdog"]

_KINDS = ("latency_p99", "miss_ratio", "queue_depth")


@dataclasses.dataclass(frozen=True)
class SLObjective:
    """One declarative objective: a kind, a threshold, a window."""

    name: str
    kind: str            # one of _KINDS
    threshold: float     # seconds / ratio / depth, by kind
    window_s: float = 60.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown SLO kind {self.kind!r}; expected one of "
                f"{_KINDS}")
        if self.threshold <= 0:
            raise ValueError(
                f"SLO threshold must be positive, got {self.threshold}")
        if self.window_s <= 0:
            raise ValueError(
                f"SLO window_s must be positive, got {self.window_s}")


@dataclasses.dataclass
class SLOStatus:
    """One objective's state at one evaluation."""

    objective: SLObjective
    value: float | None   # observed value on the window (None: no data)
    burn_rate: float      # value / threshold (0.0 with no data)
    breached: bool
    n_samples: int

    def to_dict(self) -> dict:
        return {
            "name": self.objective.name,
            "kind": self.objective.kind,
            "threshold": self.objective.threshold,
            "window_s": self.objective.window_s,
            "value": self.value,
            "burn_rate": self.burn_rate,
            "breached": self.breached,
            "n_samples": self.n_samples,
        }


class SLOWatchdog:
    """Hold SLO objectives against a live run; evaluate burn rates.

    Args:
      objectives: the :class:`SLObjective` set to watch.
      clock: injectable monotonic-seconds callable (virtual in tests).
      registry: optional ``MetricsRegistry`` — burn rates mirror into
        ``snn_slo_burn_rate{objective=...}``, breach onsets count in
        ``snn_slo_breaches_total{objective=...}``.
      on_breach: callables fired with the :class:`SLOStatus` on each
        transition into breach (e.g. a flight recorder's dump hook).
    """

    def __init__(self, objectives, *, clock=time.perf_counter,
                 registry=None, on_breach=()):
        objectives = list(objectives)
        names = [o.name for o in objectives]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate objective names: {names}")
        self.objectives = objectives
        self.clock = clock
        self.registry = registry
        self.on_breach = list(on_breach if not callable(on_breach)
                              else [on_breach])
        # rolling (t, value) samples per signal; pruned to the longest
        # objective window at each check. Misses and dones are events
        # (value unused); latencies and depths carry their value.
        self._samples: dict[str, collections.deque] = {
            "latency": collections.deque(),
            "miss": collections.deque(),
            "done": collections.deque(),
            "depth": collections.deque(),
        }
        self._breached: dict[str, bool] = {o.name: False
                                           for o in objectives}
        self._breach_counts: dict[str, int] = {o.name: 0
                                               for o in objectives}

    # -- recording (the frontend pump's feed points) ------------------
    def record_done(self, total_seconds: float) -> None:
        """A request retired in time; its submit-to-retire latency."""
        now = self.clock()
        self._samples["latency"].append((now, float(total_seconds)))
        self._samples["done"].append((now, 1.0))

    def record_miss(self) -> None:
        """A deadline was missed (refusal, eviction, or spill)."""
        self._samples["miss"].append((self.clock(), 1.0))

    def record_queue_depth(self, depth: int) -> None:
        self._samples["depth"].append((self.clock(), float(depth)))

    # -- evaluation ---------------------------------------------------
    def _window(self, signal: str, now: float,
                window_s: float) -> list[float]:
        return [v for t, v in self._samples[signal]
                if t >= now - window_s]

    def _prune(self, now: float) -> None:
        horizon = max((o.window_s for o in self.objectives), default=0.0)
        for dq in self._samples.values():
            while dq and dq[0][0] < now - horizon:
                dq.popleft()

    def _evaluate(self, obj: SLObjective, now: float) -> SLOStatus:
        if obj.kind == "latency_p99":
            xs = self._window("latency", now, obj.window_s)
            value = (float(np.percentile(np.asarray(xs), 99))
                     if xs else None)
            n = len(xs)
        elif obj.kind == "miss_ratio":
            misses = len(self._window("miss", now, obj.window_s))
            dones = len(self._window("done", now, obj.window_s))
            n = misses + dones
            value = misses / n if n else None
        else:  # queue_depth
            xs = self._window("depth", now, obj.window_s)
            value = float(max(xs)) if xs else None
            n = len(xs)
        burn = (value / obj.threshold) if value is not None else 0.0
        return SLOStatus(objective=obj, value=value, burn_rate=burn,
                         breached=burn > 1.0, n_samples=n)

    def check(self, now: float | None = None) -> list[SLOStatus]:
        """Evaluate every objective on its rolling window.

        Updates the registry mirrors, fires ``on_breach`` on each
        objective's transition into breach, and returns the statuses.
        """
        now = self.clock() if now is None else now
        self._prune(now)
        statuses = [self._evaluate(o, now) for o in self.objectives]
        for status in statuses:
            name = status.objective.name
            if self.registry is not None:
                self.registry.gauge("snn_slo_burn_rate").labels(
                    objective=name).set(status.burn_rate)
            if status.breached and not self._breached[name]:
                self._breach_counts[name] += 1
                if self.registry is not None:
                    self.registry.counter(
                        "snn_slo_breaches_total").labels(
                        objective=name).inc()
                for cb in self.on_breach:
                    cb(status)
            self._breached[name] = status.breached
        return statuses

    # -- reporting ----------------------------------------------------
    def report(self, now: float | None = None) -> dict:
        """Structured summary: one entry per objective plus breach
        totals — the ``slo`` block of the serve_snn summary. Pure read:
        neither registry mirrors nor breach callbacks fire (report()
        reads, check() acts)."""
        now = self.clock() if now is None else now
        self._prune(now)
        return {
            "objectives": [self._evaluate(o, now).to_dict()
                           for o in self.objectives],
            "breaches": dict(self._breach_counts),
        }
