"""End-to-end driver: the paper's Table IV experiment grid.

Default: one width sweep (5 experiments, CPU-minutes). ``--grid`` runs the
paper's full 80-experiment grid (5 widths x 4 train-T x 4 infer-T) — hours
on CPU, exactly the benchmark table. Results stream to CSV.

    PYTHONPATH=src python examples/train_mnist_snn.py [--grid] [--out f.csv]
"""

import argparse
import sys

sys.path.insert(0, "benchmarks")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", action="store_true",
                    help="full 80-experiment paper grid")
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--eval-n", type=int, default=512)
    ap.add_argument("--out", default=None, help="also write CSV here")
    args = ap.parse_args()

    from benchmarks import table_iv_accuracy

    argv = ["--train-steps", str(args.train_steps),
            "--eval-n", str(args.eval_n)]
    if args.grid:
        argv.append("--full")
    rows = table_iv_accuracy.main(argv)

    if args.out:
        import csv
        with open(args.out, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
        print(f"[grid] wrote {len(rows)} rows to {args.out}")


if __name__ == "__main__":
    main()
