"""Event-camera inference on the event-driven sparse path, end to end.

    PYTHONPATH=src python examples/event_camera.py [--backend pallas]

The paper's defining property is that compute, weight traffic, and energy
scale with spike ACTIVITY, not with model size. This example exercises
that property with the repo's sparsest workload: synthetic DVS-gesture
clips (~1-3 % dense) arrive as an AER event stream, run through the
accelerator with the per-example event gate, and come back out as events —
with the trace recorder measuring, from the real rasters, exactly how much
work the sparsity saved:

  1. render gesture clips and wrap them as one AER stream (wire format);
  2. compile a random gesture SNN to a Cerebra-H program;
  3. run AER-in/AER-out on the gated engine and verify BIT-identity with
     the dense reference path (sparsity is an optimization, never an
     approximation);
  4. trace the run: measured SOPs + gated-vs-dense weight-block traffic
     under both gate granularities;
  5. price it: the Table V energy model evaluated on MEASURED counts.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core import cerebra_h, energy
from repro.core.engine import BACKENDS, GATES
from repro.core.lif import LIFParams
from repro.core.network import SNNetwork
from repro.data import events as ev_data
from repro.events import aer, trace


def make_gesture_net(rng, n_in: int, *, hidden: int = 96,
                     n_out: int = len(ev_data.GESTURES)) -> SNNetwork:
    """Random sparse SNN over the sensor channels (untrained demo — the
    example's claims are about the datapath, not accuracy)."""
    n_neurons = hidden + n_out
    W = np.zeros((n_in + n_neurons, n_neurons), np.float32)
    W[:n_in, :hidden] = ((rng.random((n_in, hidden)) < 0.08)
                         * rng.normal(0.0, 0.9, (n_in, hidden)))
    W[n_in:n_in + hidden, hidden:] = (
        (rng.random((hidden, n_out)) < 0.4)
        * rng.normal(0.0, 0.6, (hidden, n_out)))
    return SNNetwork(
        n_inputs=n_in, n_neurons=n_neurons, weights=W,
        params=LIFParams(decay_rate=0.25, threshold=1.0, reset_mode="zero"),
        output_slice=(hidden, n_neurons))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS, default="reference")
    ap.add_argument("--gate", choices=GATES, default="per-example")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rng = np.random.default_rng(args.seed)

    # 1. the stimulus is an EVENT STREAM, not a raster
    stream, labels = ev_data.gesture_events(
        "test", args.batch, steps=args.steps, size=args.size, seed=args.seed)
    T, B, D = stream.shape
    print(f"[event-camera] {B} gesture clips x {T} steps on a "
          f"{args.size}x{args.size}x2 sensor: {int(stream.total)} events "
          f"({100 * stream.sparsity:.2f}% dense)")

    # 2. compile to the accelerator
    net = make_gesture_net(rng, D)
    prog = cerebra_h.compile_network(net)

    # 3. event-gated AER-in/AER-out run, checked against the dense path
    dense_ext = np.asarray(aer.aer_to_dense(stream))
    ref = cerebra_h.make_engine(prog, "reference").run(dense_ext)
    engine = cerebra_h.make_engine(prog, args.backend).with_gate(args.gate)
    out = engine.run(stream,
                     events_capacity=int(np.asarray(ref["spikes"]).sum()))
    assert np.array_equal(np.asarray(out["spikes"]),
                          np.asarray(ref["spikes"])), \
        "event-gated AER path diverged from the dense reference"
    out_events = out["events"]
    print(f"[event-camera] backend={args.backend} gate={args.gate}: "
          f"AER in -> {int(out_events.total)} spike events out, "
          f"bit-identical to the dense reference")
    counts = np.asarray(out["spikes"])[
        :, :, np.asarray(prog.output_map)].sum(axis=0)
    print(f"[event-camera] decoded gestures (untrained): "
          f"{[ev_data.GESTURES[i] for i in counts.argmax(axis=-1)]}")

    # 4. measured accounting from the real rasters
    report = trace.trace_run(engine, dense_ext, out["spikes"])
    print(f"[event-camera] trace: {report.summary()}")
    tile, example = (report.traffic_ratio("batch-tile"),
                     report.traffic_ratio("per-example"))
    print(f"[event-camera] per-example gate fetches "
          f"{100 * example:.1f}% of dense weight blocks "
          f"(batch-tile gate: {100 * tile:.1f}%) -> "
          f"{tile / max(example, 1e-9):.1f}x less traffic from "
          f"per-example gating alone")

    # 5. energy from MEASURED counts (not analytic estimates)
    measured = trace.measured_counts(prog, dense_ext, out["spikes"])
    model = energy.EnergyModel.calibrated()
    uj = model.energy_uj(measured)
    print(f"[event-camera] measured energy: {measured.sops:.0f} SOPs -> "
          f"{uj['dynamic_uj']:.2f} uJ dynamic "
          f"({model.e_sop_pj} pJ/SOP compute path, "
          f"{uj['pj_per_sop_system']:.0f} pJ/SOP system incl. static)")


if __name__ == "__main__":
    main()
