"""Multi-model co-residency (paper §V-D).

The hierarchical NoC + address-space isolation let Cerebra-H host several
SNN models at once in disjoint cluster ranges. This example deploys THREE
workloads side by side — a digit classifier, a robot controller, and an
anomaly scorer — runs them concurrently in fused SpikeEngine scans (one
scan per shared LIF configuration, exactly like the hardware timestep
advancing all clusters at once), and verifies isolation (each model's
outputs are bit-identical to running it alone).

    PYTHONPATH=src python examples/multi_model.py [--backend pallas]
"""

import argparse

import jax
import numpy as np

from repro.core.lif import LIFParams
from repro.core.session import AcceleratorSession
from repro.data import mnist
from repro.snn.model import SNNModelConfig, to_snnetwork
from repro.snn.train import TrainConfig, train

from robot_control import build_controller  # noqa: E402 (same dir)


def anomaly_net(rng) -> "SNNetwork":
    from repro.core.network import feedforward
    w1 = rng.normal(0, 0.4, (16, 24)).astype(np.float32)
    w2 = rng.normal(0, 0.5, (24, 2)).astype(np.float32)
    return feedforward([w1, w2], LIFParams(decay_rate=0.25))


def main() -> None:
    from repro.core.engine import BACKENDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS, default="reference")
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    # model 1: trained digit classifier (784 -> 32 -> 10)
    cfg = TrainConfig(model=SNNModelConfig(layer_sizes=(784, 32, 10)),
                      num_steps_time=10, train_steps=80, batch_size=64)
    params, _, _ = train(
        cfg, mnist.batches("train", 64, cfg.train_steps, seed=1),
        log_every=0)
    digits = to_snnetwork(params, cfg.model)

    sess = AcceleratorSession(backend=args.backend)
    m1 = sess.deploy("digits", digits)        # 784->32->10: 42 neurons
    m2 = sess.deploy("pid", build_controller())
    m3 = sess.deploy("anomaly", anomaly_net(rng))
    for m in (m1, m2, m3):
        print(f"[multi] {m.name:8s} clusters {m.cluster_range}")
    u = sess.utilization()
    print(f"[multi] total utilization: {u['neuron_utilization']*100:.1f}% "
          f"neurons, {u['row_utilization']*100:.1f}% SRAM rows")

    # concurrent inference
    key = jax.random.key(7)
    xd, yd = mnist.load_or_generate("test", 64, seed=2)
    xc = np.clip(rng.random((64, 2)), 0, 1).astype(np.float32)
    xa = rng.random((64, 16)).astype(np.float32)
    outs = sess.run_all({"digits": xd, "pid": xc, "anomaly": xa}, 20, key)
    acc = (np.asarray(outs["digits"]["predictions"]) == yd).mean()
    print(f"[multi] digits acc while co-resident: {acc:.3f}")

    # isolation proof: digits alone == digits co-resident
    solo = AcceleratorSession(backend=args.backend)
    solo.deploy("digits", digits)
    ref = solo.run("digits", xd, 20, key)
    same = np.array_equal(np.asarray(ref["output_counts"]),
                          np.asarray(outs["digits"]["output_counts"]))
    print(f"[multi] isolation (bit-identical to solo run): {same}")
    assert same


if __name__ == "__main__":
    main()
