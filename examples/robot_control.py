"""Closed-loop neuromorphic control — the paper's target use case.

The paper motivates SNAP-V with 20-40-neuron control networks (event-based
PID for quadrotors [17], lane keeping [16], NeuroPod locomotion [2]). This
example builds a ~36-neuron spiking PID-style controller, deploys it on
the Cerebra-H model, and runs a closed perception->action loop against a
simulated first-order plant: sensor error -> hardware rate encoder ->
accelerator -> hardware decoder -> actuator command.

The controller is hand-wired (no training): two antagonistic populations
("too high" / "too low") whose firing rates drive the actuator — the
standard neuromorphic PID construction of Stagsted et al. [17].

Since PR 2 the loop runs on the STREAMING path (``session.serve``): the
controller attaches once as a persistent stream, membrane state carries
across control ticks (the accelerator never resets mid-episode, exactly
like the hardware), and each tick pushes one chunk of encoded error
spikes through the shared compiled slot-batch step — the decoded actuator
command of tick t shapes the encoder input of tick t+1.

    PYTHONPATH=src python examples/robot_control.py
"""

import jax
import numpy as np

from repro.core import coding
from repro.core.lif import LIFParams
from repro.core.network import SNNetwork
from repro.core.session import AcceleratorSession


def build_controller(n_per_pop: int = 12, gain: float = 0.9) -> SNNetwork:
    """36-neuron spiking P-controller.

    Inputs (2): error+ (setpoint above state), error- (below).
    Populations: E+ (n), E- (n), and an antagonist-inhibition layer (n)
    that sharpens the response. Outputs: the E+/E- populations; actuator
    command = (rate(E+) - rate(E-)) * u_max.
    """
    n = n_per_pop
    N = 3 * n
    W = np.zeros((2 + N, N), np.float32)
    # error+ excites E+ (slots 0:n); error- excites E- (slots n:2n)
    W[0, 0:n] = gain
    W[1, n:2 * n] = gain
    # E+ excites the inhibition pool (2n:3n); pool inhibits E-
    W[2 + np.arange(0, n), 2 * n + np.arange(n)] = 0.5
    W[2 + 2 * n + np.arange(n), n + np.arange(n)] = -0.4
    # subtract reset + slow leak: the membrane integrates its input rate,
    # so output rate tracks input intensity almost linearly (the firing-
    # rate P-term of Stagsted et al.)
    return SNNetwork(
        n_inputs=2, n_neurons=N, weights=W,
        params=LIFParams(decay_rate=0.125, threshold=0.8,
                         reset_mode="subtract"),
        output_slice=(0, 2 * n),
    )


def main() -> None:
    net = build_controller()
    sess = AcceleratorSession()
    sess.deploy("pid", net)
    print(f"[control] deployed {net.n_neurons}-neuron controller "
          f"({sess.utilization()['clusters_used']} clusters, "
          f"{100 * sess.utilization()['neuron_utilization']:.1f}% of the "
          f"1024-neuron array — the paper's under-utilization story)")

    # streaming closed loop: one persistent stream, membrane state carries
    # across control ticks through the slot carry
    stream = sess.serve("pid", n_slots=4, chunk_steps=8)
    uid = stream.attach()

    # integrator plant (position control): x' = 0.8 u, setpoint 0.7
    x, setpoint, dt = 0.0, 0.7, 1.0
    u_max, err_scale, T = 0.25, 0.5, 24
    key = jax.random.key(0)
    n = net.output_slice[1] // 2
    print(f"{'t':>3} {'state':>8} {'error':>8} {'u':>8}")
    for t in range(30):
        err = setpoint - x
        sensor = np.asarray(
            [max(err, 0.0) / err_scale, max(-err, 0.0) / err_scale],
            np.float32)
        key, k = jax.random.split(key)
        ext = np.asarray(coding.poisson_encode(
            k, np.clip(sensor, 0, 1), T, dtype=np.int32))  # (T, 2)
        out = stream.feed(uid, ext)  # decoded output -> next tick's encoder
        counts = np.asarray(out["output_counts"])
        rate_pos = counts[:n].mean() / T
        rate_neg = counts[n:2 * n].mean() / T
        u = float(u_max * (rate_pos - rate_neg))
        x = x + dt * 0.8 * u
        if t % 3 == 0:
            print(f"{t:>3} {x:>8.3f} {err:>8.3f} {u:>8.3f}")
    stats = stream.detach(uid)
    assert abs(setpoint - x) < 0.15, "controller failed to converge"
    print(f"[control] settled at x={x:.3f} (setpoint {setpoint}) after "
          f"{stats.steps} streamed timesteps — closed loop through "
          f"encoder -> streaming Cerebra-H -> decoder, no state reset "
          f"between ticks")


if __name__ == "__main__":
    main()
