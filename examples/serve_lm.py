"""Serve a small LM with batched requests through the serving runtime.

    PYTHONPATH=src python examples/serve_lm.py [--arch granite-3-2b]

Uses the REDUCED config on CPU; the identical step function lowers for
the production mesh in the decode_32k / long_500k dry-run cells.
"""

import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve"] + sys.argv[1:],
        env={**__import__("os").environ, "PYTHONPATH": "src"}))
