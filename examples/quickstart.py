"""Quickstart: train a spiking MLP, deploy it to the Cerebra-H model,
compare software vs hardware inference, and read out the energy report.

    PYTHONPATH=src python examples/quickstart.py [--backend pallas]

This is the paper's whole pipeline in ~60 lines: snnTorch-style training
(JAX surrogate gradients) -> hardware config compiler -> bit-exact
accelerator inference (on the SpikeEngine backend of your choice) ->
Table IV-style deviation + Table V-style power.
"""

import argparse

import jax

from repro.core import cerebra_h, energy
from repro.core.engine import BACKENDS
from repro.core.lif import LIFParams
from repro.data import mnist
from repro.snn.model import SNNModelConfig, to_snnetwork
from repro.snn.train import TrainConfig, evaluate_dual, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS, default="reference")
    args = ap.parse_args()
    # 1. train the software reference model (784 -> 64 -> 10 LIF MLP)
    cfg = TrainConfig(
        model=SNNModelConfig(layer_sizes=(784, 64, 10),
                             params=LIFParams(decay_rate=0.1)),
        num_steps_time=15, lr=3e-3, batch_size=96, train_steps=150)
    data = mnist.batches("train", cfg.batch_size, cfg.train_steps, seed=0)
    params, _, metrics = train(cfg, data, log_every=50)
    print(f"[quickstart] final train acc: {float(metrics['acc']):.3f}")

    # 2. software-vs-hardware inference on identical spike trains
    x, y = mnist.load_or_generate("test", 512, seed=1)
    res = evaluate_dual(params, cfg.model, x, y, num_steps_time=25,
                        backend=args.backend)
    print(f"[quickstart] software acc: {res['software_acc']:.3f}  "
          f"hardware acc: {res['hardware_acc']:.3f}  "
          f"deviation: {res['deviation_pct']:+.2f}pp  "
          f"(paper avg: -2.62pp)")

    # 3. deployment report: mapping + cycles + energy
    net = to_snnetwork(params, cfg.model)
    prog = cerebra_h.compile_network(net)
    rows = prog.capacity_report["rows_per_group"]
    print(f"[quickstart] SRAM rows/group used: {list(rows)} "
          f"(budget {prog.config.geometry.rows_per_group})")

    counts = energy.counts_from_run(res["hw_counts"])
    model = energy.EnergyModel.calibrated()
    mw = model.breakdown_mw(counts)
    print(f"[quickstart] power: total {mw['total_mw']:.1f} mW, "
          f"weight memory {mw['weight_memory_pct']:.1f}% "
          f"(paper: 95.97%), compute {model.e_sop_pj} pJ/SOP")


if __name__ == "__main__":
    main()
