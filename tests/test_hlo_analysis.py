"""Pin the loop-aware HLO analyzer against XLA's own cost_analysis on
programs where XLA is correct (no loops), and against hand-computed totals
on scanned programs (where XLA undercounts — the reason the module exists).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.hlo_analysis import analyze_hlo, collective_profile, memory_profile

L, B, D = 6, 4, 64


def _cost(compiled) -> dict:
    """jax's Compiled.cost_analysis() returns a dict on some versions and
    a single-element list of dicts on others (the seed's latent TypeError
    when indexed unconditionally) — normalize."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca


def _body(x, w):
    return jnp.tanh(x @ w), None


def _scanned(x, W):
    y, _ = jax.lax.scan(_body, x, W)
    return y.sum()


def _unrolled(x, W):
    for i in range(L):
        x, _ = _body(x, W[i])
    return x.sum()


@pytest.fixture(scope="module")
def compiled_pair():
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    W = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    cs = jax.jit(_scanned).lower(x, W).compile()
    cu = jax.jit(_unrolled).lower(x, W).compile()
    return cs, cu


def test_matches_xla_on_unrolled(compiled_pair):
    _, cu = compiled_pair
    got = analyze_hlo(cu.as_text())
    want = _cost(cu)
    # dot flops must match exactly; elementwise conventions differ slightly
    dot_flops = L * 2 * B * D * D
    assert got.flops >= dot_flops
    assert abs(got.flops - float(want["flops"])) / float(want["flops"]) < 0.2
    assert (abs(got.bytes_accessed - float(want["bytes accessed"]))
            / float(want["bytes accessed"]) < 0.5)


def test_corrects_scan_undercount(compiled_pair):
    cs, cu = compiled_pair
    got_s = analyze_hlo(cs.as_text())
    xla_s = _cost(cs)
    dot_flops = L * 2 * B * D * D
    # XLA counts the body once -> ~1/L of the true dot flops
    assert float(xla_s["flops"]) < dot_flops
    # the analyzer recovers the full trip count
    assert got_s.flops >= dot_flops
    assert got_s.flops < dot_flops * 2.5


def test_collectives_multiplied_by_trip_count():
    from jax.sharding import NamedSharding, PartitionSpec as P
    if jax.device_count() < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("model",))

    def body(x, w):
        y = x @ w
        y = jax.lax.psum(y, "model")
        return y, None

    if hasattr(jax, "shard_map"):
        smap = jax.shard_map
        relax = {"check_vma": False}
    else:  # pre-0.6 spelling
        from jax.experimental.shard_map import shard_map as smap
        relax = {"check_rep": False}

    def f(x, W):
        return jax.lax.scan(
            smap(body, mesh=mesh, in_specs=(P(), P()),
                 out_specs=(P(), P()), **relax),
            x, W)[0].sum()

    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    W = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    c = jax.jit(f).lower(x, W).compile()
    got = analyze_hlo(c.as_text())
    if got.collective_counts:  # single-device builds may elide the psum
        assert got.collective_counts.get("all-reduce", 0) == L
        assert got.collective_bytes["all-reduce"] == L * B * D * 4


def test_cost_analysis_normalizer_yields_mapping(compiled_pair):
    """Whatever container this jax version returns, the normalized view is
    a mapping with the keys the suite reads — the version-compat contract
    the (fixed) seed debt was about."""
    cs, cu = compiled_pair
    for c in (cs, cu):
        d = _cost(c)
        assert isinstance(d, dict)
        assert "flops" in d and "bytes accessed" in d


def test_parser_tolerates_degenerate_text():
    """Empty / unrecognized HLO text reports zero cost instead of crashing
    — the analyzer's own latent parser debt, pinned."""
    for text in ("", "HloModule empty\n", "garbage {{{ not hlo"):
        got = analyze_hlo(text)
        assert got.flops == 0.0
        assert got.bytes_accessed == 0.0
        assert got.collective_counts == {}
    assert memory_profile("") == []
    assert collective_profile("") == []


def test_nested_loops_multiply():
    def inner(x, w):
        return jnp.tanh(x @ w), None

    def outer(x, Ws):
        def step(c, W):
            y, _ = jax.lax.scan(inner, c, W)
            return y, None
        return jax.lax.scan(step, x, Ws)[0].sum()

    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    Ws = jax.ShapeDtypeStruct((3, L, D, D), jnp.float32)
    c = jax.jit(outer).lower(x, Ws).compile()
    got = analyze_hlo(c.as_text())
    dot_flops = 3 * L * 2 * B * D * D
    assert got.flops >= dot_flops
    assert got.flops < dot_flops * 2.5
