"""Mesh-sharded engine parity: scale-out must never change a bit.

The acceptance criterion of the mesh subsystem: for a >= 2x2
(neuron x batch) mesh — CI fakes 8 CPU devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — every
``MeshSpikeEngine`` output (spike rasters, final carries, decoded
outputs) is BYTE-identical to the single-device engine, for every
backend x reset mode, including ``step_chunk`` masked-slot semantics,
fused multi-model ``run_all``, and streaming ``feed()`` through a
sharded ``SpikeServer``. On a single-device run (the plain tier-1 leg)
the multi-device cases skip and the degenerate 1x1-mesh cases still
exercise the shard_map path end to end.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import BACKENDS, DecaySpec, SpikeEngine
from repro.core.session import AcceleratorSession
from repro.distributed.spike_mesh import (MeshSpikeEngine, make_spike_mesh)
from repro.serving.snn import SpikeServer

from conftest import make_random_net

THRESH = 1 << 16
RESET_MODES = ("zero", "subtract", "hold")

# deliberately ragged: neither n_phys nor B divides a 2-way mesh axis
RAGGED_SHAPES = [
    # (B, n_inputs, n_phys)
    (3, 37, 48),
    (1, 1, 1),
    (5, 200, 130),
]


def _mesh(neuron, batch):
    need = neuron * batch
    if len(jax.devices()) < need:
        pytest.skip(
            f"needs {need} devices; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return make_spike_mesh(neuron=neuron, batch=batch)


def _engine_pair(rng, *, backend="reference", reset="subtract", decay=None,
                 B=4, n_in=37, n_phys=48, mesh=None, density=0.3,
                 wmax=1 << 13):
    S = n_in + n_phys
    W = jnp.asarray(
        (rng.random((S, n_phys)) < density)
        * rng.integers(-wmax, wmax, (S, n_phys)), jnp.int32)
    kw = dict(decay=decay or DecaySpec.shift(0.25), threshold_raw=THRESH,
              reset_mode=reset, backend=backend)
    single = SpikeEngine(W, n_in, **kw)
    sharded = MeshSpikeEngine(W, n_in, mesh=mesh, **kw)
    return single, sharded


def _assert_run_parity(single, sharded, ext):
    a = single.run(ext)
    b = sharded.run(ext)
    for k in ("spikes", "v_final"):
        av, bv = np.asarray(a[k]), np.asarray(b[k])
        assert av.dtype == bv.dtype == np.int32
        np.testing.assert_array_equal(av, bv)


# --------------------------------------------------------------------------
# Construction contracts
# --------------------------------------------------------------------------

def test_make_spike_mesh_validation():
    with pytest.raises(ValueError, match="devices"):
        make_spike_mesh(neuron=len(jax.devices()) + 1, batch=2)
    with pytest.raises(ValueError, match=">= 1"):
        make_spike_mesh(neuron=0)
    mesh = make_spike_mesh(neuron=1, batch=1)
    assert mesh.shape == {"neuron": 1, "batch": 1}


def test_mesh_engine_requires_snn_axes(rng):
    wrong = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="neuron"):
        _engine_pair(rng, mesh=wrong)


def test_to_mesh_is_drop_in(rng):
    """`engine.to_mesh(mesh)` re-hosts the same program: same config, a
    MeshSpikeEngine, and bit-identical outputs."""
    mesh = make_spike_mesh(neuron=1, batch=1)
    single, _ = _engine_pair(rng, mesh=mesh)
    hosted = single.to_mesh(mesh)
    assert isinstance(hosted, MeshSpikeEngine)
    assert hosted.reset_mode == single.reset_mode
    assert hosted.n_phys == single.n_phys
    ext = (np.random.default_rng(1).random((5, 3, single.n_inputs))
           < 0.35).astype(np.int32)
    _assert_run_parity(single, hosted, ext)


def test_server_mesh_kwarg_rehosts_engine(rng):
    mesh = make_spike_mesh(neuron=1, batch=1)
    single, _ = _engine_pair(rng, mesh=mesh)
    srv = SpikeServer(single, n_slots=2, chunk_steps=3, mesh=mesh)
    assert isinstance(srv.engine, MeshSpikeEngine)
    # already-mesh engines pass through untouched
    srv2 = SpikeServer(srv.engine, n_slots=2, chunk_steps=3, mesh=mesh)
    assert srv2.engine is srv.engine


# --------------------------------------------------------------------------
# Degenerate 1x1 mesh: the shard_map path runs in every environment
# --------------------------------------------------------------------------

def test_degenerate_mesh_run_parity(rng):
    mesh = make_spike_mesh(neuron=1, batch=1)
    single, sharded = _engine_pair(rng, mesh=mesh)
    ext = (rng.random((6, 5, single.n_inputs)) < 0.35).astype(np.int32)
    _assert_run_parity(single, sharded, ext)


def test_degenerate_mesh_single_step_parity(rng):
    """`step` on the mesh engine routes through the sharded path and
    matches the single-device step bit-for-bit."""
    mesh = make_spike_mesh(neuron=1, batch=1)
    single, sharded = _engine_pair(rng, mesh=mesh)
    carry = single.init_carry(3)
    ext_t = (rng.random((3, single.n_inputs)) < 0.4).astype(np.int32)
    c1, s1 = single.step(carry, jnp.asarray(ext_t))
    c2, s2 = sharded.step(carry, jnp.asarray(ext_t))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    for k in ("v", "spikes"):
        np.testing.assert_array_equal(np.asarray(c1[k]), np.asarray(c2[k]))


def test_degenerate_mesh_chunk_parity(rng):
    mesh = make_spike_mesh(neuron=1, batch=1)
    single, sharded = _engine_pair(rng, mesh=mesh, reset="zero")
    carry = single.init_carry(3)
    ext = (rng.random((4, 3, single.n_inputs)) < 0.35).astype(np.int32)
    act = (rng.random((4, 3)) < 0.6).astype(np.int32)
    c1, s1 = single.step_chunk(carry, ext, act)
    c2, s2 = sharded.step_chunk(carry, ext, act)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    for k in ("v", "spikes"):
        np.testing.assert_array_equal(np.asarray(c1[k]), np.asarray(c2[k]))


# --------------------------------------------------------------------------
# The acceptance sweep: >= 2x2 mesh, every backend x reset mode, batch
# run AND streaming feed through a sharded SpikeServer
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("reset", RESET_MODES)
def test_mesh_parity_backend_reset_sweep(rng, backend, reset):
    mesh = _mesh(2, 2)
    single, sharded = _engine_pair(rng, backend=backend, reset=reset, B=5,
                                   mesh=mesh)
    T = 7
    ext = (rng.random((T, 5, single.n_inputs)) < 0.35).astype(np.int32)
    _assert_run_parity(single, sharded, ext)

    # streaming: the same raster dribbled raggedly through a SHARDED
    # server must reproduce the one-shot batch raster byte for byte
    srv = SpikeServer(sharded, n_slots=3, chunk_steps=3)
    uid = srv.attach()
    pieces, t0 = [], 0
    for n in (2, 4, 1):  # ragged boundaries, sum == T
        pieces.append(srv.feed({uid: ext[t0:t0 + n, 0]})[uid]["spikes"])
        t0 += n
    assert t0 == T
    got = np.concatenate(pieces, axis=0)
    want = np.asarray(single.run(ext)["spikes"])[:, 0]
    assert got.dtype == want.dtype == np.int32
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("B,n_in,n_phys", RAGGED_SHAPES)
def test_mesh_parity_ragged_shapes(rng, B, n_in, n_phys):
    """Neuron/batch padding to mesh multiples must never leak into
    results — including n_phys=1 on a 2-way neuron axis."""
    mesh = _mesh(2, 2)
    single, sharded = _engine_pair(rng, B=B, n_in=n_in, n_phys=n_phys,
                                   mesh=mesh)
    ext = (rng.random((6, B, n_in)) < 0.35).astype(np.int32)
    _assert_run_parity(single, sharded, ext)


def test_mesh_parity_mul_decay(rng):
    """The Cerebra-S truncating-multiply PDU shards exactly too."""
    mesh = _mesh(2, 2)
    single, sharded = _engine_pair(
        rng, decay=DecaySpec.mul(int(round(0.7 * 65536))), mesh=mesh)
    ext = (rng.random((6, 4, single.n_inputs)) < 0.35).astype(np.int32)
    _assert_run_parity(single, sharded, ext)


def test_mesh_parity_wide_mesh_uses_all_devices(rng):
    """The full 8-device 2x4 shape of the CI leg."""
    mesh = _mesh(2, 4)
    single, sharded = _engine_pair(rng, B=6, mesh=mesh)
    assert sharded.device_count == 8
    ext = (rng.random((5, 6, single.n_inputs)) < 0.35).astype(np.int32)
    _assert_run_parity(single, sharded, ext)


# --------------------------------------------------------------------------
# step_chunk masked-slot semantics on the mesh
# --------------------------------------------------------------------------

def test_mesh_step_chunk_masked_slots(rng):
    """Inactive slots keep their carry bit-for-bit across a sharded chunk
    step; active slots advance exactly as the single-device chunk does —
    including carries chained across successive chunks."""
    mesh = _mesh(2, 2)
    single, sharded = _engine_pair(rng, reset="zero", B=5, mesh=mesh)
    c1 = single.init_carry(5)
    c2 = sharded.init_carry(5)
    for _ in range(3):
        ext = (rng.random((4, 5, single.n_inputs)) < 0.35).astype(np.int32)
        act = (rng.random((4, 5)) < 0.5).astype(np.int32)
        c1, s1 = single.step_chunk(c1, ext, act)
        c2, s2 = sharded.step_chunk(c2, ext, act)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        for k in ("v", "spikes"):
            np.testing.assert_array_equal(np.asarray(c1[k]),
                                          np.asarray(c2[k]))


def test_mesh_closed_loop_through_server(rng):
    """run_closed_loop (T=1 masked chunks, feedback through the host)
    produces the same trajectory on a sharded server."""
    mesh = _mesh(2, 2)
    single, sharded = _engine_pair(rng, reset="subtract", mesh=mesh)

    def controller(spikes_t):
        return (spikes_t[: single.n_inputs] ^ 1).astype(np.int32)

    outs = []
    for engine in (single, sharded):
        srv = SpikeServer(engine, n_slots=2, chunk_steps=4)
        uid = srv.attach()
        ext0 = np.zeros((single.n_inputs,), np.int32)
        ext0[::3] = 1
        outs.append(srv.run_closed_loop(uid, controller, 6, ext0))
    np.testing.assert_array_equal(outs[0]["spikes"], outs[1]["spikes"])
    np.testing.assert_array_equal(outs[0]["counts"], outs[1]["counts"])


# --------------------------------------------------------------------------
# Fused multi-model run_all + streaming churn on a sharded session
# --------------------------------------------------------------------------

def test_session_run_all_sharded_parity(rng):
    """Co-resident fused models on a mesh session decode bit-identically
    to the single-device session (spikes, counts, predictions, costs)."""
    mesh = _mesh(2, 2)
    nets = [make_random_net(rng),
            make_random_net(rng, n_in=12, n_neurons=32)]
    key = jax.random.key(0)
    plain, meshed = AcceleratorSession(), AcceleratorSession(mesh=mesh)
    for sess in (plain, meshed):
        sess.deploy("a", nets[0])
        sess.deploy("b", nets[1])
    inputs = {"a": rng.random((3, 20)).astype(np.float32),
              "b": rng.random((3, 12)).astype(np.float32)}
    ra = plain.run_all(inputs, 10, key)
    rb = meshed.run_all(inputs, 10, key)
    for name in ("a", "b"):
        for k in ("spikes", "output_counts", "predictions", "cycles",
                  "sops", "row_fetches"):
            np.testing.assert_array_equal(np.asarray(ra[name][k]),
                                          np.asarray(rb[name][k]))


def test_session_streaming_churn_sharded_parity(rng):
    """Attach/feed/detach churn across co-resident models' streams on a
    sharded session server matches the single-device server exactly."""
    mesh = _mesh(2, 2)
    nets = [make_random_net(rng),
            make_random_net(rng, n_in=12, n_neurons=32)]
    sessions = [AcceleratorSession(), AcceleratorSession(mesh=mesh)]
    for sess in sessions:
        sess.deploy("a", nets[0])
        sess.deploy("b", nets[1])
    chunks_a = [(rng.random((n, 20)) < 0.4).astype(np.int32)
                for n in (3, 1, 4)]
    chunks_b = [(rng.random((n, 12)) < 0.4).astype(np.int32)
                for n in (2, 5)]
    results = []
    for sess in sessions:
        va = sess.serve("a", n_slots=3, chunk_steps=3)
        vb = sess.serve("b", n_slots=3, chunk_steps=3)
        assert va.server is vb.server
        ua = va.attach()
        ub = vb.attach()
        outs = [va.feed(ua, chunks_a[0]),
                vb.feed(ub, chunks_b[0]),
                va.feed(ua, chunks_a[1])]
        va.detach(ua)            # churn: evict a, re-attach fresh
        ua2 = va.attach()
        outs.append(va.feed(ua2, chunks_a[2]))
        outs.append(vb.feed(ub, chunks_b[1]))
        results.append(outs)
    for o_plain, o_mesh in zip(*results):
        for k in ("spikes", "output_counts", "predictions"):
            np.testing.assert_array_equal(np.asarray(o_plain[k]),
                                          np.asarray(o_mesh[k]))


def _async_frontend_parity(rng, mesh):
    """Requests served through an AsyncSpikeFrontend over a SHARDED
    server are byte-identical to the single-device engine's one-shot
    run — the async front door composes with the mesh unchanged."""
    from repro.serving.frontend import AsyncSpikeFrontend

    single, sharded = _engine_pair(rng, mesh=mesh)
    rasters = [(rng.random((T, single.n_inputs)) < 0.35).astype(np.int32)
               for T in (7, 4, 9, 2)]
    server = SpikeServer(sharded, n_slots=2, chunk_steps=3)
    fe = AsyncSpikeFrontend(server, queue_capacity=len(rasters))
    handles = [fe.submit(r) for r in rasters]
    assert fe.drain()["counts"]["done"] == len(rasters)
    for h, r in zip(handles, rasters):
        want = np.asarray(single.run(r[:, None, :])["spikes"])[:, 0]
        np.testing.assert_array_equal(h.result()["spikes"], want)


def test_async_frontend_degenerate_mesh_parity(rng):
    _async_frontend_parity(rng, make_spike_mesh(neuron=1, batch=1))


def test_async_frontend_sharded_parity(rng):
    _async_frontend_parity(rng, _mesh(2, 2))
