"""Property tests for the fixed-point substrate (bit-exact HW semantics)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import fixedpoint as fxp

I32 = st.integers(min_value=-(2**31), max_value=2**31 - 1)


@given(st.floats(min_value=-30000.0, max_value=30000.0,
                 allow_nan=False, allow_infinity=False))
@settings(max_examples=200, deadline=None)
def test_roundtrip_error_bounded(x):
    raw = fxp.to_fixed(np.float32(x))
    back = float(fxp.from_fixed(raw))
    # round-to-nearest: half an LSB, plus float32 representation slack
    assert abs(back - np.float32(x)) <= (0.5 / fxp.Q16_16.scale
                                         + abs(x) * 1e-6)


def test_saturation():
    fmt = fxp.Q16_16
    assert int(fxp.to_fixed(1e9)) == (1 << 31) - 1
    assert int(fxp.to_fixed(-1e9)) == -(1 << 31)
    assert float(fxp.from_fixed(fxp.to_fixed(fmt.max_value))) == pytest.approx(
        fmt.max_value, abs=1e-4)


@given(I32, st.integers(min_value=0, max_value=2**16 - 1))
@settings(max_examples=300, deadline=None)
def test_fx_mul_matches_bigint_floor(a, b):
    """fx_mul == floor(a*b / 2^16) with exact Python integers."""
    got = int(fxp.fx_mul(jnp.int32(a), jnp.int32(b)))
    want = (a * b) >> 16  # Python ints: arithmetic shift == floor division
    # result must also wrap like int32
    want = ((want + 2**31) % 2**32) - 2**31
    assert got == want


@given(I32, st.sampled_from(fxp.SHIFT_DECAY_RATES))
@settings(max_examples=300, deadline=None)
def test_shift_decay_matches_bigint(v, rate):
    got = int(fxp.shift_decay(jnp.int32(v), rate))
    k = {0.125: 3, 0.25: 2, 0.5: 1}.get(rate)
    want = (v >> 2) if rate == 0.75 else v - (v >> k)
    want = ((want + 2**31) % 2**32) - 2**31
    assert got == want


@given(st.integers(min_value=0, max_value=2**30))
@settings(max_examples=100, deadline=None)
def test_shift_decay_monotone_nonneg(v):
    """For v >= 0 a larger decay rate removes at least as much potential."""
    outs = [int(fxp.shift_decay(jnp.int32(v), r))
            for r in fxp.SHIFT_DECAY_RATES]
    assert all(o <= v for o in outs)
    assert outs == sorted(outs, reverse=True)


@given(st.floats(min_value=0.0, max_value=1.0, allow_nan=False))
@settings(max_examples=100, deadline=None)
def test_nearest_shift_decay_is_nearest(rate):
    snapped = fxp.nearest_shift_decay(rate)
    assert snapped in fxp.SHIFT_DECAY_RATES
    assert all(abs(snapped - rate) <= abs(r - rate) + 1e-12
               for r in fxp.SHIFT_DECAY_RATES)


def test_quantize_weights_shapes():
    w = np.random.default_rng(0).normal(0, 0.3, (7, 5)).astype(np.float32)
    raw, deq = fxp.quantize_weights(w)
    assert raw.shape == w.shape and raw.dtype == jnp.int32
    np.testing.assert_allclose(np.asarray(deq), w, atol=1.0 / 65536)


def test_np_to_fixed_matches_jax():
    x = np.random.default_rng(1).normal(0, 100, (64,)).astype(np.float32)
    np.testing.assert_array_equal(
        fxp.np_to_fixed(x), np.asarray(fxp.to_fixed(x)))
