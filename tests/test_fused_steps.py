"""K-step fused timestep contracts: fusion is a SCHEDULE, never a result.

The load-bearing claims pinned here:

  * a ``fuse_steps=K`` engine is BYTE-identical to the unfused engine on
    ``run`` for every fused backend x reset mode x gate x K — including
    T not a multiple of K (the padded trailing window) — fast leg always
    runs, the full sweep rides the ``slow`` marker;
  * the masked ``step_chunk`` semantics survive fusion: inactive slots
    keep their carry bit-for-bit and report zero spikes, with carries
    chained across ragged chunks;
  * ``to_mesh`` / ``with_gate`` / ``with_fuse_steps`` carry K through
    re-hosting, and the mesh engine's outputs stay identical;
  * the MXU exactness gate stays closed under fusion and its rejection
    names the numbers that tripped it (max |w|, per-block fan-in, K);
  * the traffic accounting is CONSISTENT: the gate scalars the fused
    kernel DMAs by (``ops.ext_gate_activity``) count exactly the blocks
    the ``events.trace`` window-OR model counts, and per-step fused
    traffic at dense activity is exactly 1/K of the unfused kernel's.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (BACKENDS, GATES, MXU_EXACT_BOUND, DecaySpec,
                               SpikeEngine, mxu_partial_sum_bound,
                               sources_raster)
from repro.distributed.spike_mesh import MeshSpikeEngine, make_spike_mesh
from repro.events import trace
from repro.kernels import ops

THRESH = 1 << 16
RESET_MODES = ("zero", "subtract", "hold")
FUSED_BACKENDS = tuple(b for b in BACKENDS if b != "reference")


def _weights(rng, n_in, n_phys, density=0.3, wmax=1 << 13):
    S = n_in + n_phys
    W = ((rng.random((S, n_phys)) < density)
         * rng.integers(-wmax, wmax, (S, n_phys)))
    return jnp.asarray(W, jnp.int32)


def _raster(rng, T, B, S, density=0.3):
    return jnp.asarray(rng.random((T, B, S)) < density, jnp.int32)


def _engine(W, n_in, *, backend="reference", gate="batch-tile",
            reset="zero", K=1):
    return SpikeEngine(W, n_in, decay=DecaySpec.shift(0.25),
                       threshold_raw=THRESH, reset_mode=reset,
                       backend=backend, gate=gate, fuse_steps=K)


def _assert_run_identical(a, b):
    for k in ("spikes", "v_final"):
        av, bv = np.asarray(a[k]), np.asarray(b[k])
        assert av.dtype == bv.dtype == np.int32
        np.testing.assert_array_equal(av, bv)


# --------------------------------------------------------------------------
# run identity: fused == unfused, fast leg + full slow sweep
# --------------------------------------------------------------------------

def test_fused_run_identity_fast(rng):
    """One combo per fused backend x gate at K=4, T ragged (10 = 2.5
    windows) — the always-on identity check."""
    W = _weights(rng, 37, 48)
    ext = _raster(rng, 10, 3, 37)
    want = _engine(W, 37).run(ext)
    for backend in FUSED_BACKENDS:
        for gate in GATES:
            got = _engine(W, 37, backend=backend, gate=gate, K=4).run(ext)
            _assert_run_identical(want, got)


def test_fused_run_identity_k1_path(rng):
    """K=1 never routes through the fused kernel but must also match."""
    W = _weights(rng, 20, 40)
    ext = _raster(rng, 5, 2, 20)
    want = _engine(W, 20).run(ext)
    for backend in FUSED_BACKENDS:
        _assert_run_identical(want, _engine(W, 20, backend=backend,
                                            K=1).run(ext))


def test_fused_run_identity_edge_shapes(rng):
    """Window edges: T < K (one padded window), T == K, B=1, and a
    source axis wider than one 128-block."""
    cases = [
        # (T, B, n_in, n_phys, K)
        (2, 2, 30, 40, 4),     # T < K
        (4, 1, 30, 40, 4),     # T == K, single example
        (7, 3, 200, 130, 3),   # multi-block source axis, ragged T
    ]
    for T, B, n_in, n_phys, K in cases:
        W = _weights(rng, n_in, n_phys)
        ext = _raster(rng, T, B, n_in)
        want = _engine(W, n_in).run(ext)
        got = _engine(W, n_in, backend="pallas", K=K).run(ext)
        _assert_run_identical(want, got)


@pytest.mark.slow
def test_fused_run_identity_full_sweep(rng):
    """Every fused backend x reset mode x gate x K, ragged T."""
    W = _weights(rng, 37, 48)
    ext = _raster(rng, 9, 3, 37)
    for reset in RESET_MODES:
        want = _engine(W, 37, reset=reset).run(ext)
        for backend in FUSED_BACKENDS:
            for gate in GATES:
                for K in (2, 3, 8):
                    got = _engine(W, 37, backend=backend, gate=gate,
                                  reset=reset, K=K).run(ext)
                    _assert_run_identical(want, got)


# --------------------------------------------------------------------------
# masked step_chunk: ragged remainders inside and across windows
# --------------------------------------------------------------------------

def test_fused_step_chunk_masked_identity(rng):
    """Chunks of 5 steps under K=4 (every window ragged or masked):
    active slots advance exactly as the reference chunk, inactive slots
    keep their carry bit-for-bit, chained across chunks."""
    W = _weights(rng, 30, 40)
    ref = _engine(W, 30)
    fused = _engine(W, 30, backend="pallas", K=4)
    c1, c2 = ref.init_carry(4), fused.init_carry(4)
    for _ in range(3):
        ext = _raster(rng, 5, 4, 30, 0.35)
        act = jnp.asarray(rng.random((5, 4)) < 0.5, jnp.int32)
        c1, s1 = ref.step_chunk(c1, ext, act)
        c2, s2 = fused.step_chunk(c2, ext, act)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        for k in ("v", "spikes"):
            np.testing.assert_array_equal(np.asarray(c1[k]),
                                          np.asarray(c2[k]))


@pytest.mark.slow
def test_fused_step_chunk_masked_sweep(rng):
    W = _weights(rng, 30, 40)
    for backend in FUSED_BACKENDS:
        for gate in GATES:
            for reset in RESET_MODES:
                ref = _engine(W, 30, reset=reset)
                fused = _engine(W, 30, backend=backend, gate=gate,
                                reset=reset, K=3)
                c1, c2 = ref.init_carry(3), fused.init_carry(3)
                ext = _raster(rng, 4, 3, 30, 0.35)
                act = jnp.asarray(rng.random((4, 3)) < 0.5, jnp.int32)
                c1, s1 = ref.step_chunk(c1, ext, act)
                c2, s2 = fused.step_chunk(c2, ext, act)
                np.testing.assert_array_equal(np.asarray(s1),
                                              np.asarray(s2))
                for k in ("v", "spikes"):
                    np.testing.assert_array_equal(np.asarray(c1[k]),
                                                  np.asarray(c2[k]))


# --------------------------------------------------------------------------
# re-hosting carries K: with_gate / with_fuse_steps / to_mesh
# --------------------------------------------------------------------------

def test_with_fuse_steps_rehosting(rng):
    W = _weights(rng, 20, 40)
    e = _engine(W, 20, backend="pallas", gate="per-example", K=1)
    assert e.with_fuse_steps(1) is e
    e4 = e.with_fuse_steps(4)
    assert (e4.fuse_steps, e4.gate, e4.backend) == (4, "per-example",
                                                    "pallas")
    # and the other re-hosts preserve K
    assert e4.with_gate("batch-tile").fuse_steps == 4
    assert e4.with_gate("per-example") is e4


def test_fuse_steps_validation(rng):
    W = _weights(rng, 10, 20)
    with pytest.raises(ValueError, match="fuse_steps"):
        _engine(W, 10, K=0)
    with pytest.raises(ValueError, match="fuse_steps"):
        mxu_partial_sum_bound(np.asarray(W), fuse_steps=0)


def test_mesh_engine_carries_fuse_steps(rng):
    """1x1 mesh (always available): to_mesh / with_gate / with_fuse_steps
    keep K, and the sharded fused run stays byte-identical."""
    mesh = make_spike_mesh(neuron=1, batch=1)
    W = _weights(rng, 30, 40)
    ext = _raster(rng, 6, 3, 30)
    want = _engine(W, 30).run(ext)
    fused = _engine(W, 30, backend="pallas", K=4)
    sharded = fused.to_mesh(mesh)
    assert isinstance(sharded, MeshSpikeEngine)
    assert sharded.fuse_steps == 4
    assert sharded.with_gate("per-example").fuse_steps == 4
    assert sharded.with_fuse_steps(2).fuse_steps == 2
    assert isinstance(sharded.with_fuse_steps(2), MeshSpikeEngine)
    _assert_run_identical(want, sharded.run(ext))


# --------------------------------------------------------------------------
# MXU exactness gate under fusion
# --------------------------------------------------------------------------

def test_mxu_bound_k_invariant(rng):
    W = np.asarray(_weights(rng, 37, 48))
    for K in (1, 2, 8):
        assert mxu_partial_sum_bound(W, fuse_steps=K) == \
            mxu_partial_sum_bound(W)


def test_mxu_rejection_message_names_the_numbers():
    """The compile-time rejection must name max |w|, the per-block
    fan-in, and K — the three numbers a user needs to fix their image."""
    # a full 128-row block of 2^17 weights: partial sum 2^24, at the bound
    n_in, n_phys = 100, 128
    W = np.full((n_in + n_phys, n_phys), 1 << 17, np.int32)
    assert mxu_partial_sum_bound(W) >= MXU_EXACT_BOUND
    with pytest.raises(ValueError) as ei:
        _engine(jnp.asarray(W), n_in, backend="pallas-mxu", K=4)
    msg = str(ei.value)
    assert f"max |w| = {1 << 17}" in msg
    assert "fan-in 128" in msg
    assert "fuse_steps K = 4" in msg
    assert "K-invariant" in msg


# --------------------------------------------------------------------------
# traffic accounting: kernel gate scalars == trace window-OR model
# --------------------------------------------------------------------------

def test_ext_gate_activity_matches_trace_counts(rng):
    """The DMAs the fused kernel schedules (nonzero gate scalars) equal
    the trace model's window-OR gated block count, for every K."""
    ext = np.asarray(_raster(rng, 10, 5, 300, 0.05))
    for K in (1, 2, 4):
        for tile in (8, 1):
            kernel = int((np.asarray(
                ops.ext_gate_activity(ext, block_batch=tile,
                                      fuse_steps=K)) > 0).sum())
            touched, _ = trace.block_traffic(ext, fuse_steps=K,
                                             tile_batch=tile)
            assert kernel == touched, (K, tile)


def test_fused_traffic_is_one_over_k_at_dense_activity(rng):
    """At full activity the gate never skips, so the fused per-step
    traffic ratio is exactly 1/K (the weight-reuse claim, isolated)."""
    T, B, n_in, n_phys = 8, 4, 256, 128
    sources = np.ones((T, B, n_in + n_phys), np.int32)
    for K in (1, 2, 4, 8):
        touched, total = trace.fused_block_traffic(sources, n_in,
                                                   fuse_steps=K)
        assert touched * K == total


def test_fused_traffic_counted_from_real_run(rng):
    """End to end on a real run: fused traffic from the engine's actual
    rasters shrinks monotonically with K and the ext leg cross-checks
    against the kernel-side counter."""
    W = _weights(rng, 200, 130)
    engine = _engine(W, 200)
    ext = _raster(rng, 12, 4, 200, 0.1)
    out = engine.run(ext)["spikes"]
    sources = np.asarray(sources_raster(ext, out))
    ratios = []
    for K in (1, 2, 4):
        touched, total = trace.fused_block_traffic(sources, 200,
                                                   fuse_steps=K)
        ratios.append(touched / total)
        kernel = int((np.asarray(
            ops.ext_gate_activity(ext, fuse_steps=K)) > 0).sum())
        assert kernel == trace.block_traffic(np.asarray(ext),
                                             fuse_steps=K)[0]
    assert ratios[0] > ratios[1] > ratios[2]
