"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

The Pallas kernels run in interpret=True on CPU (the wrappers detect the
backend); the integer paths must be BIT-exact vs the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fixedpoint as fxp
from repro.kernels import ops, ref

THRESH = 1 << 16  # 1.0 in Q16.16

SHAPES_2D = [(1, 1), (3, 5), (8, 128), (7, 130), (16, 256), (33, 513)]


def _tree_equal(a, b):
    return all(bool((x == y).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("rate", fxp.SHIFT_DECAY_RATES)
@pytest.mark.parametrize("reset", ["zero", "subtract", "hold"])
def test_lif_step_sweep(shape, rate, reset):
    if shape not in ((8, 128), (7, 130)) and (
            rate != 0.25 or reset != "zero"):
        # full param cross-product only on two representative shapes
        pytest.skip("cross-product trimmed for runtime")
    rng = np.random.default_rng(hash((shape, rate, reset)) % 2**31)
    v = jnp.asarray(rng.integers(-2**22, 2**22, shape), jnp.int32)
    syn = jnp.asarray(rng.integers(-2**18, 2**18, shape), jnp.int32)
    got = ops.lif_step(v, syn, decay_rate=rate, threshold_raw=THRESH,
                       reset_mode=reset)
    want = ref.lif_step_ref(v, syn, decay_rate=rate, threshold_raw=THRESH,
                            reset_mode=reset)
    assert _tree_equal(got, want)


# --------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,P", [(1, 1, 1), (2, 40, 33), (5, 160, 300),
                                   (8, 128, 128), (3, 1056, 64)])
def test_spike_timestep_sweep(B, S, P):
    rng = np.random.default_rng(B * 1000 + S + P)
    src = jnp.asarray(rng.random((B, S)) < 0.15, jnp.int32)
    W = jnp.asarray(rng.integers(-2**14, 2**14, (S, P)), jnp.int32)
    v = jnp.asarray(rng.integers(-2**18, 2**18, (B, P)), jnp.int32)
    got = ops.spike_timestep(src, W, v, decay_rate=0.25,
                             threshold_raw=THRESH)
    want = ref.spike_timestep_ref(src, W, v, decay_rate=0.25,
                                  threshold_raw=THRESH, reset_mode="zero")
    assert _tree_equal(got, want[:2])


def test_spike_timestep_event_gating_exactness():
    """All-zero source blocks must not change results (the @pl.when gate)."""
    rng = np.random.default_rng(7)
    B, S, P = 4, 512, 96
    src = np.zeros((B, S), np.int32)
    src[:, :64] = (rng.random((B, 64)) < 0.3)  # only first block active
    W = jnp.asarray(rng.integers(-2**13, 2**13, (S, P)), jnp.int32)
    v = jnp.asarray(rng.integers(-2**17, 2**17, (B, P)), jnp.int32)
    got = ops.spike_timestep(jnp.asarray(src), W, v, decay_rate=0.5,
                             threshold_raw=THRESH)
    want = ref.spike_timestep_ref(jnp.asarray(src), W, v, decay_rate=0.5,
                                  threshold_raw=THRESH, reset_mode="zero")
    assert _tree_equal(got, want[:2])


def test_spike_timestep_mxu_mode_exact_within_bounds():
    """use_mxu=True accumulates in f32 on the MXU: exact while partial sums
    stay under 2^24 (|w|<=1.0 Q16.16, fan-in <= 256 -> bounded)."""
    rng = np.random.default_rng(11)
    B, S, P = 4, 256, 64
    src = jnp.asarray(rng.random((B, S)) < 0.2, jnp.int32)
    # weights in [-0.25, 0.25] Q16.16 -> |partial| <= 256*0.25*2^16 = 2^22
    W = jnp.asarray(rng.integers(-(1 << 14), 1 << 14, (S, P)), jnp.int32)
    v = jnp.asarray(rng.integers(-2**18, 2**18, (B, P)), jnp.int32)
    got = ops.spike_timestep(src, W, v, decay_rate=0.25,
                             threshold_raw=THRESH, use_mxu=True)
    want = ref.spike_timestep_ref(src, W, v, decay_rate=0.25,
                                  threshold_raw=THRESH, reset_mode="zero")
    assert _tree_equal(got, want[:2])


# --------------------------------------------------------------------------
@pytest.mark.parametrize("B,D,T", [(1, 1, 1), (4, 30, 16), (8, 128, 25),
                                   (9, 784, 5)])
def test_poisson_encode_sweep(B, D, T):
    rng = np.random.default_rng(B + D + T)
    x = jnp.asarray(rng.random((B, D)), jnp.float32)
    got = ops.poisson_encode(42, x, T)
    want = ref.poisson_encode_ref(42, x, T)
    assert got.shape == (T, B, D)
    assert bool((got == want).all())


def test_poisson_encode_extremes_and_rate():
    B, D, T = 16, 64, 200
    x = jnp.concatenate([jnp.zeros((B, D // 2)), jnp.ones((B, D // 2))], -1)
    s = ops.poisson_encode(0, x, T)
    assert float(s[:, :, : D // 2].sum()) == 0.0       # p=0 never fires
    assert float(s[:, :, D // 2:].mean()) == 1.0       # p=1 always fires
    # mid-rate statistics
    xm = jnp.full((B, D), 0.3, jnp.float32)
    sm = ops.poisson_encode(3, xm, T)
    assert abs(float(sm.mean()) - 0.3) < 0.01


def test_poisson_encode_seed_sensitivity():
    x = jnp.full((4, 32), 0.5, jnp.float32)
    a = ops.poisson_encode(1, x, 20)
    b = ops.poisson_encode(2, x, 20)
    assert not bool((a == b).all())
    c = ops.poisson_encode(1, x, 20)
    assert bool((a == c).all())
