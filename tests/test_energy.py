"""Energy/power model: must reproduce the paper's Table V at the reference
operating point, and account workloads consistently."""

import numpy as np
import pytest

from repro.core import cerebra_h, energy
from repro.core.energy import TABLE_V, EnergyModel, WorkloadCounts

from conftest import make_ff_net


def _reference_counts(model: EnergyModel, seconds: float = 1.0):
    r = model.reference_rates
    cycles = model.freq_mhz * 1e6 * seconds
    return WorkloadCounts(
        sops=r["sops_per_s"] * seconds,
        row_fetches=r["rows_per_s"] * seconds,
        spike_packets=r["packets_per_s"] * seconds,
        cycles=cycles,
    )


def test_calibration_reproduces_table_v():
    model = EnergyModel.calibrated()
    got = model.breakdown_mw(_reference_counts(model))
    for key in ("weight_memory_mw", "neuron_clusters_mw",
                "spike_paths_mw", "data_control_paths_mw"):
        assert got[key] == pytest.approx(TABLE_V[key], rel=1e-6), key
    # the paper's own Table V rounds: components sum to 500.11, the printed
    # total is 500.10 — we match the components exactly, total to 0.02 mW
    assert got["total_mw"] == pytest.approx(TABLE_V["total_mw"], abs=0.02)
    assert got["weight_memory_pct"] == pytest.approx(95.97, abs=0.01)
    assert got["compute_pj_per_sop"] == 1.05


def test_memory_dominance_invariant():
    """The paper's headline observation — weight memory dominates at any
    plausible activity level (static SRAM power floor)."""
    model = EnergyModel.calibrated()
    for duty in (0.0, 0.1, 0.5, 1.0, 2.0):
        c = _reference_counts(model)
        c = WorkloadCounts(c.sops * duty, c.row_fetches * duty,
                           c.spike_packets * duty, c.cycles)
        got = model.breakdown_mw(c)
        assert got["weight_memory_pct"] > 90.0


def test_energy_accounting_consistency():
    model = EnergyModel.calibrated()
    c = _reference_counts(model, seconds=0.25)
    e = model.energy_uj(c)
    assert e["total_uj"] == pytest.approx(e["static_uj"] + e["dynamic_uj"])
    # system-level pJ/SOP >> compute-path 1.05 (the paper's key trade-off)
    assert e["pj_per_sop_system"] > 10 * e["pj_per_sop_compute"]
    # power x time == energy
    mw = model.breakdown_mw(c)["total_mw"]
    assert e["total_uj"] == pytest.approx(mw * 1e-3 * 0.25 * 1e6, rel=1e-6)


def test_counts_from_run(rng):
    net = make_ff_net(rng, sizes=(16, 32, 10))
    prog = cerebra_h.compile_network(net)
    ext = (rng.random((15, 4, 16)) < 0.4).astype(np.int32)
    out = cerebra_h.run(prog, ext)
    counts = energy.counts_from_run(out)
    assert counts.sops > 0 and counts.row_fetches > 0
    assert counts.cycles > 0
    # one row fetch delivers at most 32 SOPs (cluster-wide row width)
    assert counts.sops <= counts.row_fetches * 32 + 1e-9
