"""Docs CI leg, importable: the documentation suite must stay sound.

Runs scripts/check_docs.py's checks in-process — dead links/anchors in
README / ARCHITECTURE / docs/ / benchmarks/README fail tier-1, and
docs/serving.md must stay in two-way sync with the launchers' argparsers
(no phantom flags documented, no parser flags undocumented). Negative
cases prove the checker actually detects each violation class.
"""

import importlib.util
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "scripts" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def test_required_docs_exist():
    for rel in ("README.md", "docs/serving.md", "docs/observability.md",
                "docs/glossary.md", "benchmarks/README.md",
                "ARCHITECTURE.md"):
        assert (REPO / rel).exists(), f"{rel} is part of the doc suite"


def test_no_dead_links_or_anchors():
    assert check_docs.check_links() == []


def test_flag_reference_in_sync():
    parser_flags = check_docs.parser_flag_sets()
    # the parsers themselves must expose the async front-door surface
    assert "--async" in parser_flags["repro.launch.serve_snn"]
    assert "--backpressure" in parser_flags["repro.launch.serve_snn"]
    assert "--deadline-ms" in parser_flags["repro.launch.serve_snn"]
    assert "--async" in parser_flags["benchmarks/kernel_bench.py"]
    doc = (REPO / "docs" / "serving.md").read_text()
    assert check_docs.check_flags(doc, parser_flags) == []


def test_metric_reference_in_sync():
    names = check_docs.registry_metric_names()
    assert "snn_server_sops_total" in names  # the live-energy unit
    doc = (REPO / "docs" / "observability.md").read_text()
    assert check_docs.check_metrics(doc, names) == []


def test_checker_detects_phantom_and_undocumented_metrics():
    problems = check_docs.check_metrics(
        "`snn_real_total` and `snn_made_up_total`\n"
        "```\nsnn_fenced_total 1\n```\n",
        {"snn_real_total", "snn_hidden_total"})
    assert any("snn_made_up_total" in p and "does not define" in p
               for p in problems)
    assert any("snn_hidden_total is undocumented" in p for p in problems)
    assert not any("snn_fenced_total" in p for p in problems)


def test_checker_detects_dead_link(tmp_path):
    (tmp_path / "doc.md").write_text("see [x](missing.md) and "
                                     "[y](real.md#nope)\n# Real\n")
    (tmp_path / "real.md").write_text("# Something else\n")
    problems = check_docs.check_links(["doc.md"], repo=tmp_path)
    assert len(problems) == 2
    assert any("dead link" in p for p in problems)
    assert any("dead anchor" in p for p in problems)


def test_checker_detects_phantom_and_undocumented_flags():
    parser_flags = {"launcher": {"--real", "--hidden"}}
    problems = check_docs.check_flags("`--real` and `--made-up`",
                                      parser_flags)
    assert any("phantom flag --made-up" in p for p in problems)
    assert any("--hidden is undocumented" in p for p in problems)


def test_checker_scopes_flags_to_launcher_sections():
    """A flag documented in the WRONG launcher's section is a violation
    even though the other launcher defines it (no pass-by-union)."""
    parser_flags = {"tools/alpha.py": {"--shared", "--alpha-only"},
                    "pkg.beta": {"--shared", "--beta-only"}}
    doc = ("## Launcher: `tools/alpha.py`\n"
           "`--shared` `--alpha-only` `--beta-only`\n"
           "## Launcher: `pkg.beta`\n"
           "`--shared` `--beta-only`\n")
    problems = check_docs.check_flags(doc, parser_flags)
    assert problems == [
        "docs/serving.md: tools/alpha.py section documents --beta-only, "
        "which that launcher does not define"]


def test_checker_ignores_fenced_code_and_external_links(tmp_path):
    (tmp_path / "doc.md").write_text(
        "[ext](https://example.com/x)\n"
        "```sh\n# not a heading\n[fake](nowhere.md)\n```\n")
    assert check_docs.check_links(["doc.md"], repo=tmp_path) == []


def test_cli_entry_point_green():
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_docs.py")],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout
