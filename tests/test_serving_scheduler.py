"""Slot-scheduler invariants, property-tested.

Hypothesis drives random attach/detach sequences against
:class:`repro.serving.snn.SlotScheduler` (pure bookkeeping — fast) and a
tiny :class:`SpikeServer` (array state), checking the invariants the
streaming layer's exactness proof rests on:

  * no slot is ever double-assigned;
  * eviction always zeroes the evicted slot's carry;
  * admission is FIFO-fair: waiters are granted slots in submission order.

When ``hypothesis`` is not installed the conftest stub makes every
``@given`` test skip cleanly; the deterministic companions below still
run everywhere, so the invariants are never fully untested.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.engine import DecaySpec, SpikeEngine
from repro.serving.snn import SlotScheduler, SpikeServer

# op stream: (True, uid) = attach uid; (False, k) = detach the k-th oldest
# currently-submitted uid (mapped onto live uids at replay time)
_OPS = st.lists(
    st.tuples(st.booleans(), st.integers(0, 31)), min_size=1, max_size=60)


def _replay(n_slots, ops):
    """Drive a SlotScheduler; return the trace of (event, uid, slot)."""
    sched = SlotScheduler(n_slots)
    live: list = []   # uids submitted and not yet released/cancelled
    trace = []
    next_uid = 0
    for is_attach, k in ops:
        if is_attach:
            uid = next_uid
            next_uid += 1
            slot = sched.submit(uid)
            live.append(uid)
            trace.append(("submit", uid, slot))
        elif live:
            uid = live.pop(k % len(live))
            if sched.slot_of(uid) is None:
                sched.cancel(uid)
                trace.append(("cancel", uid, None))
            else:
                slot, admitted = sched.release(uid)
                trace.append(("release", uid, slot))
                if admitted is not None:
                    trace.append(("admit", admitted, slot))
        _check_consistency(sched)
    return sched, trace


def _check_consistency(sched):
    slots = list(sched.active.values())
    assert len(slots) == len(set(slots)), "slot double-assignment"
    assert all(0 <= s < sched.n_slots for s in slots)
    assert len(slots) <= sched.n_slots
    # a waiter while a slot is free is a scheduling bug
    if sched.waiting:
        assert len(slots) == sched.n_slots
    # active and waiting are disjoint
    assert not set(sched.active) & set(sched.waiting)


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n_slots=st.integers(1, 5), ops=_OPS)
@pytest.mark.slow
def test_scheduler_no_double_assignment(n_slots, ops):
    """At every point of every attach/detach sequence, each slot holds at
    most one stream (checked inside the replay after every op)."""
    _replay(n_slots, ops)


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n_slots=st.integers(1, 4), ops=_OPS)
@pytest.mark.slow
def test_scheduler_fifo_fairness(n_slots, ops):
    """Streams are admitted in submission order: the sequence of admitted
    uids (immediate grants + queue promotions) is monotone in submit
    order among those that ever waited, and a promotion always picks the
    longest-waiting uid."""
    sched, trace = _replay(n_slots, ops)
    waiting_since: dict = {}
    for ev, uid, slot in trace:
        if ev == "submit" and slot is None:
            waiting_since[uid] = len(waiting_since)
        elif ev == "admit":
            # the admitted uid must be the oldest waiter at that moment
            assert uid in waiting_since
            oldest = min(waiting_since, key=waiting_since.get)
            assert uid == oldest, (uid, waiting_since)
            del waiting_since[uid]
        elif ev == "cancel":
            waiting_since.pop(uid, None)


_ENGINE_CACHE: dict = {}


def _shared_engine():
    """One engine (and one compiled chunk step) across all examples."""
    if "engine" not in _ENGINE_CACHE:
        rng = np.random.default_rng(0)
        W = jnp.asarray((rng.random((6 + 4, 4)) < 0.6)
                        * rng.integers(1 << 14, 1 << 17, (10, 4)), jnp.int32)
        _ENGINE_CACHE["engine"] = SpikeEngine(
            W, 6, decay=DecaySpec.shift(0.25), threshold_raw=1 << 20,
            reset_mode="hold")
    return _ENGINE_CACHE["engine"]


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=st.lists(st.tuples(st.booleans(), st.integers(0, 15)),
                    min_size=1, max_size=24))
@pytest.mark.slow
def test_server_eviction_always_zeroes_carry(ops):
    """Whatever the attach/feed/detach sequence, a detached stream's slot
    carry is zero immediately after eviction, and unoccupied slots stay
    zero (the exactness precondition for slot reuse)."""
    server = SpikeServer(_shared_engine(), n_slots=2, chunk_steps=2)
    live = []
    for is_attach, k in ops:
        if is_attach:
            uid = server.attach()
            live.append(uid)
            if server.slot_of(uid) is not None:
                server.feed({uid: np.ones((3, 6), np.int32)})
        elif live:
            uid = live.pop(k % len(live))
            had_slot = server.slot_of(uid)
            server.detach(uid)
            if had_slot is not None:
                occupied = set(server.scheduler.active.values())
                for s in range(server.n_slots):
                    if s not in occupied:
                        assert not np.asarray(server.carry["v"][s]).any()
                        assert not np.asarray(
                            server.carry["spikes"][s]).any()


# --------------------------------------------------------------------------
# Deterministic companions: the same invariants on fixed sequences, so the
# contracts run even where hypothesis is unavailable.
# --------------------------------------------------------------------------

def test_scheduler_invariants_deterministic():
    sched = SlotScheduler(2)
    assert sched.submit("a") == 0
    assert sched.submit("b") == 1
    assert sched.submit("c") is None and sched.submit("d") is None
    _check_consistency(sched)
    slot, admitted = sched.release("a")
    assert (slot, admitted) == (0, "c")       # FIFO: c before d
    _check_consistency(sched)
    sched.cancel("d")                          # withdraw a waiter
    slot, admitted = sched.release("b")
    assert (slot, admitted) == (1, None)
    assert sched.submit("e") == 1              # FIFO slot reuse
    with pytest.raises(ValueError, match="already"):
        sched.submit("e")
    with pytest.raises(KeyError):
        sched.release("ghost")
    with pytest.raises(KeyError):
        sched.cancel("e")                      # active, not waiting


def test_scheduler_rejects_bad_sizes():
    with pytest.raises(ValueError):
        SlotScheduler(0)
    W = jnp.zeros((4, 2), jnp.int32)
    eng = SpikeEngine(W, 2, decay=DecaySpec.shift(0.25),
                      threshold_raw=1, reset_mode="zero")
    with pytest.raises(ValueError, match="chunk_steps"):
        SpikeServer(eng, n_slots=1, chunk_steps=0)


def test_server_detach_of_waiting_stream(rng):
    W = jnp.zeros((8 + 4, 4), jnp.int32)
    engine = SpikeEngine(W, 8, decay=DecaySpec.shift(0.25),
                         threshold_raw=1, reset_mode="zero")
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    a = server.attach()
    b = server.attach()
    server.detach(b)                # cancel from the waiting queue
    assert server.slot_of(a) == 0
    server.detach(a)
    c = server.attach()
    assert server.slot_of(c) == 0   # queue empty, slot recycled
