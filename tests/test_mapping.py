"""Mapping compiler (placement, row budgets, communication profile)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.lif import LIFParams
from repro.core.mapping import (
    ClusterGeometry, Placement, check_capacity, communication_profile,
    place_contiguous, place_greedy, place_random, row_usage,
)
from repro.core.network import SNNetwork, feedforward

from conftest import make_ff_net, make_random_net


def test_paper_geometry_constants():
    g = ClusterGeometry()
    assert g.n_physical == 1024                 # 32 clusters x 32 neurons
    assert g.n_groups == 8                      # groups of 4 share one SRAM
    assert g.total_synapse_capacity == 524_288  # paper §V-C
    assert g.n_l1_routers == 8                  # L2 aggregates 8 L1s


def test_feedforward_structure():
    ws = [np.ones((4, 3), np.float32), np.full((3, 2), 2.0, np.float32)]
    net = feedforward(ws, LIFParams())
    assert net.n_inputs == 4 and net.n_neurons == 5
    assert net.output_slice == (3, 5)
    # block structure: inputs -> layer0 only; layer0 -> layer1 only
    W = net.weights
    np.testing.assert_array_equal(W[:4, :3], 1.0)
    np.testing.assert_array_equal(W[:4, 3:], 0.0)
    np.testing.assert_array_equal(W[4:7, 3:], 2.0)
    np.testing.assert_array_equal(W[4:7, :3], 0.0)
    assert net.n_synapses == 4 * 3 + 3 * 2


def test_placement_validation():
    g = ClusterGeometry()
    with pytest.raises(ValueError, match="two neurons"):
        Placement(g, np.asarray([0, 0]))
    with pytest.raises(ValueError, match="out of range"):
        Placement(g, np.asarray([0, 5000]))


@given(st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_row_usage_invariants(seed):
    rng = np.random.default_rng(seed)
    net = make_random_net(rng, n_in=10, n_neurons=40, density=0.3)
    geom = ClusterGeometry()
    for place in (place_contiguous, place_greedy,
                  lambda n, g: place_random(n, g, seed)):
        p = place(net, geom)
        strict = row_usage(net, p, "strict")
        shared = row_usage(net, p, "external_broadcast")
        # broadcast mode never uses MORE rows than the literal reading
        assert (shared <= strict).all()
        # every nonzero source-cluster edge must consume at least one row
        assert strict.sum() >= shared.sum() > 0
        report = check_capacity(net, p, "external_broadcast")
        assert report["feasible"]


def test_paper_mnist_net_feasible_only_in_broadcast_mode(rng):
    """The paper's own 784->256->10 net: infeasible under the literal
    row reading, feasible with external-broadcast rows (DESIGN.md §2)."""
    net = make_ff_net(rng, sizes=(784, 256, 10))
    geom = ClusterGeometry()
    p = place_contiguous(net, geom)
    strict = row_usage(net, p, "strict")
    assert (strict > geom.rows_per_group).any()
    with pytest.raises(ValueError):
        check_capacity(net, p, "strict")
    shared = row_usage(net, p, "external_broadcast")
    assert (shared <= geom.rows_per_group).all()


def test_communication_profile_partition(rng):
    net = make_random_net(rng, n_in=8, n_neurons=64, density=0.4)
    geom = ClusterGeometry()
    p = place_contiguous(net, geom)
    prof = communication_profile(net, p)
    total_edges = prof["edge_matrix"].sum()
    assert (prof["local_edges"] + prof["l1_edges"] + prof["l2_edges"]
            == total_edges)
    assert total_edges > 0


def test_greedy_placement_reduces_l2_traffic(rng):
    """Locality-aware placement should not WORSEN L2 crossings vs random
    (paper: 'place neurons with common synapses within the same cluster')."""
    net = make_random_net(rng, n_in=8, n_neurons=256, density=0.15)
    geom = ClusterGeometry()
    l2_greedy = communication_profile(net, place_greedy(net, geom))["l2_edges"]
    l2_rand = np.mean([
        communication_profile(net, place_random(net, geom, s))["l2_edges"]
        for s in range(3)])
    assert l2_greedy <= l2_rand * 1.05


def test_oversized_network_rejected(rng):
    net = make_random_net(rng, n_in=4, n_neurons=2000)
    with pytest.raises(ValueError, match="physical"):
        place_contiguous(net, ClusterGeometry())
