"""Multi-model co-residency (paper §V-D): address-space isolation."""

import jax
import numpy as np
import pytest

from repro.core import cerebra_h
from repro.core.session import AcceleratorSession

from conftest import make_ff_net


def test_co_residency_isolation(rng):
    """A model's outputs are identical whether it runs alone or alongside
    other resident models — disjoint clusters + rows = no interference."""
    netA = make_ff_net(rng, sizes=(12, 40, 10))
    netB = make_ff_net(rng, sizes=(8, 30, 5), scale=0.9)
    key = jax.random.key(0)
    xA = rng.random((6, 12)).astype(np.float32)
    xB = rng.random((6, 8)).astype(np.float32)

    solo = AcceleratorSession()
    solo.deploy("A", netA)
    outA_solo = solo.run("A", xA, 20, key)

    both = AcceleratorSession()
    both.deploy("A", netA)
    both.deploy("B", netB)
    outs = both.run_all({"A": xA, "B": xB}, 20, key)

    np.testing.assert_array_equal(
        np.asarray(outA_solo["predictions"]),
        np.asarray(outs["A"]["predictions"]))
    np.testing.assert_array_equal(
        np.asarray(outA_solo["output_counts"]),
        np.asarray(outs["A"]["output_counts"]))


def test_group_boundary_isolation(rng):
    """Deployments round up to group boundaries so no two models share a
    weight SRAM (the hardware's address-space isolation guarantee)."""
    sess = AcceleratorSession()
    m1 = sess.deploy("m1", make_ff_net(rng, sizes=(6, 10, 4)))
    m2 = sess.deploy("m2", make_ff_net(rng, sizes=(6, 10, 4)))
    cpg = sess.geometry.clusters_per_group
    assert m1.cluster_range[1] % cpg == 0
    assert m2.cluster_range[0] >= m1.cluster_range[1]


def test_capacity_exhaustion(rng):
    sess = AcceleratorSession()
    sess.deploy("big", make_ff_net(rng, sizes=(10, 900, 10)))
    with pytest.raises(ValueError, match="clusters"):
        sess.deploy("more", make_ff_net(rng, sizes=(10, 200, 10)))


def test_duplicate_name_rejected(rng):
    sess = AcceleratorSession()
    sess.deploy("m", make_ff_net(rng, sizes=(4, 8, 2)))
    with pytest.raises(ValueError, match="already"):
        sess.deploy("m", make_ff_net(rng, sizes=(4, 8, 2)))


def test_utilization_accounting(rng):
    sess = AcceleratorSession()
    sess.deploy("a", make_ff_net(rng, sizes=(6, 40, 10)))
    u = sess.utilization()
    assert 0 < u["neuron_utilization"] < 1
    assert 0 < u["row_utilization"] < 1
    assert u["models"] == ["a"]
    assert u["clusters_used"] >= -(-50 // 32)  # >= ceil(neurons/32)
