"""Multi-model co-residency (paper §V-D): address-space isolation AND
true fusion — run_all advances all resident models in one engine scan."""

import jax
import numpy as np
import pytest

from repro.core import cerebra_h
from repro.core.engine import SpikeEngine
from repro.core.session import AcceleratorSession

from conftest import make_ff_net


def test_co_residency_isolation(rng):
    """A model's outputs are identical whether it runs alone or alongside
    other resident models — disjoint clusters + rows = no interference."""
    netA = make_ff_net(rng, sizes=(12, 40, 10))
    netB = make_ff_net(rng, sizes=(8, 30, 5), scale=0.9)
    key = jax.random.key(0)
    xA = rng.random((6, 12)).astype(np.float32)
    xB = rng.random((6, 8)).astype(np.float32)

    solo = AcceleratorSession()
    solo.deploy("A", netA)
    outA_solo = solo.run("A", xA, 20, key)

    both = AcceleratorSession()
    both.deploy("A", netA)
    both.deploy("B", netB)
    outs = both.run_all({"A": xA, "B": xB}, 20, key)

    np.testing.assert_array_equal(
        np.asarray(outA_solo["predictions"]),
        np.asarray(outs["A"]["predictions"]))
    np.testing.assert_array_equal(
        np.asarray(outA_solo["output_counts"]),
        np.asarray(outs["A"]["output_counts"]))


def test_run_all_is_one_fused_scan(rng, monkeypatch):
    """N co-resident models with a shared LIF config advance in EXACTLY one
    SpikeEngine scan — no per-model Python loop over run()."""
    sess = AcceleratorSession()
    sess.deploy("A", make_ff_net(rng, sizes=(12, 40, 10)))
    sess.deploy("B", make_ff_net(rng, sizes=(8, 30, 5), scale=0.9))
    sess.deploy("C", make_ff_net(rng, sizes=(6, 20, 4)))

    scans = []
    orig_run = SpikeEngine.run
    monkeypatch.setattr(SpikeEngine, "run",
                        lambda self, ext: scans.append(self) or
                        orig_run(self, ext))

    def no_solo_run(*a, **k):  # run_all must not fall back to solo runs
        raise AssertionError("run_all looped over per-model run()")
    monkeypatch.setattr(AcceleratorSession, "run", no_solo_run)

    xs = {"A": rng.random((4, 12)).astype(np.float32),
          "B": rng.random((4, 8)).astype(np.float32),
          "C": rng.random((4, 6)).astype(np.float32)}
    outs = sess.run_all(xs, 15, jax.random.key(1))
    assert len(scans) == 1  # one fused engine scan for all three models
    assert set(outs) == {"A", "B", "C"}
    # the fused engine covers the concatenated external sources
    assert scans[0].n_inputs == 12 + 8 + 6


def test_run_all_isolation_bit_exact_per_model(rng):
    """Every co-resident model (not just the first) decodes identically to
    its solo deployment at the same placement."""
    nets = {"A": make_ff_net(rng, sizes=(12, 40, 10)),
            "B": make_ff_net(rng, sizes=(8, 30, 5), scale=0.9)}
    key = jax.random.key(3)
    xs = {"A": rng.random((5, 12)).astype(np.float32),
          "B": rng.random((5, 8)).astype(np.float32)}

    both = AcceleratorSession()
    for name, net in nets.items():
        both.deploy(name, net)
    outs = both.run_all(xs, 18, key)

    # solo reference for B at the SAME placement: deploy a dummy A first
    solo = AcceleratorSession()
    for name, net in nets.items():
        solo.deploy(name, net)
    soloB = solo.run("B", xs["B"], 18, key)
    np.testing.assert_array_equal(np.asarray(soloB["output_counts"]),
                                  np.asarray(outs["B"]["output_counts"]))
    np.testing.assert_array_equal(np.asarray(soloB["spikes"]),
                                  np.asarray(outs["B"]["spikes"]))
    for k in ("cycles", "sops", "row_fetches"):
        np.testing.assert_array_equal(np.asarray(soloB[k]),
                                      np.asarray(outs["B"][k]))


def test_run_all_mixed_lif_configs_still_fused_per_group(rng, monkeypatch):
    """Models with different LIF configs form separate fused groups (the
    hardware's per-configuration register banks) — still no per-model
    loop, and outputs still match solo deployment."""
    netA = make_ff_net(rng, sizes=(10, 30, 6))
    netB = make_ff_net(rng, sizes=(8, 20, 4), decay_rate=0.5)
    sess = AcceleratorSession()
    sess.deploy("A", netA)
    sess.deploy("B", netB)

    scans = []
    orig_run = SpikeEngine.run
    monkeypatch.setattr(SpikeEngine, "run",
                        lambda self, ext: scans.append(self) or
                        orig_run(self, ext))

    key = jax.random.key(5)
    xs = {"A": rng.random((3, 10)).astype(np.float32),
          "B": rng.random((3, 8)).astype(np.float32)}
    outs = sess.run_all(xs, 12, key)
    assert len(scans) == 2  # one scan per LIF-config group

    monkeypatch.undo()
    solo = AcceleratorSession()
    solo.deploy("A", netA)
    soloA = solo.run("A", xs["A"], 12, key)
    np.testing.assert_array_equal(np.asarray(soloA["output_counts"]),
                                  np.asarray(outs["A"]["output_counts"]))


def test_fused_engine_cache_keyed_on_backend(rng, monkeypatch):
    """Switching sess.backend after a run_all must rebuild the fused
    engine for the new backend, not reuse the cached one."""
    sess = AcceleratorSession()
    sess.deploy("A", make_ff_net(rng, sizes=(6, 10, 4)))
    scans = []
    orig_run = SpikeEngine.run
    monkeypatch.setattr(SpikeEngine, "run",
                        lambda self, ext: scans.append(self) or
                        orig_run(self, ext))
    xs = {"A": rng.random((2, 6)).astype(np.float32)}
    key = jax.random.key(0)
    sess.run_all(xs, 5, key)
    assert scans[-1].backend == "reference"
    sess.backend = "pallas"
    sess.run_all(xs, 5, key)
    assert scans[-1].backend == "pallas"


def test_run_all_rejects_mismatched_batches(rng):
    sess = AcceleratorSession()
    sess.deploy("A", make_ff_net(rng, sizes=(6, 10, 4)))
    sess.deploy("B", make_ff_net(rng, sizes=(6, 10, 4)))
    with pytest.raises(ValueError, match="batch"):
        sess.run_all({"A": np.zeros((2, 6), np.float32),
                      "B": np.zeros((3, 6), np.float32)},
                     5, jax.random.key(0))


def test_group_boundary_isolation(rng):
    """Deployments round up to group boundaries so no two models share a
    weight SRAM (the hardware's address-space isolation guarantee)."""
    sess = AcceleratorSession()
    m1 = sess.deploy("m1", make_ff_net(rng, sizes=(6, 10, 4)))
    m2 = sess.deploy("m2", make_ff_net(rng, sizes=(6, 10, 4)))
    cpg = sess.geometry.clusters_per_group
    assert m1.cluster_range[1] % cpg == 0
    assert m2.cluster_range[0] >= m1.cluster_range[1]


def test_capacity_exhaustion(rng):
    sess = AcceleratorSession()
    sess.deploy("big", make_ff_net(rng, sizes=(10, 900, 10)))
    with pytest.raises(ValueError, match="clusters"):
        sess.deploy("more", make_ff_net(rng, sizes=(10, 200, 10)))


def test_duplicate_name_rejected(rng):
    sess = AcceleratorSession()
    sess.deploy("m", make_ff_net(rng, sizes=(4, 8, 2)))
    with pytest.raises(ValueError, match="already"):
        sess.deploy("m", make_ff_net(rng, sizes=(4, 8, 2)))


def test_utilization_accounting(rng):
    sess = AcceleratorSession()
    sess.deploy("a", make_ff_net(rng, sizes=(6, 40, 10)))
    u = sess.utilization()
    assert 0 < u["neuron_utilization"] < 1
    assert 0 < u["row_utilization"] < 1
    assert u["models"] == ["a"]
    assert u["clusters_used"] >= -(-50 // 32)  # >= ceil(neurons/32)
