"""Metrics-registry contracts: instruments, label families, exporters.

The registry is the single accounting substrate for the serving stack, so
its semantics are pinned tightly: counters are monotone, gauges are
point-in-time, histograms keep exact bucket/sum/count AND a rolling raw
window, label families key children by label values, the injectable clock
drives ``timer()``, and both exporters (Prometheus text exposition, JSON
snapshot) carry every registered metric name even before traffic arrives
— the CI observability smoke relies on that last property.
"""

import json

import pytest

from repro.obs import METRIC_SPECS, MetricsRegistry, get_registry, \
    set_registry
from repro.obs.metrics import LATENCY_BUCKETS, MetricSpec


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def test_counter_inc_and_negative_rejected():
    reg = MetricsRegistry()
    c = reg.counter("snn_server_steps_total")
    c.inc()
    c.inc(41)
    assert c.value == 42
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_set_inc_dec():
    reg = MetricsRegistry()
    g = reg.gauge("snn_frontend_queue_depth")
    g.set(7)
    g.inc(2)
    g.dec()
    assert g.value == 8


def test_histogram_buckets_sum_count_and_samples():
    reg = MetricsRegistry()
    h = reg.histogram("snn_server_chunk_latency_seconds")
    for v in (5e-5, 2e-4, 0.3, 99.0):
        h.observe(v)
    child = h.labels() if h.spec.labels else h._require_default()
    assert child.count == 4
    assert child.sum == pytest.approx(5e-5 + 2e-4 + 0.3 + 99.0)
    # 99.0 overflows every finite bucket -> +Inf slot
    assert child.bucket_counts[-1] == 1
    assert list(child.samples) == [5e-5, 2e-4, 0.3, 99.0]


def test_label_families_key_children_independently():
    reg = MetricsRegistry()
    fam = reg.counter("snn_frontend_requests_total")
    fam.labels(outcome="done").inc(3)
    fam.labels(outcome="rejected").inc()
    assert fam.labels(outcome="done").value == 3
    assert fam.labels(outcome="rejected").value == 1
    # an unlabeled use of a labeled family is a bug, not a default child
    with pytest.raises(ValueError):
        fam.inc()
    with pytest.raises(ValueError):
        fam.labels(outcome="a", extra="b")


def test_kind_and_registration_errors():
    reg = MetricsRegistry()
    with pytest.raises(KeyError):
        reg.counter("no_such_metric")
    with pytest.raises(TypeError):
        reg.counter("snn_frontend_queue_depth")  # it is a gauge
    # same-spec re-registration is idempotent; conflicting spec raises
    spec = METRIC_SPECS["snn_server_steps_total"]
    assert reg.register(spec) is reg.counter("snn_server_steps_total")
    with pytest.raises(ValueError):
        reg.register(MetricSpec(spec.name, "gauge", "different"))


def test_injectable_clock_drives_timer():
    clk = FakeClock()
    reg = MetricsRegistry(clock=clk)
    with reg.timer("snn_server_chunk_latency_seconds"):
        clk.t += 0.25
    child = reg.histogram("snn_server_chunk_latency_seconds") \
        ._require_default()
    assert child.count == 1
    assert child.sum == pytest.approx(0.25)
    with reg.timer("snn_connector_op_seconds", op="snapshot"):
        clk.t += 1.5
    labeled = reg.histogram("snn_connector_op_seconds").labels(op="snapshot")
    assert labeled.sum == pytest.approx(1.5)


def test_prometheus_exposition_contains_every_documented_name():
    reg = MetricsRegistry()
    text = reg.to_prometheus()
    for name, spec in METRIC_SPECS.items():
        assert f"# HELP {name} " in text
        assert f"# TYPE {name} {spec.kind}" in text


def test_prometheus_histogram_lines_are_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("snn_server_chunk_latency_seconds")
    h.observe(LATENCY_BUCKETS[0] / 2)   # first bucket
    h.observe(LATENCY_BUCKETS[0] / 2)
    h.observe(LATENCY_BUCKETS[2])       # third bucket
    lines = [ln for ln in reg.to_prometheus().splitlines()
             if ln.startswith("snn_server_chunk_latency_seconds")]
    buckets = [ln for ln in lines if "_bucket{" in ln]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in buckets]
    assert counts == sorted(counts), "le buckets must be cumulative"
    assert counts[0] == 2 and counts[-1] == 3
    assert any(ln.startswith("snn_server_chunk_latency_seconds_sum ")
               for ln in lines)
    assert any(ln.startswith("snn_server_chunk_latency_seconds_count 3")
               for ln in lines)


def test_snapshot_is_json_able_and_complete():
    reg = MetricsRegistry()
    reg.counter("snn_server_sops_total").inc(123)
    reg.counter("snn_server_source_events_total").labels(
        kind="external").inc(9)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert set(snap) == set(METRIC_SPECS)
    assert snap["snn_server_sops_total"]["samples"][0]["value"] == 123
    ev = snap["snn_server_source_events_total"]["samples"]
    assert ev == [{"labels": {"kind": "external"}, "value": 9}]
    hist = snap["snn_server_chunk_latency_seconds"]
    assert hist["type"] == "histogram"
    assert "+Inf" in hist["samples"][0]["buckets"]


def test_prometheus_label_value_escaping():
    # the text exposition format escapes backslash, newline and double
    # quote in label VALUES — an unescaped one silently corrupts the
    # scrape, so pin each case (and their combination) byte-exactly
    reg = MetricsRegistry()
    fam = reg.counter("snn_frontend_requests_total")
    fam.labels(outcome='say "hi"').inc()
    fam.labels(outcome="a\\b").inc(2)
    fam.labels(outcome="two\nlines").inc(3)
    fam.labels(outcome='mix\\"\n').inc(4)
    text = reg.to_prometheus()
    assert r'outcome="say \"hi\""' in text
    assert r'outcome="a\\b"' in text
    assert r'outcome="two\nlines"' in text
    assert r'outcome="mix\\\"\n"' in text
    # negative: the raw bytes must NOT leak through
    assert 'outcome="say "hi""' not in text
    assert "two\nlines" not in text
    for line in text.splitlines():
        if not line.startswith("#"):
            assert "\n" not in line  # tautology post-split; shape guard
            assert line == line.strip()


def test_prometheus_help_escaping_backslash_and_newline():
    # HELP text escapes backslash + newline (quotes stay literal); a
    # registry with a hostile help string must still emit parseable
    # line-oriented exposition
    reg = MetricsRegistry()
    spec = MetricSpec("snn_test_escape_total", "counter",
                      'multi\nline \\ "quoted"')
    reg.register(spec)
    text = reg.to_prometheus()
    assert r'# HELP snn_test_escape_total multi\nline \\ "quoted"' in text
    # every physical line still starts with a name or a comment marker
    for line in text.splitlines():
        assert line.startswith("#") or line[0].isalpha()


def test_histogram_bucket_edge_is_inclusive():
    # `le` means <=: a value landing EXACTLY on a bucket edge counts in
    # that bucket, not the next one up
    reg = MetricsRegistry()
    h = reg.histogram("snn_server_chunk_latency_seconds")
    h.observe(LATENCY_BUCKETS[1])
    child = h._require_default()
    assert child.bucket_counts[0] == 0
    assert child.bucket_counts[1] == 1
    assert sum(child.bucket_counts) == 1
    # and the cumulative exposition agrees from that edge upward
    lines = [ln for ln in reg.to_prometheus().splitlines()
             if "_bucket{" in ln]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in lines]
    assert counts[0] == 0 and counts[1] == 1 and counts[-1] == 1


def test_timer_observes_even_when_body_raises():
    clk = FakeClock()
    reg = MetricsRegistry(clock=clk)
    with pytest.raises(RuntimeError):
        with reg.timer("snn_server_chunk_latency_seconds"):
            clk.t += 0.75
            raise RuntimeError("body blew up")
    child = reg.histogram("snn_server_chunk_latency_seconds") \
        ._require_default()
    assert child.count == 1
    assert child.sum == pytest.approx(0.75)


def test_registries_are_isolated_and_global_is_swappable():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("snn_server_steps_total").inc(5)
    assert b.counter("snn_server_steps_total").value == 0
    prev = set_registry(a)
    try:
        assert get_registry() is a
        assert set_registry(b) is a
        assert get_registry() is b
    finally:
        set_registry(prev)
