"""Cerebra-S / Cerebra-H functional models vs independent big-int oracles,
cost-model accounting, and the HW-vs-SW agreement contract (Table IV role).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cerebra_h, cerebra_s, software
from repro.core import fixedpoint as fxp
from repro.core.lif import LIFParams
from repro.core.mapping import ClusterGeometry
from repro.core.network import SNNetwork

from conftest import make_ff_net, make_random_net


def _python_sim(W_raw, ext, params, decay_kind, decay_arg, n_phys):
    """Independent big-int simulator of the accelerator timestep loop.

    W_raw: (n_in+n_phys, n_phys) int; ext: (T, B, n_in) {0,1}.
    decay_kind: 'mul' (Cerebra-S raw retain factor) | 'shift' (rate).
    """
    def wrap(x):
        return ((x + 2**31) % 2**32) - 2**31

    T, B, n_in = ext.shape
    thr = params.threshold_raw
    v = [[0] * n_phys for _ in range(B)]
    prev = [[0] * n_phys for _ in range(B)]
    rasters = np.zeros((T, B, n_phys), np.int32)
    for t in range(T):
        for b in range(B):
            sources = list(ext[t, b]) + prev[b]
            syn = [0] * n_phys
            for s, active in enumerate(sources):
                if active:
                    for d in range(n_phys):
                        w = int(W_raw[s, d])
                        if w:
                            syn[d] = wrap(syn[d] + w)
            new_spk = [0] * n_phys
            for d in range(n_phys):
                if decay_kind == "mul":
                    vd = (v[b][d] * decay_arg) >> 16
                else:
                    k = {0.125: 3, 0.25: 2, 0.5: 1}.get(decay_arg)
                    vd = (v[b][d] >> 2) if decay_arg == 0.75 else (
                        v[b][d] - (v[b][d] >> k))
                vn = wrap(vd + syn[d])
                spk = 1 if vn >= thr else 0
                new_spk[d] = spk
                if params.reset_mode == "zero":
                    v[b][d] = 0 if spk else vn
                elif params.reset_mode == "subtract":
                    v[b][d] = wrap(vn - spk * thr)
                else:
                    v[b][d] = vn
            prev[b] = new_spk
            rasters[t, b] = new_spk
    return rasters


@pytest.mark.parametrize("reset_mode", ["zero", "subtract"])
def test_cerebra_s_bit_exact_vs_python(rng, reset_mode):
    net = make_random_net(rng, n_in=6, n_neurons=10, density=0.4,
                          decay_rate=0.3, reset_mode=reset_mode)
    cfg = cerebra_s.CerebraSConfig(n_physical_neurons=16)
    prog = cerebra_s.compile_network(net, cfg)
    ext = (rng.random((8, 2, 6)) < 0.4).astype(np.int32)
    out = cerebra_s.run(prog, ext)
    want = _python_sim(np.asarray(prog.weights_raw), ext, net.params,
                       "mul", prog.decay_raw, 16)
    np.testing.assert_array_equal(np.asarray(out["spikes"]), want)


def test_cerebra_h_bit_exact_vs_python(rng):
    geom = ClusterGeometry(n_clusters=4, neurons_per_cluster=4,
                           clusters_per_group=2, rows_per_group=64,
                           clusters_per_l1=2)
    net = make_random_net(rng, n_in=5, n_neurons=12, density=0.5,
                          decay_rate=0.25)
    cfg = cerebra_h.CerebraHConfig(geometry=geom)
    prog = cerebra_h.compile_network(net, cfg)
    ext = (rng.random((10, 3, 5)) < 0.4).astype(np.int32)
    out = cerebra_h.run(prog, ext)
    W = np.asarray(prog.weights_raw).reshape(prog.n_sources, -1)
    want = _python_sim(W, ext, net.params, "shift", prog.decay_rate,
                       geom.n_physical)
    np.testing.assert_array_equal(np.asarray(out["spikes"]), want)


def test_s_and_h_predictions_agree(rng):
    """Same logical net through both generations -> same classifications
    (paper: 'behavioral consistency across accelerator generations')."""
    net = make_ff_net(rng, sizes=(16, 32, 10))
    ext = (rng.random((25, 8, 16)) < 0.35).astype(np.int32)
    outS = cerebra_s.run(cerebra_s.compile_network(net), ext)
    outH = cerebra_h.run(cerebra_h.compile_network(net), ext)
    predS = np.argmax(np.asarray(outS["output_counts"]), -1)
    predH = np.argmax(np.asarray(outH["output_counts"]), -1)
    assert (predS == predH).mean() >= 0.75


def test_hw_vs_sw_deviation_contract(rng):
    """The Table IV premise: HW (fixed, snapped decay) vs SW (float, exact
    decay) on identical spike trains -> small deviation, not identity."""
    net = make_ff_net(rng, sizes=(24, 48, 10), decay_rate=0.2)  # snaps .25
    ext = (rng.random((40, 16, 24)) < 0.3).astype(np.float32)
    sw = software.run_software(net, ext)
    hw = cerebra_h.run(cerebra_h.compile_network(net), ext.astype(np.int32))
    preds_sw = np.argmax(np.asarray(sw["output_counts"]), -1)
    preds_hw = np.argmax(np.asarray(hw["output_counts"]), -1)
    assert (preds_sw == preds_hw).mean() >= 0.5  # same-trend, quantized
    # spike rasters over the physical slots of logical neurons correlate
    phys = hw["spikes"][:, :, :net.n_neurons]
    agree = (np.asarray(phys) == np.asarray(sw["spikes"])).mean()
    assert agree > 0.9


def test_cerebra_s_cost_model(rng):
    """Bus cycles = sum of fanouts of spiking sources (1 event / cycle)."""
    net = make_random_net(rng, n_in=8, n_neurons=12, density=0.5)
    prog = cerebra_s.compile_network(net)
    ext = np.zeros((2, 1, 8), np.int32)
    ext[0, 0, [1, 3]] = 1
    out = cerebra_s.run(prog, ext)
    fanout = prog.fanout
    assert int(out["cycles"][0, 0]) == fanout[1] + fanout[3]
    # step 2: externally silent; cycles = fanout of neurons that spiked at t0
    spiked = np.where(np.asarray(out["spikes"][0, 0]) > 0)[0]
    want = sum(fanout[prog.n_inputs + int(i)] for i in spiked)
    assert int(out["cycles"][1, 0]) == want


def test_cerebra_h_cost_model_parallelism(rng):
    """H cycles track the max-loaded group/L1, not the total (parallel
    groups) -> H is far below S on the same workload."""
    net = make_ff_net(rng, sizes=(20, 64, 10))
    ext = (rng.random((20, 4, 20)) < 0.4).astype(np.int32)
    outS = cerebra_s.run(cerebra_s.compile_network(net), ext)
    outH = cerebra_h.run(cerebra_h.compile_network(net), ext)
    cyc_s = float(np.asarray(outS["cycles"]).sum())
    cyc_h = float(np.asarray(outH["cycles"]).sum())
    assert cyc_h < cyc_s  # clustered memory + NoC beats the serial bus
    # SOPs are identical work regardless of architecture
    np.testing.assert_array_equal(np.asarray(outS["sops"]).sum(),
                                  np.asarray(outH["sops"]).sum())


def test_capacity_rejection():
    geom = ClusterGeometry(rows_per_group=4)
    dense = SNNetwork(
        n_inputs=64, n_neurons=64,
        weights=np.ones((128, 64), np.float32),
        params=LIFParams(decay_rate=0.25))
    with pytest.raises(ValueError, match="capacity"):
        cerebra_h.compile_network(
            dense, cerebra_h.CerebraHConfig(geometry=geom))


def test_weight_quantization_roundtrip(rng):
    net = make_ff_net(rng)
    prog = cerebra_h.compile_network(net)
    flat = np.asarray(prog.weights_raw).reshape(prog.n_sources, -1)
    # dequantized blocked weights match the placed float weights to 1 LSB
    geom = prog.config.geometry
    W = np.zeros((prog.n_sources, geom.n_physical), np.float32)
    phys = prog.placement.neuron_to_physical
    W[:net.n_inputs, phys] = net.weights[:net.n_inputs]
    W[net.n_inputs + phys[:, None], phys[None, :]] = net.weights[net.n_inputs:]
    np.testing.assert_allclose(flat / 65536.0, W, atol=0.5 / 65536 + 1e-7)
