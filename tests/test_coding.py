"""Spike coding unit (encoder/decoder) invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import coding


def test_poisson_rate_statistics():
    key = jax.random.key(0)
    x = jnp.asarray([[0.0, 0.1, 0.5, 0.9, 1.0]])
    T = 4000
    s = coding.poisson_encode(key, x, T)
    rates = np.asarray(s.mean(axis=0))[0]
    np.testing.assert_allclose(rates, np.asarray(x)[0], atol=0.03)
    assert rates[0] == 0.0 and rates[-1] == 1.0


def test_poisson_deterministic_given_key():
    key = jax.random.key(42)
    x = jnp.full((3, 7), 0.4)
    a = coding.poisson_encode(key, x, 50)
    b = coding.poisson_encode(key, x, 50)
    assert bool((a == b).all())


@given(st.lists(st.floats(0.01, 1.0), min_size=2, max_size=6))
@settings(max_examples=50, deadline=None)
def test_latency_encode_order(intensities):
    x = jnp.asarray(intensities)
    T = 32
    s = np.asarray(coding.latency_encode(x, T))
    # exactly one spike per active input
    assert (s.sum(0) == 1).all()
    t_fire = s.argmax(0)
    # stronger input fires no later
    order = np.argsort(-x)
    assert all(t_fire[order[i]] <= t_fire[order[i + 1]]
               for i in range(len(order) - 1))


def test_latency_encode_silent_at_zero():
    s = np.asarray(coding.latency_encode(jnp.asarray([0.0, 0.5]), 16))
    assert s[:, 0].sum() == 0 and s[:, 1].sum() == 1


@given(st.integers(1, 10), st.integers(1, 5), st.integers(2, 6))
@settings(max_examples=30, deadline=None)
def test_decode_invariants(T, B, D):
    rng = np.random.default_rng(T * 100 + B * 10 + D)
    spikes = jnp.asarray((rng.random((T, B, D)) < 0.5).astype(np.float32))
    counts = coding.rate_decode(spikes)
    assert counts.shape == (B, D)
    assert float(counts.sum()) == float(spikes.sum())
    cls = coding.classify_decode(spikes)
    assert cls.shape == (B,)
    assert ((np.asarray(cls) >= 0) & (np.asarray(cls) < D)).all()
    analog = coding.analog_decode(spikes, lo=-1.0, hi=3.0)
    a = np.asarray(analog)
    assert ((a >= -1.0 - 1e-6) & (a <= 3.0 + 1e-6)).all()


def test_analog_decode_closed_loop():
    """encode -> decode approximates identity (the SoC's sensor->actuator
    loop contract)."""
    key = jax.random.key(1)
    x = jnp.asarray([[0.2, 0.5, 0.8]])
    s = coding.poisson_encode(key, x, 2000)
    y = np.asarray(coding.analog_decode(s))[0]
    np.testing.assert_allclose(y, np.asarray(x)[0], atol=0.05)
