"""Live-migration byte-identity: moving a stream must never change a bit.

The governing contract of the stream-state connector: a stream whose
carry is snapshotted, parked, and restored — onto another server, another
slot, another backend/gate/fuse hosting, a mesh-sharded server, or a
fresh process after a crash — produces a spike raster BYTE-identical to
the same stream never migrated. Pinned here:

  * cross-server migration (detach_stream -> connector -> attach_stream)
    for every backend x reset mode x gate x fuse_steps re-hosting — fast
    reference legs always run, the full sweep rides the ``slow`` marker
    (same tiering as ``test_fused_steps.py``);
  * migration into mesh-sharded servers (1x1 always; 2x2 when devices
    allow — CI fakes 8 via XLA_FLAGS=--xla_force_host_platform_device_count=8);
  * intra-server ``migrate_stream`` (a slot index is an address, not a
    parameter) and straggler-driven ``rebalance_streams`` (flagged shards
    drained onto donors, deterministically, every moved stream bit-clean);
  * session-level rolling redeploy: ``deploy`` mid-stream parks live
    carries, the next ``serve`` restores them, and the spliced raster +
    decoded outputs equal an uninterrupted run;
  * crash recovery: ``checkpoint_streams`` to a file-backed connector,
    drop the server, rebuild on a NEW connector instance over the same
    directory — resumed streams continue bit-clean;
  * restore-side safety: incompatible engines, full servers, and missing
    snapshots are refused BEFORE any server state mutates.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coding
from repro.core.engine import BACKENDS, GATES, DecaySpec, SpikeEngine
from repro.core.lif import LIFParams
from repro.core.network import SNNetwork
from repro.core.session import AcceleratorSession
from repro.distributed.spike_mesh import make_spike_mesh
from repro.serving.connector import (FileCarryConnector,
                                     InMemoryCarryConnector, migrate_stream,
                                     rebalance_streams)
from repro.serving.snn import SpikeServer

THRESH = 1 << 16
RESET_MODES = ("zero", "subtract", "hold")


def _engine(rng, *, backend="reference", gate="batch-tile", reset="subtract",
            K=1, n_in=10, n_phys=16, wmax=1 << 13):
    S = n_in + n_phys
    W = ((rng.random((S, n_phys)) < 0.4)
         * rng.integers(-wmax, wmax, (S, n_phys)))
    return SpikeEngine(jnp.asarray(W, jnp.int32), n_in,
                       decay=DecaySpec.shift(0.25), threshold_raw=THRESH,
                       reset_mode=reset, backend=backend, gate=gate,
                       fuse_steps=K)


def _raster(rng, T, n_in, p=0.35):
    return (rng.random((T, n_in)) < p).astype(np.int32)


def _migrated_vs_reference(engine_a, engine_b, rng, *, T=14, t_mid=6,
                           connector=None, chunk_a=5, chunk_b=3,
                           mesh_b=None):
    """THE contract check: stream T steps with a mid-flight hop from
    server A to server B through a connector; the stitched raster must
    equal the one-shot never-migrated ``run`` on engine A."""
    ext = _raster(rng, T, engine_a.n_inputs)
    want = np.asarray(engine_a.run(ext[:, None, :])["spikes"])[:, 0]

    conn = connector if connector is not None else InMemoryCarryConnector()
    a = SpikeServer(engine_a, n_slots=3, chunk_steps=chunk_a)
    b = SpikeServer(engine_b, n_slots=4, chunk_steps=chunk_b, mesh=mesh_b)
    uid = a.attach("mig")
    first = a.feed({uid: ext[:t_mid]})[uid]["spikes"]

    a.detach_stream(uid, conn)
    assert uid not in a.streams and uid in conn
    b.attach_stream(conn, uid)
    assert uid not in conn  # the hop consumed the parked carry

    second = b.feed({uid: ext[t_mid:]})[uid]["spikes"]
    got = np.concatenate([np.asarray(first), np.asarray(second)], axis=0)
    assert got.dtype == want.dtype == np.int32
    np.testing.assert_array_equal(got, want)
    assert b.streams[uid].steps == T  # counters rode along


# --------------------------------------------------------------------------
# cross-server migration: fast legs + full slow sweep
# --------------------------------------------------------------------------

def test_cross_server_migration_fast(rng):
    """Reference engine, ragged chunking on both sides, in-memory hop —
    the always-on leg of the contract."""
    e = _engine(rng)
    _migrated_vs_reference(e, e, rng)


def test_cross_server_migration_through_file_fast(rng, tmp_path):
    """Same hop through the file-backed connector: the bytes take the
    disk detour and still land identical."""
    e = _engine(rng, reset="zero")
    _migrated_vs_reference(
        e, e, rng, connector=FileCarryConnector(str(tmp_path / "c")))


def test_migration_across_hostings_fast(rng):
    """A carry is portable across backend/gate/fuse re-hostings: park on
    the reference server, resume on a fused per-example pallas server."""
    src = _engine(rng)
    # same weights so the slot params (and the future) agree
    dst = SpikeEngine(src.weights_raw, src.n_inputs,
                      decay=DecaySpec.shift(0.25), threshold_raw=THRESH,
                      reset_mode="subtract", backend="pallas",
                      gate="per-example", fuse_steps=4)
    _migrated_vs_reference(src, dst, rng)


@pytest.mark.slow
@pytest.mark.parametrize("reset", RESET_MODES)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("gate", GATES)
@pytest.mark.parametrize("K", [1, 4])
def test_cross_server_migration_sweep(rng, backend, reset, gate, K):
    """Full hosting matrix: migrate FROM a reference server INTO every
    backend x reset x gate x fuse_steps hosting. t_mid=7 lands mid-window
    for K=4 — the restored carry starts a fresh window, which must not
    show in the bits."""
    src = _engine(rng, reset=reset)
    dst = SpikeEngine(src.weights_raw, src.n_inputs,
                      decay=DecaySpec.shift(0.25), threshold_raw=THRESH,
                      reset_mode=reset, backend=backend, gate=gate,
                      fuse_steps=K)
    _migrated_vs_reference(src, dst, rng, t_mid=7)


# --------------------------------------------------------------------------
# mesh: migrate into (and out of) a sharded server
# --------------------------------------------------------------------------

def _mesh(neuron, batch):
    need = neuron * batch
    if len(jax.devices()) < need:
        pytest.skip(
            f"needs {need} devices; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return make_spike_mesh(neuron=neuron, batch=batch)


@pytest.mark.parametrize("shape", [(1, 1), (2, 2)])
def test_migration_into_mesh_server(rng, shape):
    """A carry parked on a single-device server resumes bit-clean on a
    mesh-sharded one (and 1x1 exercises the shard_map path everywhere)."""
    mesh = _mesh(*shape)
    e = _engine(rng, n_in=11, n_phys=24)
    _migrated_vs_reference(e, e, rng, mesh_b=mesh)


def test_migration_out_of_mesh_server(rng):
    """And back: a stream born sharded hops to a plain server."""
    mesh = _mesh(1, 1)
    e = _engine(rng, reset="hold")
    ext = _raster(rng, 12, e.n_inputs)
    want = np.asarray(e.run(ext[:, None, :])["spikes"])[:, 0]

    conn = InMemoryCarryConnector()
    a = SpikeServer(e, n_slots=2, chunk_steps=4, mesh=mesh)
    b = SpikeServer(e, n_slots=2, chunk_steps=5)
    uid = a.attach()
    first = a.feed({uid: ext[:5]})[uid]["spikes"]
    a.detach_stream(uid, conn)
    b.attach_stream(conn, uid)
    second = b.feed({uid: ext[5:]})[uid]["spikes"]
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(first), np.asarray(second)]), want)


# --------------------------------------------------------------------------
# intra-server: migrate_stream + straggler rebalance
# --------------------------------------------------------------------------

def test_migrate_stream_changes_address_not_future(rng):
    """Mid-stream slot move: the raster continues byte-identically, the
    old slot is powered down, counters survive."""
    e = _engine(rng)
    ext = _raster(rng, 12, e.n_inputs)
    want = np.asarray(e.run(ext[:, None, :])["spikes"])[:, 0]

    server = SpikeServer(e, n_slots=4, chunk_steps=4)
    uid = server.attach()
    first = server.feed({uid: ext[:7]})[uid]["spikes"]

    old = migrate_stream(server, uid, slot=3)
    assert (old, server.slot_of(uid)) == (0, 3)
    assert not np.asarray(server.carry["v"][old]).any()  # zeroed behind

    second = server.feed({uid: ext[7:]})[uid]["spikes"]
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(first), np.asarray(second)]), want)
    assert server.streams[uid].steps == 12


def test_migrate_stream_same_slot_is_noop(rng):
    server = SpikeServer(_engine(rng), n_slots=2, chunk_steps=4)
    uid = server.attach()
    before = np.asarray(server.carry["v"])
    assert migrate_stream(server, uid, slot=0) == 0
    np.testing.assert_array_equal(np.asarray(server.carry["v"]), before)


def test_rebalance_drains_flagged_shards_bit_clean(rng):
    """8 slots / 4 shards (slots_per_shard=2), shard 0 flagged: its
    streams walk onto donor shards' free slots, lowest ids first, and a
    twin server that never rebalanced proves every stream's raster is
    untouched by the move."""
    e = _engine(rng)
    moved = SpikeServer(e, n_slots=8, chunk_steps=4)
    still = SpikeServer(e, n_slots=8, chunk_steps=4)
    uids = ["s0", "s1", "s2"]
    for server in (moved, still):
        for u in uids:
            server.attach(u)
    # slots 0,1 (shard 0, flagged) + slot 2 (shard 1)
    rasters = {u: _raster(rng, 16, e.n_inputs) for u in uids}
    for server in (moved, still):
        server.feed({u: r[:6] for u, r in rasters.items()})

    flagged = [True, False, False, False]
    moves = rebalance_streams(moved, flagged, slots_per_shard=2)
    # deterministic: busiest flagged shard's lowest live slot -> the
    # emptiest donor shard's lowest free slot (shard 2, slot 4); a second
    # move would only relocate the imbalance, so exactly one happens
    assert moves == [("s0", 0, 4)]
    assert moved.slot_of("s0") == 4 and moved.slot_of("s1") == 1

    got = moved.feed({u: r[6:] for u, r in rasters.items()})
    want = still.feed({u: r[6:] for u, r in rasters.items()})
    for u in uids:
        np.testing.assert_array_equal(np.asarray(got[u]["spikes"]),
                                      np.asarray(want[u]["spikes"]))


def test_rebalance_noop_cases(rng):
    server = SpikeServer(_engine(rng), n_slots=4, chunk_steps=4)
    server.attach("a")
    # nothing flagged / everything flagged (no donors): no moves
    assert rebalance_streams(server, [False, False],
                             slots_per_shard=2) == []
    assert rebalance_streams(server, [True, True],
                             slots_per_shard=2) == []
    assert server.slot_of("a") == 0


# --------------------------------------------------------------------------
# session: rolling redeploy parks and restores live streams
# --------------------------------------------------------------------------

def _net(rng, n_in=6, n_neurons=12, decay_rate=0.25, reset="zero"):
    W = ((rng.random((n_in + n_neurons, n_neurons)) < 0.4)
         * rng.normal(0.0, 0.5, (n_in + n_neurons, n_neurons)))
    return SNNetwork(
        n_inputs=n_in, n_neurons=n_neurons, weights=W.astype(np.float32),
        params=LIFParams(decay_rate=decay_rate, threshold=1.0,
                         reset_mode=reset),
        output_slice=(n_neurons - 4, n_neurons))


def test_session_redeploy_preserves_live_streams(rng):
    """deploy() mid-stream is a rolling redeploy: the live stream's carry
    rides the session connector across the fused-layout change and the
    spliced outputs equal an uninterrupted single-model run."""
    netA, netB = _net(rng), _net(rng, n_in=5, n_neurons=10)
    ext = (rng.random((12, 6)) < 0.4).astype(np.int32)

    solo = AcceleratorSession()
    solo.deploy("A", netA)
    sv = solo.serve("A", n_slots=2, chunk_steps=4)
    u = sv.attach("live")
    want = [sv.feed(u, ext[:5]), sv.feed(u, ext[5:])]

    sess = AcceleratorSession()
    sess.deploy("A", netA)
    view = sess.serve("A", n_slots=2, chunk_steps=4)
    uid = view.attach("live")
    got_first = view.feed(uid, ext[:5])

    sess.deploy("B", netB)          # invalidates the view, parks "live"
    with pytest.raises(RuntimeError):
        view.feed(uid, ext[5:6])
    view2 = sess.serve("A", n_slots=2, chunk_steps=4)
    got_second = view2.feed(uid, ext[5:])

    for got, ref in ((got_first, want[0]), (got_second, want[1])):
        np.testing.assert_array_equal(np.asarray(got["spikes"]),
                                      np.asarray(ref["spikes"]))
        np.testing.assert_array_equal(got["output_counts"],
                                      ref["output_counts"])
    assert view2.server.streams[uid].steps == 12


def test_session_redeploy_keeps_waiting_streams_waiting(rng):
    """A stream still queued for a slot has no carry; the redeploy must
    re-queue it (not drop it, not fabricate state)."""
    sess = AcceleratorSession()
    sess.deploy("A", _net(rng))
    view = sess.serve("A", n_slots=1, chunk_steps=4)
    view.attach("holder")
    view.attach("waiter")           # n_slots=1: this one queues
    sess.deploy("B", _net(rng, n_in=5, n_neurons=10))
    view2 = sess.serve("A", n_slots=1, chunk_steps=4)
    srv = view2.server
    assert srv.slot_of("holder") == 0
    assert srv.slot_of("waiter") is None and "waiter" in srv.streams


# --------------------------------------------------------------------------
# crash recovery: file-backed checkpoints outlive the server
# --------------------------------------------------------------------------

def test_crash_recovery_from_file_checkpoint(rng, tmp_path):
    """Kill the server after a checkpoint barrier; a NEW connector
    instance over the same directory rebuilds every stream on a fresh
    server, bit-clean — including counters."""
    e = _engine(rng, reset="subtract")
    ext = {u: _raster(rng, 15, e.n_inputs) for u in ("x", "y")}
    want = {u: np.asarray(e.run(r[:, None, :])["spikes"])[:, 0]
            for u, r in ext.items()}

    root = str(tmp_path / "wal")
    server = SpikeServer(e, n_slots=3, chunk_steps=5)
    for u in ext:
        server.attach(u)
    first = server.feed({u: r[:8] for u, r in ext.items()})
    assert server.checkpoint_streams(FileCarryConnector(root)) == ["x", "y"]
    steps_before = {u: server.streams[u].steps for u in ext}
    del server                      # the crash

    recovered = SpikeServer(e, n_slots=3, chunk_steps=5)
    restored = recovered.restore_streams(FileCarryConnector(root))
    assert sorted(restored, key=repr) == ["x", "y"]
    second = recovered.feed({u: r[8:] for u, r in ext.items()})
    for u in ext:
        got = np.concatenate([np.asarray(first[u]["spikes"]),
                              np.asarray(second[u]["spikes"])])
        np.testing.assert_array_equal(got, want[u])
        assert recovered.streams[u].steps == steps_before[u] + 7


def test_checkpoint_is_nondestructive(rng, tmp_path):
    """checkpoint_streams is a write barrier, not a drain: the source
    server keeps streaming identically afterwards."""
    e = _engine(rng)
    ext = _raster(rng, 10, e.n_inputs)
    want = np.asarray(e.run(ext[:, None, :])["spikes"])[:, 0]
    server = SpikeServer(e, n_slots=2, chunk_steps=4)
    uid = server.attach()
    first = server.feed({uid: ext[:4]})[uid]["spikes"]
    server.checkpoint_streams(FileCarryConnector(str(tmp_path / "c")))
    second = server.feed({uid: ext[4:]})[uid]["spikes"]
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(first), np.asarray(second)]), want)


def test_restore_streams_restores_what_fits(rng, tmp_path):
    conn = FileCarryConnector(str(tmp_path / "c"))
    e = _engine(rng)
    src = SpikeServer(e, n_slots=4, chunk_steps=4)
    for u in ("a", "b", "c"):
        src.attach(u)
    src.feed({u: _raster(rng, 4, e.n_inputs) for u in ("a", "b", "c")})
    src.checkpoint_streams(conn)

    tiny = SpikeServer(e, n_slots=2, chunk_steps=4)
    restored = tiny.restore_streams(conn)
    assert len(restored) == 2 and tiny.scheduler.free_slots == 0
    leftover = set(conn.stream_ids())
    assert leftover == {"a", "b", "c"} - set(restored)  # still parked


# --------------------------------------------------------------------------
# restore-side safety: refused before any state mutates
# --------------------------------------------------------------------------

def test_attach_stream_rejects_incompatible_server(rng):
    """A snapshot from a 16-neuron subtract engine must not land on a
    32-neuron or zero-reset server — and the refusal leaves the target
    completely untouched."""
    src = SpikeServer(_engine(rng), n_slots=2, chunk_steps=4)
    uid = src.attach()
    src.feed({uid: _raster(rng, 4, src.engine.n_inputs)})
    snap = src.snapshot_stream(uid)

    for bad_engine, field in ((_engine(rng, n_phys=32), "n_phys"),
                              (_engine(rng, reset="zero"), "reset_mode")):
        dst = SpikeServer(bad_engine, n_slots=2, chunk_steps=4)
        with pytest.raises(ValueError, match=field):
            dst.attach_stream(snap)
        assert not dst.streams and dst.scheduler.free_slots == 2
        assert not np.asarray(dst.carry["v"]).any()


def test_attach_stream_requires_free_slot(rng):
    e = _engine(rng)
    src = SpikeServer(e, n_slots=2, chunk_steps=4)
    uid = src.attach()
    src.feed({uid: _raster(rng, 3, e.n_inputs)})
    snap = src.snapshot_stream(uid)

    full = SpikeServer(e, n_slots=1, chunk_steps=4)
    full.attach()
    with pytest.raises(RuntimeError, match="free slot"):
        full.attach_stream(snap)
    assert len(full.streams) == 1   # no phantom half-attached stream


def test_attach_stream_connector_misuse(rng):
    e = _engine(rng)
    server = SpikeServer(e, n_slots=2, chunk_steps=4)
    conn = InMemoryCarryConnector()
    with pytest.raises(ValueError, match="stream id"):
        server.attach_stream(conn)            # connector source needs uid
    with pytest.raises(KeyError):
        server.attach_stream(conn, uid="ghost")


def test_snapshot_waiting_stream_raises(rng):
    server = SpikeServer(_engine(rng), n_slots=1, chunk_steps=4)
    server.attach("holder")
    server.attach("waiter")
    with pytest.raises(ValueError, match="waiting"):
        server.snapshot_stream("waiter")


def test_traced_migration_hop_reconstructs_violation_free(rng):
    """Lifecycle audit riding the migration contract: both servers share
    one SpanTracer through attach / feed / cross-server hop /
    intra-server migrate_stream / retire, and the timeline
    reconstruction — which hard-errors on illegal transitions, leaks, or
    retire-without-admit — accepts the trace with the expected
    park/admission/migration counts on the single stream identity."""
    from repro.obs import SpanTracer
    from repro.obs.timeline import reconstruct

    tracer = SpanTracer()
    e = _engine(rng)
    conn = InMemoryCarryConnector()
    a = SpikeServer(e, n_slots=3, chunk_steps=5, tracer=tracer)
    b = SpikeServer(e, n_slots=4, chunk_steps=3, tracer=tracer)
    ext = _raster(rng, 14, e.n_inputs)

    uid = a.attach("mig")
    first = a.feed({uid: ext[:6]})[uid]["spikes"]
    a.detach_stream(uid, conn)                # park on A
    b.attach_stream(conn, uid)                # resumed admit on B
    migrate_stream(b, uid, slot=3)            # address change on B
    second = b.feed({uid: ext[6:]})[uid]["spikes"]
    b.detach(uid, reason="done")

    want = np.asarray(e.run(ext[:, None, :])["spikes"])[:, 0]
    got = np.concatenate([np.asarray(first), np.asarray(second)])
    np.testing.assert_array_equal(got, want)  # audit never bends bytes

    rep = reconstruct(tracer)                 # raises on any violation
    st = rep.stream("mig")
    assert st.state == "retired" and st.outcome == "done"
    assert st.n_parks == 2                    # hop + intra-server move
    assert st.n_admissions == 3
    assert st.n_migrations == 1
    assert rep.by_state() == {"retired": 1}
