"""Data pipelines: determinism, resumability, sharding (stateless contract)."""

import numpy as np

from repro.data import lm, mnist


def test_render_digits_range_and_determinism():
    labels = np.arange(10).astype(np.int32)
    a = mnist.render_digits(labels, seed=3)
    b = mnist.render_digits(labels, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (10, 28, 28)
    assert a.min() >= 0.0 and a.max() <= 1.0
    # different classes render differently
    assert not np.allclose(a[0], a[1])


def test_digit_classes_are_separable():
    """Nearest-centroid on clean glyphs classifies jittered renders well —
    the procedural dataset is learnable, not noise."""
    protos = mnist.render_digits(np.arange(10), seed=0, jitter=False)
    protos = protos.reshape(10, -1)
    rng = np.random.default_rng(5)
    labels = rng.integers(0, 10, 128).astype(np.int32)
    imgs = mnist.render_digits(labels, seed=11).reshape(128, -1)
    d = ((imgs[:, None] - protos[None]) ** 2).sum(-1)
    acc = (d.argmin(1) == labels).mean()
    assert acc > 0.6  # raw-pixel NN under affine jitter; chance is 0.1


def test_mnist_batches_resumable_and_sharded():
    full = list(mnist.batches("train", 8, 6, seed=1))
    resumed = list(mnist.batches("train", 8, 6, seed=1, start_step=3))
    assert [s for s, _, _ in resumed] == [3, 4, 5]
    for (s1, x1, y1), (s2, x2, y2) in zip(full[3:], resumed):
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)
    # different shards draw different data at the same step
    _, xa, _ = next(iter(mnist.batches("train", 8, 1, seed=1,
                                       shard_index=0, num_shards=2)))
    _, xb, _ = next(iter(mnist.batches("train", 8, 1, seed=1,
                                       shard_index=1, num_shards=2)))
    assert not np.array_equal(xa, xb)


def test_load_or_generate_contract():
    x, y = mnist.load_or_generate("test", 32, seed=0)
    assert x.shape == (32, 784) and y.shape == (32,)
    x2, y2 = mnist.load_or_generate("test", 32, seed=0)
    np.testing.assert_array_equal(x, x2)
    xt, _ = mnist.load_or_generate("train", 32, seed=0)
    assert not np.array_equal(x, xt)  # splits differ


def test_lm_stream_properties():
    vocab = 101
    got = list(lm.lm_batches(vocab, 4, 32, 3, seed=2))
    assert len(got) == 3
    for _, toks, tgts in got:
        assert toks.shape == (4, 32) and tgts.shape == (4, 32)
        assert toks.min() >= 0 and toks.max() < vocab
        np.testing.assert_array_equal(toks[:, 1:], tgts[:, :-1])
    # resumability
    resumed = list(lm.lm_batches(vocab, 4, 32, 3, seed=2, start_step=2))
    np.testing.assert_array_equal(got[2][1], resumed[0][1])


def test_lm_stream_is_learnable():
    """Second-order structure: the same (prev2, prev) context yields the
    same 'structured' next token (most of the time)."""
    stream = lm.TokenStream(97, seed=0, structure=1.0)
    toks = stream.sample(2, 64, step=0)
    nxt = stream._hash_next(toks[:, 1:-1].ravel(), toks[:, :-2].ravel())
    match = (nxt == toks[:, 2:].ravel()).mean()
    assert match == 1.0  # structure=1.0 -> fully deterministic transitions
