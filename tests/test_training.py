"""Training substrate: optimizers, checkpoint/restart, loop resumability,
gradient compression, straggler detection."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import checkpoint as ckpt
from repro.distributed.straggler import StragglerDetector, rebalance_shards
from repro.training import optimizers
from repro.training.compression import Int8Compressor, TopKCompressor
from repro.training.loop import LoopConfig, run_loop


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------
@pytest.mark.parametrize("make", [
    lambda: optimizers.sgd(0.1, momentum=0.9),
    lambda: optimizers.adam(0.1),
    lambda: optimizers.adamw(0.1, weight_decay=0.0),
])
def test_optimizers_minimize_quadratic(make):
    opt = make()
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0)}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for _ in range(200):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params)
        params = optimizers.apply_updates(params, updates)
    assert float(loss(params)) < 1e-2


def test_adamw_decays_weights():
    opt = optimizers.adamw(0.1, weight_decay=0.5)
    params = {"w": jnp.asarray([10.0])}
    state = opt.init(params)
    zero_grad = {"w": jnp.asarray([0.0])}
    for _ in range(20):
        updates, state = opt.update(zero_grad, state, params)
        params = optimizers.apply_updates(params, updates)
    assert float(jnp.abs(params["w"][0])) < 10.0  # decayed toward zero


@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=1, max_size=16),
       st.floats(0.1, 10.0))
@settings(max_examples=50, deadline=None)
def test_clip_by_global_norm_property(vals, max_norm):
    g = {"x": jnp.asarray(vals, jnp.float32)}
    clipped, norm = optimizers.clip_by_global_norm(g, max_norm)
    out_norm = float(optimizers.global_norm(clipped))
    assert out_norm <= max_norm * (1 + 1e-4) + 1e-6
    if float(norm) <= max_norm:  # no-op when under the limit
        np.testing.assert_allclose(np.asarray(clipped["x"]),
                                   np.asarray(g["x"]), rtol=1e-5)


def test_warmup_cosine_schedule():
    fn = optimizers.Schedules.warmup_cosine(1.0, 10, 100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, abs=0.02)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)
    assert float(fn(jnp.asarray(5))) == pytest.approx(0.5, abs=1e-6)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------
def _tree(rng):
    return {
        "params": {"w": rng.normal(size=(4, 3)).astype(np.float32),
                   "b": rng.normal(size=(3,)).astype(np.float32)},
        "opt": {"m": [rng.normal(size=(2,)).astype(np.float32)]},
        "step": np.asarray(7),
    }


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    ckpt.save(str(tmp_path), 7, tree, metadata={"note": "hi"})
    restored, meta = ckpt.load(str(tmp_path), like=tree)
    assert meta["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_bf16_roundtrip(tmp_path, rng):
    """ml_dtypes (bf16) leaves must survive the npz store (raw-view path)."""
    tree = {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.bfloat16),
            "v": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
    host = jax.tree.map(lambda x: np.asarray(x), tree)
    ckpt.save(str(tmp_path), 0, host)
    restored, _ = ckpt.load(str(tmp_path), like=host)
    assert restored["w"].dtype == host["w"].dtype
    np.testing.assert_array_equal(restored["w"].view(np.uint16),
                                  host["w"].view(np.uint16))


def test_checkpoint_keep_k_gc(tmp_path, rng):
    tree = _tree(rng)
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_corruption_detected(tmp_path, rng):
    tree = _tree(rng)
    path = ckpt.save(str(tmp_path), 1, tree)
    arrays = os.path.join(path, "arrays.npz")
    raw = bytearray(open(arrays, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(arrays, "wb").write(bytes(raw))
    with pytest.raises(ckpt.CheckpointError, match="CRC"):
        ckpt.load(str(tmp_path), 1, like=tree)


def test_checkpoint_missing_key_detected(tmp_path, rng):
    tree = _tree(rng)
    ckpt.save(str(tmp_path), 1, tree)
    bigger = dict(tree, extra=np.zeros(3))
    with pytest.raises(ckpt.CheckpointError, match="missing"):
        ckpt.load(str(tmp_path), 1, like=bigger)


def test_async_checkpointer(tmp_path, rng):
    tree = _tree(rng)
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        saver.save(s, tree)
    saver.wait()
    assert ckpt.all_steps(str(tmp_path)) == [2, 3]


def test_checkpoint_elastic_reshard(tmp_path, rng):
    """Checkpoints are mesh-agnostic: load with an explicit sharding tree
    (single-device here; the contract is the device_put re-layout path)."""
    from jax.sharding import NamedSharding, PartitionSpec
    tree = {"w": rng.normal(size=(8, 4)).astype(np.float32)}
    ckpt.save(str(tmp_path), 0, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, PartitionSpec("data"))}
    restored, _ = ckpt.load(str(tmp_path), like=tree, sharding_tree=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])


# --------------------------------------------------------------------------
# fault-tolerant loop: preemption -> restart produces the SAME trajectory
# --------------------------------------------------------------------------
def _loop_pieces():
    def step_fn(state, batch):
        w = state["w"] - 0.1 * (state["w"] - batch)
        return dict(state, w=w), {"loss": float(jnp.sum((w - batch) ** 2))}

    def batch_fn(step):
        return jnp.asarray(float(step % 5))

    return step_fn, batch_fn


def test_preemption_resume_exact(tmp_path):
    step_fn, batch_fn = _loop_pieces()
    init = {"w": jnp.asarray(10.0), "step": 0}

    # uninterrupted run
    ref = run_loop(LoopConfig(total_steps=20, log_every=0),
                   dict(init), step_fn, batch_fn)

    # interrupted at step 13, checkpointing every 5
    cfg = LoopConfig(total_steps=20, checkpoint_dir=str(tmp_path),
                     checkpoint_every=5, log_every=0, fail_at_step=13)
    with pytest.raises(RuntimeError, match="preemption"):
        run_loop(cfg, dict(init), step_fn, batch_fn)
    # restart: resumes from step 10 checkpoint automatically
    cfg2 = LoopConfig(total_steps=20, checkpoint_dir=str(tmp_path),
                      checkpoint_every=5, log_every=0)
    out = run_loop(cfg2, dict(init), step_fn, batch_fn)
    assert out["step"] == ref["step"] == 20
    np.testing.assert_allclose(float(out["w"]), float(ref["w"]), rtol=1e-6)


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------
@given(st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_topk_error_feedback_conserves_signal(seed):
    rng = np.random.default_rng(seed)
    comp = TopKCompressor(fraction=0.25)
    g = {"a": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(5,)), jnp.float32)}
    err = comp.init_error(g)
    sparse, new_err = comp.compress(g, err)
    dec = comp.decompress(sparse, jax.tree.map(lambda x: x.shape, g))
    # decompressed + residual == original + old error (nothing lost)
    for k in g:
        np.testing.assert_allclose(
            np.asarray(dec[k]) + np.asarray(new_err[k]),
            np.asarray(g[k]) + np.asarray(err[k]), atol=1e-6)
    assert comp.wire_bytes(sparse) < sum(
        x.size * 4 for x in jax.tree.leaves(g))


def test_int8_quantization_error_bounded(rng):
    comp = Int8Compressor()
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    c = comp.compress(g, jax.random.key(0))
    dec = comp.decompress(c)
    scale = float(c["w"]["scale"])
    err = np.abs(np.asarray(dec["w"]) - np.asarray(g["w"]))
    assert err.max() <= scale * 1.01  # stochastic rounding: <= 1 LSB
    assert comp.wire_bytes(c) < g["w"].size * 4 // 3


# --------------------------------------------------------------------------
# straggler detection / mitigation
# --------------------------------------------------------------------------
def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(num_hosts=8, patience=3, warmup_steps=5)
    rng = np.random.default_rng(0)
    flagged_at = None
    for step in range(40):
        t = 1.0 + rng.normal(0, 0.01, 8)
        if step >= 10:
            t[3] = 3.0  # host 3 goes slow
        flags = det.observe(t)
        if flags.any():
            flagged_at = step
            assert flags[3] and flags.sum() == 1
            break
    assert flagged_at is not None and flagged_at < 25


def test_straggler_detector_quiet_on_uniform_noise():
    det = StragglerDetector(num_hosts=4, warmup_steps=5)
    rng = np.random.default_rng(1)
    assert not any(
        det.observe(1.0 + rng.normal(0, 0.02, 4)).any() for _ in range(50))


@given(st.integers(1, 64), st.integers(2, 16))
@settings(max_examples=50, deadline=None)
def test_rebalance_preserves_batch(batch, hosts):
    rng = np.random.default_rng(batch * hosts)
    flagged = rng.random(hosts) < 0.3
    sizes = rebalance_shards(batch, flagged)
    assert sizes.sum() == batch
    assert (sizes >= 0).all()
