"""Lifecycle timelines: reconstruction, auditing, and mesh lanes.

The timeline module replays a span stream through the closed lifecycle
state machine and either summarizes it (per-stream wait/service/park
splits) or convicts it (`LifecycleViolation`). Pinned here in two
layers:

  * unit: hand-built span streams exercise every transition rule —
    legal paths (spill/resume, cancel-while-parked, crash-recovery
    restore-over-running, rejected-at-the-door), every violation class
    (double admit, retire-without-admit, post-retirement activity,
    chunk_step naming a non-running stream, leaked streams), JSONL
    round-trips, and the request/stream domain split;
  * end-to-end: REAL traces recorded by the instrumented frontend /
    server / connector / session paths reconstruct with zero
    violations — the suites' scenarios (spill -> resume, migration,
    rebalance, rolling redeploy, crash recovery) double as lifecycle
    audits;
  * mesh lanes: shard_step spans fold into per-shard lanes and replay
    bit-exactly through a fresh StragglerDetector
    (`verify_shard_lanes`), and a tampered trace is caught.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import DecaySpec, SpikeEngine
from repro.core.session import AcceleratorSession
from repro.distributed.straggler import StragglerDetector
from repro.obs import MetricsRegistry, SpanTracer
from repro.obs.timeline import (LifecycleViolation, load_jsonl, mesh_lanes,
                                reconstruct, verify_shard_lanes)
from repro.serving.connector import (InMemoryCarryConnector, migrate_stream,
                                     rebalance_streams)
from repro.serving.frontend import AsyncSpikeFrontend
from repro.serving.snn import SpikeServer

THRESH = 1 << 16


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _engine(rng, *, n_in=10, n_phys=16, wmax=1 << 13):
    S = n_in + n_phys
    W = ((rng.random((S, n_phys)) < 0.4)
         * rng.integers(-wmax, wmax, (S, n_phys)))
    return SpikeEngine(jnp.asarray(W, jnp.int32), n_in,
                       decay=DecaySpec.shift(0.25), threshold_raw=THRESH,
                       reset_mode="subtract", backend="reference")


def _raster(rng, T, n_in, p=0.35):
    return (rng.random((T, n_in)) < p).astype(np.int32)


def _span(kind, uid, t, **attrs):
    """A hand-built event span dict (t0 == t1, tracer shape)."""
    return {"kind": kind, "uid": uid, "t0": t, "t1": t, "dur": 0.0,
            "attrs": attrs}


# --------------------------------------------------------------------------
# unit: the state machine on hand-built spans
# --------------------------------------------------------------------------

def test_happy_path_splits_wait_and_service():
    spans = [
        _span("queued", "a", 1.0),
        _span("admitted", "a", 3.0, slot=0),
        _span("retired", "a", 10.0, outcome="done", steps_done=7),
    ]
    rep = reconstruct(spans)
    st = rep.stream("a")
    assert st.state == "retired" and st.outcome == "done"
    assert st.wait_s == pytest.approx(2.0)
    assert st.service_s == pytest.approx(7.0)
    assert st.park_s == 0.0
    assert st.total_s == pytest.approx(9.0)
    assert st.n_admissions == 1
    assert rep.by_state() == {"retired": 1}


def test_spill_resume_path_accumulates_park_time():
    spans = [
        _span("queued", "a", 0.0),
        _span("admitted", "a", 1.0, slot=0),
        _span("parked", "a", 4.0, steps_done=2),       # spill at t=4
        _span("resumed", "a", 9.0, server_uid=7),      # back to queued
        _span("queued", "a", 9.0),
        _span("admitted", "a", 11.0, slot=0, resumed=True),
        _span("retired", "a", 15.0, outcome="done"),
    ]
    st = reconstruct(spans).stream("a")
    assert st.state == "retired"
    assert st.wait_s == pytest.approx(1.0 + 2.0)
    assert st.service_s == pytest.approx(3.0 + 4.0)
    assert st.park_s == pytest.approx(5.0)
    assert st.n_parks == 1 and st.n_admissions == 2


def test_parked_end_state_is_legal_but_running_is_a_leak():
    parked = [_span("queued", "a", 0.0), _span("admitted", "a", 1.0),
              _span("parked", "a", 2.0)]
    assert reconstruct(parked).stream("a").state == "parked"

    leaked = [_span("queued", "a", 0.0), _span("admitted", "a", 1.0)]
    with pytest.raises(LifecycleViolation, match="leaked"):
        reconstruct(leaked)
    # mid-run windows (the flight recorder's ring) tolerate in-flight
    rep = reconstruct(leaked, allow_inflight=True)
    assert rep.violations == []
    assert rep.stream("a").state == "running"


def test_double_admit_is_illegal():
    spans = [
        _span("queued", "a", 0.0),
        _span("admitted", "a", 1.0, slot=0),
        _span("admitted", "a", 2.0, slot=1),   # no resumed flag: illegal
        _span("retired", "a", 3.0, outcome="done"),
    ]
    with pytest.raises(LifecycleViolation, match="illegal 'admitted'"):
        reconstruct(spans)


def test_crash_recovery_readmit_over_running_is_legal():
    # restore over a live incarnation: admitted-while-running with
    # resumed=True is the documented crash-recovery special case
    spans = [
        _span("queued", "a", 0.0),
        _span("admitted", "a", 1.0, slot=0),
        _span("admitted", "a", 5.0, slot=2, resumed=True),
        _span("retired", "a", 9.0, outcome="done"),
    ]
    st = reconstruct(spans).stream("a")
    assert st.state == "retired" and st.n_admissions == 2
    assert st.service_s == pytest.approx(8.0)


def test_retire_without_admit_vs_rejected_at_the_door():
    with pytest.raises(LifecycleViolation, match="without ever being"):
        reconstruct([_span("retired", "a", 1.0, outcome="done")])
    # a queue-door refusal is the one legal retire-from-nothing
    st = reconstruct(
        [_span("retired", "a", 1.0, outcome="rejected")]).stream("a")
    assert st.state == "retired" and st.outcome == "rejected"


def test_activity_after_retirement_is_convicted():
    spans = [
        _span("queued", "a", 0.0),
        _span("admitted", "a", 1.0),
        _span("retired", "a", 2.0, outcome="done"),
        _span("queued", "a", 3.0),
    ]
    with pytest.raises(LifecycleViolation, match="after retirement"):
        reconstruct(spans)


def test_validate_false_collects_instead_of_raising():
    spans = [_span("retired", "a", 1.0, outcome="done"),
             _span("queued", "b", 0.0)]
    rep = reconstruct(spans, validate=False)
    assert len(rep.violations) == 2
    assert any("without ever being" in v for v in rep.violations)
    assert any("leaked" in v for v in rep.violations)


def test_chunk_step_audit_convicts_non_running_participants():
    chunk = {"kind": "chunk_step", "uid": None, "t0": 2.0, "t1": 3.0,
             "dur": 1.0, "attrs": {"steps": 4, "streams": 2,
                                   "uids": ["a", "ghost"]}}
    spans = [
        _span("queued", "a", 0.0),
        _span("admitted", "a", 1.0),
        chunk,
        _span("retired", "a", 5.0, outcome="done"),
    ]
    with pytest.raises(LifecycleViolation, match="ghost"):
        reconstruct(spans)
    rep = reconstruct(spans, validate=False)
    st = rep.stream("a")          # the running participant still counts
    assert st.n_chunks == 1 and st.chunk_s == pytest.approx(1.0)
    assert rep.n_chunk_steps == 1


def test_request_and_stream_domains_do_not_alias():
    # rid 0 (frontend, domain=request) and server uid 0 share a tracer;
    # they must reconstruct as distinct timelines
    spans = [
        _span("queued", 0, 0.0, domain="request"),
        _span("queued", 0, 0.0),
        _span("admitted", 0, 1.0, domain="request"),
        _span("admitted", 0, 1.0),
        _span("retired", 0, 2.0, outcome="done", domain="request"),
        _span("retired", 0, 5.0, outcome="done"),
    ]
    rep = reconstruct(spans)
    assert len(rep.streams) == 2
    assert rep.stream(0, domain="request").total_s == pytest.approx(2.0)
    assert rep.stream(0).total_s == pytest.approx(5.0)


def test_jsonl_round_trip(tmp_path, rng):
    # a real recorded trace survives the disk detour byte-meaningfully:
    # export_jsonl -> load_jsonl/reconstruct(path) agree with in-memory
    e = _engine(rng)
    tracer = SpanTracer()
    server = SpikeServer(e, n_slots=2, chunk_steps=3, tracer=tracer)
    uid = server.attach()
    server.feed({uid: _raster(rng, 5, e.n_inputs)})
    server.detach(uid, reason="done")

    path = tmp_path / "trace.jsonl"
    tracer.export_jsonl(path)
    assert load_jsonl(path) == tracer.to_dicts()
    mem = reconstruct(tracer)
    disk = reconstruct(str(path))
    assert disk.to_dict() == mem.to_dict()
    assert disk.stream(uid).state == "retired"


# --------------------------------------------------------------------------
# end-to-end: real traces from the serving scenarios audit clean
# --------------------------------------------------------------------------

def _spill_frontend(rng, tracer, *, n_slots=1, chunk_steps=2, capacity=4):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=n_slots, chunk_steps=chunk_steps,
                         tracer=tracer)
    clock = VirtualClock()
    conn = InMemoryCarryConnector()
    fe = AsyncSpikeFrontend(server, queue_capacity=capacity, clock=clock,
                            connector=conn, tracer=tracer)
    return engine, server, clock, conn, fe


def test_e2e_spill_resume_trace_audits_clean(rng):
    tracer = SpanTracer(clock=VirtualClock())
    engine, server, clock, conn, fe = _spill_frontend(rng, tracer)
    h = fe.submit(_raster(rng, 10, engine.n_inputs), deadline_ms=1_000)
    fe.pump()
    clock.t = 2.0
    fe.pump()
    assert h.state == "parked"
    assert fe.resume(h) is True
    fe.drain()
    assert h.state == "done"

    rep = reconstruct(tracer)      # raises on any lifecycle violation
    req = rep.stream(h.rid, domain="request")
    assert req.state == "retired" and req.outcome == "done"
    assert req.n_parks == 1 and req.n_admissions == 2
    # the server-side incarnations retire or park legally too
    assert all(st.state in ("retired", "parked")
               for st in rep.streams.values())


def test_e2e_cancel_while_parked_trace_audits_clean(rng):
    tracer = SpanTracer(clock=VirtualClock())
    engine, server, clock, conn, fe = _spill_frontend(rng, tracer)
    h = fe.submit(_raster(rng, 8, engine.n_inputs), deadline_ms=500)
    fe.pump()
    clock.t = 1.0
    fe.pump()
    assert h.state == "parked"
    assert h.cancel() is True

    rep = reconstruct(tracer)
    req = rep.stream(h.rid, domain="request")
    assert req.state == "retired" and req.outcome == "cancelled"
    assert req.n_parks == 1


def test_e2e_migration_and_rebalance_trace_audits_clean(rng):
    tracer = SpanTracer(clock=VirtualClock())
    e = _engine(rng)
    server = SpikeServer(e, n_slots=8, chunk_steps=4, tracer=tracer)
    uids = ["s0", "s1", "s2"]
    rasters = {u: _raster(rng, 16, e.n_inputs) for u in uids}
    for u in uids:
        server.attach(u)
    server.feed({u: r[:6] for u, r in rasters.items()})
    migrate_stream(server, "s2", slot=7)
    moves = rebalance_streams(server, [True, False, False, False],
                              slots_per_shard=2)
    assert moves, "the flagged shard should drain at least one stream"
    server.feed({u: r[6:] for u, r in rasters.items()})
    for u in uids:
        server.detach(u, reason="done")

    rep = reconstruct(tracer)
    assert rep.by_state() == {"retired": 3}
    migrations = {u: rep.stream(u).n_migrations for u in uids}
    assert migrations["s2"] >= 1
    assert sum(migrations.values()) == 1 + len(moves)


def test_e2e_session_redeploy_trace_audits_clean(rng):
    from repro.core.lif import LIFParams
    from repro.core.network import SNNetwork

    def net(n_in=6, n_neurons=12):
        W = ((rng.random((n_in + n_neurons, n_neurons)) < 0.4)
             * rng.normal(0.0, 0.5, (n_in + n_neurons, n_neurons)))
        return SNNetwork(
            n_inputs=n_in, n_neurons=n_neurons,
            weights=W.astype(np.float32),
            params=LIFParams(decay_rate=0.25, threshold=1.0,
                             reset_mode="zero"),
            output_slice=(n_neurons - 4, n_neurons))

    tracer = SpanTracer(clock=VirtualClock())
    sess = AcceleratorSession(tracer=tracer)
    sess.deploy("A", net())
    view = sess.serve("A", n_slots=2, chunk_steps=4)
    uid = view.attach("live")
    ext = (rng.random((12, 6)) < 0.4).astype(np.int32)
    view.feed(uid, ext[:5])
    sess.deploy("B", net(n_in=5, n_neurons=10))   # rolling redeploy
    view2 = sess.serve("A", n_slots=2, chunk_steps=4)
    view2.feed(uid, ext[5:])
    view2.detach(uid, reason="done")

    rep = reconstruct(tracer)
    live = rep.stream("live")
    assert live.state == "retired"
    assert live.n_redeploys == 1
    assert live.n_admissions == 2       # re-admitted after the redeploy
    assert live.park_s >= 0.0


def test_e2e_crash_recovery_trace_audits_clean(rng, tmp_path):
    from repro.serving.connector import FileCarryConnector

    tracer = SpanTracer(clock=VirtualClock())
    e = _engine(rng)
    root = str(tmp_path / "wal")
    server = SpikeServer(e, n_slots=3, chunk_steps=5, tracer=tracer)
    for u in ("x", "y"):
        server.attach(u)
    ext = {u: _raster(rng, 15, e.n_inputs) for u in ("x", "y")}
    server.feed({u: r[:8] for u, r in ext.items()})
    server.checkpoint_streams(FileCarryConnector(root))
    del server                           # the crash

    recovered = SpikeServer(e, n_slots=3, chunk_steps=5, tracer=tracer)
    assert sorted(recovered.restore_streams(FileCarryConnector(root)),
                  key=repr) == ["x", "y"]
    recovered.feed({u: r[8:] for u, r in ext.items()})
    for u in ("x", "y"):
        recovered.detach(u, reason="done")

    # ONE tracer saw both incarnations: the checkpoint parked nothing
    # (non-destructive), so the restore is the documented
    # admitted-over-running crash-recovery case — still a legal trace
    rep = reconstruct(tracer)
    for u in ("x", "y"):
        st = rep.stream(u)
        assert st.state == "retired" and st.n_admissions == 2


# --------------------------------------------------------------------------
# mesh lanes
# --------------------------------------------------------------------------

def _recorded_shard_trace(n=8, n_shards=2, straggle_from=4):
    """Drive a live detector through the registry-transported path the
    way serve_snn does, recording shard_step spans."""
    from repro.distributed.straggler import observe_from_registry

    registry = MetricsRegistry()
    tracer = SpanTracer(clock=VirtualClock())
    det = StragglerDetector(num_hosts=n_shards, warmup_steps=2,
                            patience=2)
    fam = registry.gauge("snn_shard_step_seconds")
    for i in range(n):
        times = [0.1] * n_shards
        if i >= straggle_from:
            times[-1] = 10.0         # shard 1 turns straggler
        for s, t in enumerate(times):
            fam.labels(shard=s).set(t)
        observe_from_registry(det, registry, tracer=tracer)
    return tracer


def test_mesh_lanes_fold_per_shard_series():
    tracer = _recorded_shard_trace()
    lanes = mesh_lanes(tracer)
    assert lanes["n_dispatches"] == 8 and lanes["n_shards"] == 2
    lane0, lane1 = lanes["lanes"]
    assert len(lane0["times"]) == 8
    assert lane0["flagged"] == 0
    assert lane1["flagged"] > 0          # the straggler shard
    assert max(lane1["times"]) == pytest.approx(10.0)
    # empty traces fold to an empty breakdown, not an error
    assert mesh_lanes([])["n_dispatches"] == 0


def test_verify_shard_lanes_agrees_with_live_flags():
    tracer = _recorded_shard_trace()
    fresh = StragglerDetector(num_hosts=2, warmup_steps=2, patience=2)
    assert verify_shard_lanes(tracer, fresh) == 8


def test_verify_shard_lanes_catches_tampering():
    tracer = _recorded_shard_trace()
    dicts = tracer.to_dicts()
    shard_steps = [d for d in dicts if d["kind"] == "shard_step"]
    shard_steps[-1]["attrs"]["flags"] = [1, 0]   # forge the flags
    fresh = StragglerDetector(num_hosts=2, warmup_steps=2, patience=2)
    with pytest.raises(LifecycleViolation, match="disagree"):
        verify_shard_lanes(dicts, fresh)
