"""Carry-snapshot wire-format + connector-store contracts.

The snapshot blob is the WIRE FORMAT live migration rides on — redeploy
drains, frontend spills, shard rebalances, and crash recovery all move
carries through it — so the claims pinned here are:

  * serialize -> deserialize is the identity for ANY array table (ragged
    slot shapes, every serializable dtype, empty/huge metadata) —
    hypothesis property + deterministic companions, mirroring the
    ``test_bitpack.py`` pattern;
  * every corruption is REJECTED loudly: flipped bytes (CRC), truncation,
    bad magic, unknown version, malformed headers, trailing garbage;
  * restore-side validation names the first incompatible slot-params
    field, and rejects wrong carry dtypes/shapes — a snapshot can never
    silently restore into an engine it did not come from;
  * both connector stores (memory, file) give the same insert / select /
    evict semantics over ``(stream_id, slot_params)`` keys, the file
    store round-trips through real files atomically, and
    ``stream_ids()`` enumerates deterministically (recovery order).
"""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.engine import DecaySpec, SpikeEngine
from repro.serving.connector import (SNAPSHOT_MAGIC, SNAPSHOT_VERSION,
                                     CarrySnapshot, FileCarryConnector,
                                     InMemoryCarryConnector, slot_params_of)

THRESH = 1 << 16

_PARAMS = {
    "n_phys": 16, "decay_kind": "shift", "decay_rate": 0.25,
    "decay_raw": 0, "threshold_raw": THRESH, "reset_mode": "subtract",
}


def _snap(rng, n_phys=16, stream_id="s", meta=None):
    return CarrySnapshot(
        stream_id=stream_id,
        slot_params=dict(_PARAMS, n_phys=n_phys),
        arrays={
            "v": rng.integers(-(1 << 20), 1 << 20, n_phys).astype(np.int32),
            "spikes": rng.integers(0, 2, n_phys).astype(np.int32),
        },
        meta=meta if meta is not None else {"steps": 7, "spike_count": 3},
    )


# --------------------------------------------------------------------------
# round trip: property test + deterministic companions
# --------------------------------------------------------------------------

_DTYPES = ["int8", "uint8", "int16", "uint16", "int32", "uint32",
           "int64", "uint64", "float32", "float64", "bool"]


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n_arrays=st.integers(0, 4),
       dims=st.lists(st.integers(0, 7), min_size=0, max_size=3),
       dtype=st.sampled_from(_DTYPES),
       steps=st.integers(0, 2**40),
       seed=st.integers(0, 2**16))
@pytest.mark.slow
def test_snapshot_round_trip_property(n_arrays, dims, dtype, steps, seed):
    """to_bytes -> from_bytes is the identity for ANY array table: ragged
    shapes (zero-size dims included), every serializable dtype, and
    arbitrary counter metadata."""
    rng = np.random.default_rng(seed)
    shape = tuple(dims)
    arrays = {}
    for i in range(n_arrays):
        if dtype == "bool":
            arr = rng.random(shape) < 0.5
        elif dtype.startswith("float"):
            arr = rng.normal(size=shape).astype(dtype)
        else:
            info = np.iinfo(dtype)
            arr = rng.integers(info.min, info.max, shape,
                               dtype=np.int64 if info.min < 0
                               else np.uint64).astype(dtype)
        arrays[f"a{i}"] = arr
    snap = CarrySnapshot(stream_id=seed, slot_params=dict(_PARAMS),
                         arrays=arrays, meta={"steps": steps})
    got = CarrySnapshot.from_bytes(snap.to_bytes())
    assert got.version == SNAPSHOT_VERSION
    assert got.slot_params == snap.slot_params
    assert got.meta == {"steps": steps}
    assert set(got.arrays) == set(arrays)
    for name, arr in arrays.items():
        assert got.arrays[name].dtype == arr.dtype
        assert got.arrays[name].shape == arr.shape
        np.testing.assert_array_equal(got.arrays[name], arr)


def test_snapshot_round_trip_deterministic(rng):
    """The same identity on fixed corner cases (always runs)."""
    cases = [
        _snap(rng),                                    # the real carry shape
        _snap(rng, n_phys=1),                          # single neuron
        _snap(rng, stream_id=("tup", 3), meta={}),     # tuple id, empty meta
        CarrySnapshot(stream_id=0, slot_params=dict(_PARAMS),
                      arrays={}, meta={"steps": 0}),   # no arrays at all
        CarrySnapshot(stream_id="z", slot_params=dict(_PARAMS),
                      arrays={"v": np.zeros((0,), np.int32)},
                      meta={}),                        # zero-length array
    ]
    for snap in cases:
        got = CarrySnapshot.from_bytes(snap.to_bytes())
        assert got.slot_params == snap.slot_params
        assert got.meta == snap.meta
        for name, arr in snap.arrays.items():
            assert got.arrays[name].dtype == arr.dtype
            np.testing.assert_array_equal(got.arrays[name], arr)


def test_snapshot_blob_is_deterministic(rng):
    """Same snapshot -> same bytes (sorted header keys, raw payload):
    checkpointing twice cannot dirty a file-backed store."""
    snap = _snap(rng)
    assert snap.to_bytes() == snap.to_bytes()


# --------------------------------------------------------------------------
# corruption: every damaged blob is rejected loudly
# --------------------------------------------------------------------------

@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(pos=st.integers(0, 10_000), bit=st.integers(0, 7),
       seed=st.integers(0, 2**16))
@pytest.mark.slow
def test_any_flipped_bit_is_rejected_property(pos, bit, seed):
    """Flipping ANY single bit of a blob makes from_bytes raise — magic,
    header, payload, and CRC bytes alike (CRC covers everything)."""
    rng = np.random.default_rng(seed)
    blob = bytearray(_snap(rng).to_bytes())
    blob[pos % len(blob)] ^= 1 << bit
    with pytest.raises(ValueError):
        CarrySnapshot.from_bytes(bytes(blob))


def test_corrupted_blobs_rejected_deterministic(rng):
    blob = _snap(rng).to_bytes()
    cases = [
        b"",                                   # empty
        blob[:8],                              # shorter than any header
        blob[:-5],                             # truncated payload
        blob + b"\x00",                        # trailing garbage
        b"NOTME" + blob[5:],                   # bad magic
    ]
    for bad in cases:
        with pytest.raises(ValueError):
            CarrySnapshot.from_bytes(bad)
    # flipped payload byte: CRC catches it
    flipped = bytearray(blob)
    flipped[len(blob) // 2] ^= 0xFF
    with pytest.raises(ValueError, match="CRC"):
        CarrySnapshot.from_bytes(bytes(flipped))


def test_unknown_version_rejected(rng):
    """A future-format blob is refused, not mis-parsed. The version field
    sits right after the magic; patch it and re-seal the CRC so ONLY the
    version is wrong."""
    import struct
    import zlib

    blob = _snap(rng).to_bytes()
    body = bytearray(blob[:-4])
    struct.pack_into("<H", body, len(SNAPSHOT_MAGIC), SNAPSHOT_VERSION + 1)
    resealed = bytes(body) + struct.pack(
        "<I", zlib.crc32(bytes(body)) & 0xFFFFFFFF)
    with pytest.raises(ValueError, match="version"):
        CarrySnapshot.from_bytes(resealed)


def test_unserializable_dtype_refused():
    snap = CarrySnapshot(
        stream_id="c", slot_params=dict(_PARAMS),
        arrays={"v": np.zeros(4, np.complex128)})
    with pytest.raises(ValueError, match="dtype"):
        snap.to_bytes()


# --------------------------------------------------------------------------
# restore-side validation: slot params + carry dtype/shape
# --------------------------------------------------------------------------

def test_slot_params_of_matches_engine(rng):
    W = np.asarray(rng.integers(-100, 100, (26, 16)), np.int32)
    engine = SpikeEngine(W, 10, decay=DecaySpec.shift(0.25),
                         threshold_raw=THRESH, reset_mode="subtract")
    assert slot_params_of(engine) == _PARAMS


def test_slot_params_exclude_hosting_choices(rng):
    """backend / gate / fuse_steps are re-hostings with byte-identical
    outputs, so they must NOT fragment the compatibility key."""
    W = np.asarray(rng.integers(-100, 100, (26, 16)), np.int32)
    base = SpikeEngine(W, 10, decay=DecaySpec.shift(0.25),
                       threshold_raw=THRESH, reset_mode="subtract")
    for other in (base.with_gate("per-example"), base.with_fuse_steps(4),
                  SpikeEngine(W, 10, decay=DecaySpec.shift(0.25),
                              threshold_raw=THRESH, reset_mode="subtract",
                              backend="pallas")):
        assert slot_params_of(other) == slot_params_of(base)


def test_check_compatible_names_mismatched_field(rng):
    snap = _snap(rng)
    for field, value in [("n_phys", 32), ("decay_kind", "mul"),
                         ("decay_rate", 0.5), ("threshold_raw", 1 << 10),
                         ("reset_mode", "zero")]:
        with pytest.raises(ValueError, match=field):
            snap.check_compatible(dict(_PARAMS, **{field: value}))
    snap.check_compatible(dict(_PARAMS))  # identical params pass


def test_check_compatible_rejects_bad_carry_arrays(rng):
    wrong_dtype = _snap(rng)
    wrong_dtype.arrays["v"] = wrong_dtype.arrays["v"].astype(np.int64)
    with pytest.raises(ValueError, match="dtype"):
        wrong_dtype.check_compatible(dict(_PARAMS))

    wrong_shape = _snap(rng)
    wrong_shape.arrays["spikes"] = np.zeros((2, 16), np.int32)
    with pytest.raises(ValueError, match="shape"):
        wrong_shape.check_compatible(dict(_PARAMS))

    missing = _snap(rng)
    del missing.arrays["spikes"]
    with pytest.raises(ValueError, match="missing"):
        missing.check_compatible(dict(_PARAMS))


# --------------------------------------------------------------------------
# connector stores: one contract, two implementations
# --------------------------------------------------------------------------

def _connectors(tmp_path):
    return [InMemoryCarryConnector(),
            FileCarryConnector(str(tmp_path / "carries"))]


def test_connector_crud_contract(rng, tmp_path):
    for conn in _connectors(tmp_path):
        snap = _snap(rng, stream_id="a")
        assert conn.select("a") is None
        assert not conn.evict("a")
        assert len(conn) == 0

        conn.insert("a", snap)
        assert "a" in conn and len(conn) == 1
        got = conn.select("a")
        np.testing.assert_array_equal(got.arrays["v"], snap.arrays["v"])
        assert got.meta == snap.meta

        # select does NOT consume; overwrite keeps the latest
        snap2 = _snap(rng, stream_id="a", meta={"steps": 99})
        conn.insert("a", snap2)
        assert len(conn) == 1
        assert conn.select("a").meta["steps"] == 99

        assert conn.evict("a") and len(conn) == 0
        assert conn.select("a") is None


def test_connector_select_checks_slot_params(rng, tmp_path):
    for conn in _connectors(tmp_path):
        conn.insert("a", _snap(rng, stream_id="a"))
        assert conn.select("a", dict(_PARAMS)) is not None
        with pytest.raises(ValueError, match="n_phys"):
            conn.select("a", dict(_PARAMS, n_phys=999))
        assert "a" in conn  # the failed select did not consume it


def test_connector_stream_ids_sorted(rng, tmp_path):
    for conn in _connectors(tmp_path):
        for sid in ["z", "a", "m"]:
            conn.insert(sid, _snap(rng, stream_id=sid))
        assert conn.stream_ids() == ["a", "m", "z"]


def test_file_connector_persists_across_instances(rng, tmp_path):
    """The point of the file store: a NEW connector over the same root
    sees the old one's snapshots (crash recovery's first step)."""
    root = str(tmp_path / "carries")
    a = FileCarryConnector(root)
    a.insert(7, _snap(rng, stream_id=7))
    a.insert("s", _snap(rng, stream_id="s"))

    b = FileCarryConnector(root)
    assert sorted(b.stream_ids(), key=repr) == sorted([7, "s"], key=repr)
    np.testing.assert_array_equal(
        b.select(7).arrays["v"], a.select(7).arrays["v"])


def test_file_connector_atomic_write_leaves_no_tmp(rng, tmp_path):
    import os

    root = str(tmp_path / "carries")
    conn = FileCarryConnector(root)
    for i in range(5):
        conn.insert(i, _snap(rng, stream_id=i))
    files = os.listdir(root)
    assert len(files) == 5
    assert all(f.endswith(".carry") for f in files)


def test_file_connector_corrupt_file_fails_loudly(rng, tmp_path):
    import os

    root = str(tmp_path / "carries")
    conn = FileCarryConnector(root)
    conn.insert("a", _snap(rng, stream_id="a"))
    fname = os.listdir(root)[0]
    path = os.path.join(root, fname)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    with pytest.raises(ValueError, match="corrupt"):
        conn.select("a")
