"""LIF neuron semantics: float reference, fixed-point HW model, surrogate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import fixedpoint as fxp
from repro.core.lif import (
    LIFParams, lif_init, lif_step_fixed, lif_step_float, lif_step_train,
    surrogate_spike,
)


@pytest.mark.parametrize("reset_mode", ["zero", "subtract", "hold"])
def test_float_reset_semantics(reset_mode):
    p = LIFParams(decay_rate=0.25, threshold=1.0, reset_mode=reset_mode)
    state = {"v": jnp.asarray([[0.8, 0.0, 2.0]])}
    syn = jnp.asarray([[0.5, 0.1, 0.0]])
    new, spikes = lif_step_float(state, syn, p)
    # v_decayed = v*0.75 -> [0.6, 0, 1.5]; v_new = [1.1, 0.1, 1.5]
    np.testing.assert_array_equal(np.asarray(spikes), [[1.0, 0.0, 1.0]])
    v = np.asarray(new["v"])[0]
    if reset_mode == "zero":
        np.testing.assert_allclose(v, [0.0, 0.1, 0.0], atol=1e-6)
    elif reset_mode == "subtract":
        np.testing.assert_allclose(v, [0.1, 0.1, 0.5], atol=1e-6)
    else:
        np.testing.assert_allclose(v, [1.1, 0.1, 1.5], atol=1e-6)


@given(
    st.lists(st.integers(-2**24, 2**24), min_size=1, max_size=8),
    st.lists(st.integers(-2**20, 2**20), min_size=1, max_size=8),
    st.sampled_from(fxp.SHIFT_DECAY_RATES),
    st.sampled_from(["zero", "subtract", "hold"]),
)
@settings(max_examples=100, deadline=None)
def test_fixed_step_matches_python_ints(vs, syns, rate, reset_mode):
    """The HW step against an independent big-int oracle."""
    n = min(len(vs), len(syns))
    vs, syns = vs[:n], syns[:n]
    p = LIFParams(decay_rate=rate, threshold=1.0, reset_mode=reset_mode)
    state = {"v": jnp.asarray(vs, jnp.int32)}
    new, spikes = lif_step_fixed(state, jnp.asarray(syns, jnp.int32), p)
    thr = p.threshold_raw
    for i, (v, s) in enumerate(zip(vs, syns)):
        k = {0.125: 3, 0.25: 2, 0.5: 1}.get(rate)
        vd = (v >> 2) if rate == 0.75 else v - (v >> k)
        vn = vd + s
        vn = ((vn + 2**31) % 2**32) - 2**31  # int32 wrap
        spk = 1 if vn >= thr else 0
        assert int(spikes[i]) == spk
        if reset_mode == "zero":
            want = 0 if spk else vn
        elif reset_mode == "subtract":
            want = vn - spk * thr
        else:
            want = vn
        want = ((want + 2**31) % 2**32) - 2**31
        assert int(new["v"][i]) == want


def test_float_vs_fixed_agree_on_representable_trace(rng):
    """Identical spike trains through both arithmetic paths: when weights
    are exactly representable and decay=0.5 (exact in both paths for even
    potentials), traces agree closely — the paper's Table IV premise."""
    p = LIFParams(decay_rate=0.5, threshold=1.0, reset_mode="zero")
    T, B, N = 30, 4, 16
    syn_f = (rng.integers(-8, 8, (T, B, N)) / 16.0).astype(np.float32)
    syn_raw = fxp.to_fixed(syn_f)
    sf = {"v": jnp.zeros((B, N))}
    sx = {"v": jnp.zeros((B, N), jnp.int32)}
    agree = 0
    for t in range(T):
        sf, spk_f = lif_step_float(sf, jnp.asarray(syn_f[t]), p)
        sx, spk_x = lif_step_fixed(sx, syn_raw[t], p)
        agree += int((np.asarray(spk_f) == np.asarray(spk_x)).sum())
    assert agree / (T * B * N) > 0.98


def test_surrogate_forward_is_heaviside():
    x = jnp.asarray([-1.0, -1e-6, 0.0, 1e-6, 1.0])
    np.testing.assert_array_equal(
        np.asarray(surrogate_spike(x)), [0.0, 0.0, 1.0, 1.0, 1.0])


def test_surrogate_gradient_shape_and_decay():
    g = jax.grad(lambda v: jnp.sum(surrogate_spike(v)))
    near = float(g(jnp.asarray([0.0]))[0])
    far = float(g(jnp.asarray([2.0]))[0])
    assert near == pytest.approx(1.0)          # 1/(1+25*0)^2
    assert 0.0 < far < 0.01                     # decays away from threshold
    # and the straight-through reset keeps training step differentiable
    p = LIFParams(decay_rate=0.25)
    def loss(w):
        state = {"v": jnp.zeros((1, 3))}
        _, s = lif_step_train(state, w, p)
        return jnp.sum(s * jnp.arange(3.0))
    gw = jax.grad(loss)(jnp.asarray([[0.9, 1.1, 0.5]]))
    assert np.all(np.isfinite(np.asarray(gw)))
    assert float(jnp.abs(gw).sum()) > 0


def test_lif_init_dtypes():
    assert lif_init((2, 3))["v"].dtype == jnp.float32
    assert lif_init((2, 3), fixed=True)["v"].dtype == jnp.int32
