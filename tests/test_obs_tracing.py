"""SpanTracer contracts: typed kinds, events vs durations, JSONL export.

The tracer is the lifecycle half of the telemetry layer: every span kind
an instrumentation site may record is catalogued in ``SPAN_KINDS`` (a
typo'd kind raises instead of minting an undocumented type), events are
instantaneous (t1 == t0), duration spans measure the injected clock, and
the JSONL export round-trips span-per-line with stable keys.
"""

import io
import json

import pytest

from repro.obs.tracing import SPAN_KINDS, Span, SpanTracer


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def test_unknown_kind_rejected():
    tr = SpanTracer()
    with pytest.raises(ValueError):
        tr.event("chunk_stepp")
    with pytest.raises(ValueError):
        with tr.span("not-a-kind"):
            pass
    assert tr.spans == []


def test_every_catalogued_kind_records():
    tr = SpanTracer(clock=FakeClock())
    for kind in SPAN_KINDS:
        tr.event(kind, uid=1)
    assert [s.kind for s in tr.spans] == list(SPAN_KINDS)


def test_event_is_instantaneous_and_carries_attrs():
    clk = FakeClock(5.0)
    tr = SpanTracer(clock=clk)
    s = tr.event("admitted", uid=7, slot=3)
    assert (s.t0, s.t1, s.duration) == (5.0, 5.0, 0.0)
    assert s.attrs == {"slot": 3}


def test_duration_span_measures_clock_and_keeps_body_attrs():
    clk = FakeClock()
    tr = SpanTracer(clock=clk)
    with tr.span("chunk_step", uid="stream-0") as attrs:
        clk.t += 0.125
        attrs["steps"] = 8
    (s,) = tr.spans
    assert s.duration == pytest.approx(0.125)
    assert s.attrs == {"steps": 8}
    # recorded even when the body raises (the finally path)
    with pytest.raises(RuntimeError):
        with tr.span("snapshot", uid="stream-0"):
            clk.t += 1.0
            raise RuntimeError("boom")
    assert len(tr.spans) == 2 and tr.spans[1].duration == pytest.approx(1.0)


def test_spans_for_filters_by_uid():
    tr = SpanTracer(clock=FakeClock())
    tr.event("queued", uid=1)
    tr.event("queued", uid=2)
    tr.event("retired", uid=1, outcome="done")
    assert [s.kind for s in tr.spans_for(1)] == ["queued", "retired"]


def test_jsonl_export_roundtrip(tmp_path):
    clk = FakeClock()
    tr = SpanTracer(clock=clk)
    tr.event("queued", uid=0, steps=16)
    with tr.span("chunk_step", uid=0):
        clk.t += 0.5
    path = tmp_path / "trace.jsonl"
    assert tr.export_jsonl(path) == 2
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert rows == tr.to_dicts()
    assert set(rows[0]) == {"kind", "uid", "t0", "t1", "dur", "attrs"}
    assert rows[1]["dur"] == pytest.approx(0.5)
    # file-object export too
    buf = io.StringIO()
    assert tr.export_jsonl(buf) == 2
    assert buf.getvalue().count("\n") == 2


def test_sink_streams_spans_through():
    buf = io.StringIO()
    tr = SpanTracer(clock=FakeClock(), sink=buf)
    tr.event("parked", uid=4)
    line = buf.getvalue().strip()
    assert json.loads(line)["kind"] == "parked"


def test_span_dataclass_duration():
    s = Span("deploy", None, 1.0, 3.5, {})
    assert s.duration == 2.5
    assert s.to_dict()["dur"] == 2.5
