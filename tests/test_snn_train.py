"""End-to-end SNN training + the paper's HW-vs-SW evaluation methodology."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coding
from repro.core.lif import LIFParams
from repro.data import mnist
from repro.snn.model import SNNModelConfig, forward, init_params, to_snnetwork
from repro.snn.train import TrainConfig, evaluate_dual, make_train_step, train


def _cfg(hidden=32, T=10, steps=140):
    return TrainConfig(
        model=SNNModelConfig(layer_sizes=(784, hidden, 10),
                             params=LIFParams(decay_rate=0.1)),
        num_steps_time=T, lr=3e-3, batch_size=64, train_steps=steps)


@pytest.fixture(scope="module")
def trained():
    cfg = _cfg()
    data = mnist.batches("train", cfg.batch_size, cfg.train_steps, seed=0)
    params, opt_state, metrics = train(cfg, data, log_every=0)
    return cfg, params, metrics


def _eval_acc(params, cfg, x, y, seed=11):
    """Software-path accuracy on a fixed Poisson-encoded eval set."""
    spikes = coding.poisson_encode(jax.random.key(seed), jnp.asarray(x),
                                   cfg.num_steps_time)
    out = forward(params, spikes, cfg.model)
    pred = np.asarray(jnp.argmax(out["output_counts"], -1))
    return float((pred == np.asarray(y)).mean())


def test_training_learns(trained):
    """Seed-robust learning check: rather than pinning an absolute
    final-batch accuracy (brittle — a jax PRNG-stream change reshuffles
    init/encodings and shifts it by several points), require the trained
    model to (a) sit far above 10% chance on a held-out set and (b) beat
    an untrained init by a wide margin on the SAME eval."""
    cfg, params, metrics = trained
    x, y = mnist.load_or_generate("test", 256, seed=2)
    acc = _eval_acc(params, cfg, x, y)
    base = _eval_acc(init_params(jax.random.key(123), cfg.model), cfg, x, y)
    assert acc > 0.35           # >3.5x chance, with slack for PRNG drift
    assert acc >= base + 0.20   # training moved the needle, whatever seed
    assert float(metrics["acc"]) > 0.35  # the train metric agrees


def test_weights_stay_deployable(trained):
    cfg, params, _ = trained
    clip = cfg.model.weight_clip
    for w in params:
        assert float(jax.numpy.abs(w).max()) <= clip + 1e-6


def test_evaluate_dual_matches_paper_contract(trained):
    """HW (bit-exact Cerebra-H) vs SW (float) accuracy on the same spike
    trains: deviation is small and agreement high — the Table IV analogue.

    The CONTRACT is the relative part (quantization + snapped decay cost
    little accuracy and the two paths agree on most samples); absolute
    floors are anchored to chance (0.1) with slack so a PRNG-stream change
    across jax versions cannot flip the test."""
    cfg, params, _ = trained
    x, y = mnist.load_or_generate("test", 256, seed=1)
    res = evaluate_dual(params, cfg.model, x, y,
                        num_steps_time=cfg.num_steps_time)
    assert res["software_acc"] > 0.3   # 3x chance
    assert res["hardware_acc"] > 0.25  # 2.5x chance
    assert abs(res["deviation_pct"]) < 15.0
    assert res["agreement"] > 0.65


def test_train_resume_exact_trajectory():
    """fold_in(key, step) + stateless data => a restarted run reproduces the
    exact parameter trajectory of the uninterrupted one."""
    cfg = _cfg(hidden=16, T=5, steps=12)
    full_data = mnist.batches("train", cfg.batch_size, cfg.train_steps,
                              seed=3)
    p_full, _, _ = train(cfg, full_data, log_every=0)

    first = mnist.batches("train", cfg.batch_size, 6, seed=3)
    p_half, opt_half, _ = train(cfg, first, log_every=0)
    rest = mnist.batches("train", cfg.batch_size, cfg.train_steps, seed=3,
                         start_step=6)
    p_resumed, _, _ = train(cfg, rest, params=p_half, opt_state=opt_half,
                            start_step=6, log_every=0)
    for a, b in zip(p_full, p_resumed):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_forward_output_shapes():
    cfg = SNNModelConfig(layer_sizes=(12, 8, 4))
    params = init_params(jax.random.key(0), cfg)
    spikes = jax.numpy.zeros((6, 3, 12))
    out = forward(params, spikes, cfg)
    assert out["output_counts"].shape == (3, 4)
    assert out["output_spikes"].shape == (6, 3, 4)


def test_to_snnetwork_roundtrip():
    cfg = SNNModelConfig(layer_sizes=(5, 4, 2))
    params = init_params(jax.random.key(1), cfg)
    net = to_snnetwork(params, cfg)
    assert net.n_inputs == 5 and net.n_neurons == 6
    assert net.output_slice == (4, 6)
    np.testing.assert_allclose(
        net.weights[:5, :4],
        np.clip(np.asarray(params[0]), -cfg.weight_clip, cfg.weight_clip))
