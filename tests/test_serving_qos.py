"""Multi-tenant QoS admission contracts (PR 10).

The policy layer reorders WHEN requests run, never what they compute:

  * Determinism — admission order, slot assignment, AND eviction victims
    are a pure function of the submit/cancel/pump op sequence for every
    priority / weight / quota / rate-limit mix (hypothesis property with
    deterministic companions always on).
  * Exactness — QoS-served rasters are byte-identical to direct
    synchronous feeds of the same requests (full backend x gate sweep
    under ``slow``), and a preempt-evicted-then-resumed stream is
    byte-identical to a never-interrupted run (the connector carries the
    carry; nothing is dropped).
  * Policy semantics — strict priority strata, DRR weight shares inside
    a stratum, slot quotas never exceeded, token buckets spacing
    admissions on the injectable clock, drop-oldest shedding the lowest
    priority first, preemption requiring a connector.
  * Lifecycle audit — adversarial mixes (burst tenant, quota
    exhaustion, SLO-shed) reconstruct violation-free through
    ``obs/timeline.reconstruct``, with park/eviction counts matching
    the per-class outcome counters exactly.
  * Thread safety — N submitter threads against the background pump
    driver lose no handles, duplicate no rids, and leave the queue-depth
    gauge consistent.
"""

import threading

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import BACKENDS, GATES, DecaySpec, SpikeEngine
from repro.core.session import AcceleratorSession
from repro.serving.connector import InMemoryCarryConnector
from repro.serving.frontend import (OUTCOME_KEYS, AsyncSpikeFrontend,
                                    FrontendConfig)
from repro.serving.qos import QoSClass, QoSPolicy, WeightedFairQueue
from repro.serving.snn import SpikeServer

from conftest import make_random_net

THRESH = 1 << 16


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _engine(rng, *, backend="reference", reset="subtract",
            gate="batch-tile", n_in=10, n_phys=16, wmax=1 << 13):
    S = n_in + n_phys
    W = ((rng.random((S, n_phys)) < 0.4)
         * rng.integers(-wmax, wmax, (S, n_phys)))
    return SpikeEngine(jnp.asarray(W, jnp.int32), n_in,
                       decay=DecaySpec.shift(0.25), threshold_raw=THRESH,
                       reset_mode=reset, backend=backend, gate=gate)


def _rasters(rng, lengths, n_in, p=0.35):
    return [(rng.random((T, n_in)) < p).astype(np.int32) for T in lengths]


# --------------------------------------------------------------------------
# determinism: the whole observable trace is a pure function of the ops
# --------------------------------------------------------------------------

def _policy_mix(seed: int) -> QoSPolicy:
    """A deterministic priority/weight/quota/rate mix derived from seed."""
    r = np.random.default_rng(seed)
    classes = {}
    for name in ("hi", "mid", "bg"):
        classes[name] = QoSClass(
            priority=int(r.integers(0, 3)),
            weight=int(r.integers(1, 5)),
            max_slots=(None if r.random() < 0.5
                       else int(r.integers(1, 3))),
            rate_per_s=(None if r.random() < 0.5
                        else float(r.integers(1, 4)) / 2.0),
            burst=int(r.integers(1, 3)),
        )
    return QoSPolicy(classes=classes,
                     quantum=int(r.integers(1, 9)),
                     preempt=bool(r.random() < 0.5))


def _run_qos_scenario(engine, *, seed, n_slots, chunk_steps, capacity,
                      policy, backpressure="reject"):
    """One full QoS frontend run; returns the observable trace: per-round
    (admitted rid -> slot) and parked-victim rids, plus every request's
    terminal state, outcome counts, and result bytes."""
    r = np.random.default_rng(seed)
    lengths = r.integers(1, 9, size=10)
    tenants = r.choice(["hi", "mid", "bg"], size=len(lengths))
    cancel_at = set(r.integers(0, len(lengths), size=2).tolist())
    rasters = _rasters(np.random.default_rng(7), lengths, engine.n_inputs)
    clock = VirtualClock()
    server = SpikeServer(engine, n_slots=n_slots, chunk_steps=chunk_steps)
    fe = AsyncSpikeFrontend(server, queue_capacity=capacity,
                            backpressure=backpressure, clock=clock,
                            qos=policy, connector=InMemoryCarryConnector())
    handles, trace = [], []
    for i, raster in enumerate(rasters):
        handles.append(fe.submit(raster, tenant=str(tenants[i])))
        if i in cancel_at:
            handles[-1].cancel()
    rid_of_uid = {}
    rounds = 0
    while not fe.idle and rounds < 400:
        fe.pump()
        clock.t += 1.0
        rounds += 1
        for h in handles:
            uid = h._req.uid
            if uid is not None and uid not in rid_of_uid:
                rid_of_uid[uid] = h.rid
        trace.append((
            sorted((rid_of_uid[u], s)
                   for u, s in server.scheduler.active.items()),
            sorted(h.rid for h in handles
                   if h._req.parked_key is not None),
        ))
    assert fe.idle, "scenario did not converge"
    states = [h.state for h in handles]
    bytes_out = [None if h.result() is None
                 else h.result()["spikes"].tobytes() for h in handles]
    return trace, states, dict(fe.counts), bytes_out


def test_qos_determinism_deterministic_companion(rng):
    engine = _engine(rng)
    for seed in (0, 3, 11):
        kw = dict(seed=seed, n_slots=2, chunk_steps=3, capacity=4,
                  policy=_policy_mix(seed))
        assert (_run_qos_scenario(engine, **kw)
                == _run_qos_scenario(engine, **kw))


def test_qos_determinism_drop_oldest_companion(rng):
    engine = _engine(rng)
    kw = dict(seed=5, n_slots=1, chunk_steps=2, capacity=2,
              policy=_policy_mix(5), backpressure="drop-oldest")
    assert (_run_qos_scenario(engine, **kw)
            == _run_qos_scenario(engine, **kw))


@hypothesis.given(
    seed=st.integers(0, 2**32 - 1),
    n_slots=st.integers(1, 3),
    chunk_steps=st.integers(1, 4),
    capacity=st.integers(2, 6),
    backpressure=st.sampled_from(("reject", "drop-oldest")),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_qos_determinism_property(seed, n_slots, chunk_steps, capacity,
                                  backpressure):
    """Admission order + slot assignment + eviction victims are a pure
    function of the op sequence across priority/quota/rate-limit mixes."""
    engine = _engine(np.random.default_rng(0))
    kw = dict(seed=seed, n_slots=n_slots, chunk_steps=chunk_steps,
              capacity=capacity, policy=_policy_mix(seed),
              backpressure=backpressure)
    assert (_run_qos_scenario(engine, **kw)
            == _run_qos_scenario(engine, **kw))


# --------------------------------------------------------------------------
# exactness: QoS reorders WHEN, never WHAT
# --------------------------------------------------------------------------

def _assert_qos_exact(engine):
    """Every request a QoS frontend completes is byte-identical to a
    direct synchronous feed of the same raster on a fresh slot."""
    policy = QoSPolicy(classes={"hi": QoSClass(priority=1, weight=2),
                                "bg": QoSClass(rate_per_s=1.0, burst=2)},
                       preempt=True)
    clock = VirtualClock()
    server = SpikeServer(engine, n_slots=2, chunk_steps=3)
    fe = AsyncSpikeFrontend(server, queue_capacity=8, clock=clock,
                            qos=policy, connector=InMemoryCarryConnector())
    rasters = _rasters(np.random.default_rng(5), (7, 4, 6, 5, 3),
                       engine.n_inputs)
    handles = [fe.submit(r, tenant=("bg" if i % 2 else "hi"))
               for i, r in enumerate(rasters)]
    rounds = 0
    while not fe.idle and rounds < 200:
        fe.pump()
        clock.t += 1.0
        rounds += 1
    for h, raster in zip(handles, rasters):
        assert h.state == "done"
        sync = SpikeServer(engine, n_slots=1,
                           chunk_steps=int(raster.shape[0]))
        uid = sync.attach()
        want = sync.feed({uid: raster})[uid]["spikes"]
        np.testing.assert_array_equal(h.result()["spikes"], want)


def test_qos_exactness_default_combo(rng):
    _assert_qos_exact(_engine(rng))


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("gate", GATES)
def test_qos_exactness_backend_gate_sweep(rng, backend, gate):
    _assert_qos_exact(_engine(rng, backend=backend, gate=gate))


def test_preempt_evict_resume_byte_identity(rng):
    """A background stream preempted mid-flight (carry parked through
    the connector) finishes byte-identical to a never-interrupted run."""
    engine = _engine(rng)
    policy = QoSPolicy(classes={"hi": QoSClass(priority=2),
                                "bg": QoSClass()}, preempt=True)
    clock = VirtualClock()
    server = SpikeServer(engine, n_slots=1, chunk_steps=4)
    fe = AsyncSpikeFrontend(server, queue_capacity=8, clock=clock,
                            qos=policy, connector=InMemoryCarryConnector())
    bg = _rasters(np.random.default_rng(7), (16,), engine.n_inputs)[0]
    hi = _rasters(np.random.default_rng(8), (8,), engine.n_inputs)[0]
    h_bg = fe.submit(bg, tenant="bg")
    fe.pump()                       # bg admitted, runs one quantum
    clock.t += 1.0
    h_hi = fe.submit(hi, tenant="hi")
    rounds = 0
    while not fe.idle and rounds < 50:
        fe.pump()
        clock.t += 1.0
        rounds += 1
    assert h_bg.state == "done" and h_hi.state == "done"
    assert fe.counts["evicted"] == 1
    assert fe.counts["parked"] == 1 and fe.counts["resumed"] == 1

    plain = SpikeServer(engine, n_slots=1, chunk_steps=4)
    fe2 = AsyncSpikeFrontend(plain, queue_capacity=8, clock=VirtualClock())
    h2 = fe2.submit(bg)
    fe2.drain()
    np.testing.assert_array_equal(h_bg.result()["spikes"],
                                  h2.result()["spikes"])


# --------------------------------------------------------------------------
# policy semantics
# --------------------------------------------------------------------------

def _admission_order(fe, server, handles, clock, max_rounds=200):
    order = []
    seen = set()
    rounds = 0
    while not fe.idle and rounds < max_rounds:
        fe.pump()
        clock.t += 1.0
        rounds += 1
        for h in handles:
            uid = h._req.uid
            if uid is not None and (h.rid, uid) not in seen:
                seen.add((h.rid, uid))
                order.append(h.rid)
    return order


def test_strict_priority_admits_high_first(rng):
    engine = _engine(rng)
    policy = QoSPolicy(classes={"hi": QoSClass(priority=5),
                                "bg": QoSClass(priority=0)})
    clock = VirtualClock()
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=8, clock=clock,
                            qos=policy)
    rasters = _rasters(np.random.default_rng(1), (4, 4, 4, 4),
                       engine.n_inputs)
    # bg submitted FIRST — priority must still admit both hi before it
    handles = [fe.submit(rasters[0], tenant="bg"),
               fe.submit(rasters[1], tenant="hi"),
               fe.submit(rasters[2], tenant="hi"),
               fe.submit(rasters[3], tenant="bg")]
    order = _admission_order(fe, server, handles, clock)
    assert order == [1, 2, 0, 3]


def test_wfq_weights_share_admissions(rng):
    """Same priority, weights 3:1, saturated single slot: the weighted
    class gets 3 of every 4 admissions while both have queued work."""
    engine = _engine(rng)
    policy = QoSPolicy(classes={"a": QoSClass(weight=3),
                                "b": QoSClass(weight=1)}, quantum=8)
    clock = VirtualClock()
    server = SpikeServer(engine, n_slots=1, chunk_steps=8)
    fe = AsyncSpikeFrontend(server, queue_capacity=32, clock=clock,
                            qos=policy)
    rasters = _rasters(np.random.default_rng(2), [8] * 16,
                       engine.n_inputs)
    handles = []
    for i in range(8):
        handles.append(fe.submit(rasters[2 * i], tenant="a"))
        handles.append(fe.submit(rasters[2 * i + 1], tenant="b"))
    order = _admission_order(fe, server, handles, clock)
    tenants = [handles[rid]._req.tenant for rid in order]
    # while both classes are backlogged (first 8 grants) the 3:1 weight
    # ratio shows up exactly
    assert tenants[:8].count("a") == 6
    assert tenants[:8].count("b") == 2


def test_quota_caps_concurrent_slots(rng):
    engine = _engine(rng)
    policy = QoSPolicy(classes={"hi": QoSClass(priority=1),
                                "bg": QoSClass(max_slots=1)})
    clock = VirtualClock()
    server = SpikeServer(engine, n_slots=3, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=16, clock=clock,
                            qos=policy)
    rasters = _rasters(np.random.default_rng(3), [6] * 8,
                       engine.n_inputs)
    handles = [fe.submit(r, tenant=("bg" if i < 5 else "hi"))
               for i, r in enumerate(rasters)]
    rounds = 0
    while not fe.idle and rounds < 100:
        fe.pump()
        clock.t += 1.0
        rounds += 1
        running = [h._req.tenant for h in handles
                   if h._req.state == "running"]
        assert running.count("bg") <= 1, "slot quota exceeded"
    assert all(h.state == "done" for h in handles)


def test_token_bucket_spaces_admissions(rng):
    """rate_per_s=0.5, burst=1 on the virtual clock: one admission every
    2 ticks even with free slots and queued work."""
    engine = _engine(rng)
    policy = QoSPolicy(classes={"rl": QoSClass(rate_per_s=0.5, burst=1)})
    clock = VirtualClock()
    server = SpikeServer(engine, n_slots=4, chunk_steps=4)
    fe = AsyncSpikeFrontend(server, queue_capacity=16, clock=clock,
                            qos=policy)
    rasters = _rasters(np.random.default_rng(4), [4] * 4,
                       engine.n_inputs)
    for r in rasters:
        fe.submit(r, tenant="rl")
    admit_at = []
    for _ in range(30):
        s = fe.pump()
        if s["admitted"]:
            admit_at.append((clock.t, s["admitted"]))
        clock.t += 1.0
        if fe.idle:
            break
    assert admit_at == [(0.0, 1), (2.0, 1), (4.0, 1), (6.0, 1)]


def test_drop_oldest_sheds_lowest_priority(rng):
    engine = _engine(rng)
    policy = QoSPolicy(classes={"hi": QoSClass(priority=1),
                                "bg": QoSClass(priority=0)})
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=3,
                            backpressure="drop-oldest",
                            clock=VirtualClock(), qos=policy)
    rasters = _rasters(np.random.default_rng(5), [4] * 4,
                       engine.n_inputs)
    h_bg0 = fe.submit(rasters[0], tenant="bg")
    h_hi = fe.submit(rasters[1], tenant="hi")
    h_bg1 = fe.submit(rasters[2], tenant="bg")
    h_new = fe.submit(rasters[3], tenant="hi")   # queue full -> shed
    # the victim is the OLDEST LOWEST-priority request — not the global
    # queue head the plain FIFO policy would have dropped
    assert h_bg0.state == "dropped"
    assert h_hi.state == "queued" and h_bg1.state == "queued"
    assert h_new.state == "queued"
    assert fe.counts["dropped"] == 1


def test_preempt_requires_connector(rng):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    with pytest.raises(ValueError, match="needs a connector"):
        AsyncSpikeFrontend(server, qos=QoSPolicy(preempt=True))


def test_qos_policy_validation():
    with pytest.raises(ValueError, match="weight"):
        QoSClass(weight=0)
    with pytest.raises(ValueError, match="rate_per_s"):
        QoSClass(rate_per_s=0.0)
    with pytest.raises(ValueError, match="burst"):
        QoSClass(burst=0)
    with pytest.raises(ValueError, match="max_slots"):
        QoSClass(max_slots=0)
    with pytest.raises(ValueError, match="quantum"):
        QoSPolicy(quantum=0)
    with pytest.raises(TypeError, match="QoSClass"):
        QoSPolicy(classes={"x": object()})


def test_frontend_rejects_non_policy_qos(rng):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    with pytest.raises(TypeError, match="QoSPolicy"):
        AsyncSpikeFrontend(server, qos={"hi": 1})


def test_queue_position_follows_scheduler_order(rng):
    """poll()['queue_position'] under QoS reflects the priority-then-
    class order the scheduler favors, not raw submission order."""
    engine = _engine(rng)
    policy = QoSPolicy(classes={"hi": QoSClass(priority=1),
                                "bg": QoSClass(priority=0)})
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=8,
                            clock=VirtualClock(), qos=policy)
    rasters = _rasters(np.random.default_rng(6), [4] * 3,
                       engine.n_inputs)
    h_bg = fe.submit(rasters[0], tenant="bg")
    h_hi0 = fe.submit(rasters[1], tenant="hi")
    h_hi1 = fe.submit(rasters[2], tenant="hi")
    assert h_hi0.poll()["queue_position"] == 0
    assert h_hi1.poll()["queue_position"] == 1
    assert h_bg.poll()["queue_position"] == 2


def test_session_shared_frontend_qos_conflict(rng):
    """Co-resident views must agree on the QoS policy shaping their
    shared queue; a different policy raises, the same policy shares."""
    sess = AcceleratorSession()
    r = np.random.default_rng(3)
    sess.deploy("a", make_random_net(r))
    policy = QoSPolicy(classes={"a": QoSClass(priority=1)})
    cfg = FrontendConfig(queue_capacity=8, qos=policy)
    va = sess.serve("a", n_slots=2, chunk_steps=3, frontend=cfg)
    assert va.frontend.qos == policy
    # identical policy value (fresh object) is NOT a conflict
    same = FrontendConfig(
        queue_capacity=8, qos=QoSPolicy(classes={"a": QoSClass(priority=1)}))
    assert (sess.serve("a", n_slots=2, chunk_steps=3,
                       frontend=same).frontend is va.frontend)
    with pytest.raises(ValueError, match="one request queue"):
        sess.serve("a", n_slots=2, chunk_steps=3,
                   frontend=FrontendConfig(queue_capacity=8))


# --------------------------------------------------------------------------
# lifecycle audit: adversarial traffic reconstructs violation-free
# --------------------------------------------------------------------------

def test_adversarial_qos_timeline_audit(rng):
    """Burst tenant + quota exhaustion + SLO-shed (preemption) + queued
    expiry: the request-domain trace replays violation-free, and the
    park/eviction counts match the per-class outcome counters exactly."""
    from repro.obs import MetricsRegistry, SpanTracer
    from repro.obs.timeline import reconstruct

    engine = _engine(rng)
    policy = QoSPolicy(
        classes={"burst": QoSClass(priority=2, weight=2),
                 "bg": QoSClass(priority=0, max_slots=1)},
        preempt=True)
    clock = VirtualClock()
    registry, tracer = MetricsRegistry(), SpanTracer(clock=clock)
    server = SpikeServer(engine, n_slots=2, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=8, clock=clock,
                            qos=policy, connector=InMemoryCarryConnector(),
                            metrics=registry, tracer=tracer)
    r = np.random.default_rng(9)
    bg_rasters = _rasters(r, (10, 10, 10), engine.n_inputs)
    burst_rasters = _rasters(r, (4, 4, 4, 4), engine.n_inputs)
    handles = [fe.submit(x, tenant="bg") for x in bg_rasters]
    fe.pump()                      # bg occupies its quota'd slot
    clock.t += 1.0
    # the burst lands mid-run; one request carries a deadline it misses
    handles += [fe.submit(x, tenant="burst") for x in burst_rasters[:3]]
    handles.append(fe.submit(burst_rasters[3], tenant="burst",
                             deadline_ms=500.0))
    clock.t += 2.0                 # deadline (0.5 s) passes while queued
    rounds = 0
    while not fe.idle and rounds < 200:
        fe.pump()
        clock.t += 1.0
        rounds += 1
    assert fe.idle

    rep = reconstruct(tracer)      # validate=True: any violation raises
    req_streams = [s for (domain, _), s in rep.streams.items()
                   if domain == "request"]
    assert len(req_streams) == len(handles)
    m = fe.metrics()
    # park events in the trace == the parked counter, globally and per
    # class (preemptions are the "evicted" subset of parks)
    assert sum(s.n_parks for s in req_streams) == m["counts"]["parked"]
    by_tenant_parks = {}
    for h, s in zip(handles, sorted(req_streams, key=lambda s: s.uid)):
        t = h._req.tenant
        by_tenant_parks[t] = by_tenant_parks.get(t, 0) + s.n_parks
    for cls in ("burst", "bg"):
        assert (by_tenant_parks.get(cls, 0)
                == m["by_class"][cls]["counts"]["parked"])
    assert m["counts"]["evicted"] >= 1          # the shed actually fired
    assert m["counts"]["expired"] == 1          # the deadline miss
    assert (m["by_class"]["burst"]["counts"]["expired"] == 1)
    # registry mirror agrees with the plain-dict per-class counters
    samples = registry.snapshot()[
        "snn_frontend_class_outcomes_total"]["samples"]
    for cls in ("burst", "bg"):
        for outcome in OUTCOME_KEYS:
            got = sum(s["value"] for s in samples
                      if s["labels"] == {"stream_class": cls,
                                         "outcome": outcome})
            assert got == m["by_class"][cls]["counts"][outcome], (
                cls, outcome)


# --------------------------------------------------------------------------
# thread safety: submitters racing the pump loop
# --------------------------------------------------------------------------

@pytest.mark.parametrize("use_qos", [False, True])
def test_threaded_submit_against_pump_loop(rng, use_qos):
    """N submitter threads against the background pump driver: every
    handle reaches a terminal state, no rid is lost or duplicated, the
    outcome counters balance, and the queue-depth gauge ends at 0."""
    from repro.obs import MetricsRegistry

    engine = _engine(rng)
    policy = (QoSPolicy(classes={"t0": QoSClass(priority=1, weight=2),
                                 "t1": QoSClass(),
                                 "t2": QoSClass(),
                                 "t3": QoSClass()})
              if use_qos else None)
    for _ in range(3):             # re-run: races don't reproduce once
        registry = MetricsRegistry()
        server = SpikeServer(engine, n_slots=2, chunk_steps=2)
        fe = AsyncSpikeFrontend(server, queue_capacity=64,
                                backpressure="reject", qos=policy,
                                metrics=registry)
        n_threads, per_thread = 4, 6
        all_handles = [[] for _ in range(n_threads)]
        barrier = threading.Barrier(n_threads)

        def submitter(tid):
            r = np.random.default_rng(100 + tid)
            barrier.wait()
            for _ in range(per_thread):
                raster = (r.random((3, engine.n_inputs)) < 0.3
                          ).astype(np.int32)
                all_handles[tid].append(
                    fe.submit(raster, tenant=f"t{tid}"))

        fe.start(poll_interval_s=0.0005)
        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        fe.stop(drain=True)

        handles = [h for per in all_handles for h in per]
        assert len(handles) == n_threads * per_thread
        rids = [h.rid for h in handles]
        assert len(set(rids)) == len(rids), "duplicated rid"
        assert all(h.done for h in handles), "lost request"
        m = fe.metrics()
        assert m["counts"]["submitted"] == n_threads * per_thread
        terminal = (m["counts"]["done"] + m["counts"]["rejected"]
                    + m["counts"]["dropped"] + m["counts"]["cancelled"]
                    + m["counts"]["expired"])
        assert terminal == n_threads * per_thread
        assert fe.queue_depth == 0 and fe.n_running == 0
        depth = registry.snapshot()[
            "snn_frontend_queue_depth"]["samples"]
        assert depth and depth[0]["value"] == 0
        # every completed request actually computed something
        for h in handles:
            if h.state == "done":
                assert h.result()["spikes"].shape[0] == 3


def test_start_twice_raises_and_stop_is_idempotent(rng):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    fe = AsyncSpikeFrontend(server)
    fe.start()
    with pytest.raises(RuntimeError, match="already running"):
        fe.start()
    fe.stop()
    fe.stop()          # no thread -> no-op
    fe.start()         # restartable after a clean stop
    fe.stop()


# --------------------------------------------------------------------------
# WeightedFairQueue unit surface (deque compatibility)
# --------------------------------------------------------------------------

def test_wfq_deque_surface():
    import dataclasses as dc

    @dc.dataclass
    class R:
        rid: int
        tenant: str

        @property
        def steps_total(self):
            return 4

    policy = QoSPolicy(classes={"hi": QoSClass(priority=1),
                                "bg": QoSClass()})
    q = WeightedFairQueue(policy)
    a, b, c = R(0, "bg"), R(1, "hi"), R(2, "bg")
    for x in (a, b, c):
        q.append(x)
    assert len(q) == 3 and bool(q)
    assert list(q) == [b, a, c]            # priority first, then FIFO
    assert q.index(c) == 2
    q.remove(a)
    assert list(q) == [b, c]
    q.appendleft(a)
    assert list(q) == [b, a, c]
    assert q.depth_by_class() == {"hi": 1, "bg": 2}
    v = q.drop_victim()
    assert v is a                          # oldest of the lowest class
    got = q.pop_admissible(now=0.0)
    assert got is b                        # strict priority
    assert q.running["hi"] == 1
    q.note_released(b)
    assert q.running["hi"] == 0
