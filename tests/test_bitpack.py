"""Bitpacked spike raster contracts (``repro.kernels.bitpack``).

The packed form is a WIRE FORMAT other subsystems build on — the fused
kernel's external raster, the event gate's activity scalars, and the AER
decode all assume the exact lane layout — so the claims pinned here are:

  * ``unpack_spikes(pack_spikes(x), S)`` is the identity on {0,1} rasters
    for ANY source count (ragged last lane, zero sources, all-zero and
    all-one lanes) — hypothesis property + deterministic companions;
  * the lane layout is exactly ``source s -> lane s // 32, bit s % 32``
    (little-endian in the lane) — pinned against hand-built words so a
    refactor cannot silently flip endianness;
  * popcount reductions (``count_spikes``, ``block_activity``) equal the
    dense sums they replace;
  * the AER event path scatters into the same layout:
    ``aer_to_packed == pack_spikes(dense)`` for any stream.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.events.aer import aer_to_packed, dense_to_aer
from repro.kernels import bitpack


def _raster(rng, shape, density=0.3):
    return (rng.random(shape) < density).astype(np.int32)


# --------------------------------------------------------------------------
# round trip: property test + deterministic companions
# --------------------------------------------------------------------------

@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(B=st.integers(1, 4), S=st.integers(1, 130),
       density=st.floats(0.0, 1.0), seed=st.integers(0, 2**16))
@pytest.mark.slow
def test_pack_round_trip_property(B, S, density, seed):
    """pack -> unpack is the identity for ANY ragged source count and
    activity level (0.0 and 1.0 included: all-zero / all-one lanes)."""
    rng = np.random.default_rng(seed)
    dense = _raster(rng, (B, S), density)
    packed = bitpack.pack_spikes(dense)
    assert packed.shape == (B, bitpack.packed_lanes(S))
    assert packed.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(bitpack.unpack_spikes(packed, S)), dense)


def test_pack_round_trip_deterministic(rng):
    """The same identity on fixed corner cases (always runs)."""
    cases = [
        np.zeros((2, 5), np.int32),            # ragged, silent
        np.ones((2, 64), np.int32),            # exact lanes, saturated
        np.ones((3, 33), np.int32),            # one bit into the 2nd lane
        np.zeros((1, 32), np.int32),           # one all-zero lane
        _raster(rng, (4, 127), 0.5),           # ragged last lane
        _raster(rng, (2, 3, 40), 0.2),         # leading batch dims
    ]
    for dense in cases:
        packed = bitpack.pack_spikes(dense)
        np.testing.assert_array_equal(
            np.asarray(bitpack.unpack_spikes(packed, dense.shape[-1])),
            dense)


def test_pack_binarizes_and_pads():
    """Any nonzero packs to a set bit; the ragged tail is zero-filled so
    popcounts equal dense counts."""
    dense = np.array([[0, 3, -1, 0, 7]], np.int32)  # S=5, one lane
    packed = np.asarray(bitpack.pack_spikes(dense))
    assert packed.shape == (1, 1)
    assert packed[0, 0] == (1 << 1) | (1 << 2) | (1 << 4)
    assert int(bitpack.count_spikes(bitpack.pack_spikes(dense))[0]) == 3


def test_zero_sources():
    dense = np.zeros((3, 0), np.int32)
    packed = bitpack.pack_spikes(dense)
    assert packed.shape == (3, 0)
    assert np.asarray(bitpack.unpack_spikes(packed, 0)).shape == (3, 0)
    assert bitpack.packed_lanes(0) == 0


# --------------------------------------------------------------------------
# lane layout: the contract, pinned bit by bit
# --------------------------------------------------------------------------

def test_lane_layout_pinned():
    """source s -> lane s // 32, bit s % 32, little-endian in the lane."""
    S = 80  # 3 lanes, ragged last
    for s in (0, 1, 31, 32, 63, 64, 79):
        dense = np.zeros((1, S), np.int32)
        dense[0, s] = 1
        packed = np.asarray(bitpack.pack_spikes(dense))
        expected = np.zeros(3, np.uint32)
        expected[s // 32] = np.uint32(1) << (s % 32)
        np.testing.assert_array_equal(packed[0], expected)


def test_unpack_validates_lane_count():
    packed = jnp.zeros((2, 2), jnp.uint32)
    with pytest.raises(ValueError, match="lanes"):
        bitpack.unpack_spikes(packed, 65)  # needs 3 lanes
    # exactly enough lanes, and fewer sources than capacity, both fine
    assert bitpack.unpack_spikes(packed, 64).shape == (2, 64)
    assert bitpack.unpack_spikes(packed, 40).shape == (2, 40)


# --------------------------------------------------------------------------
# popcount reductions == the dense sums they replace
# --------------------------------------------------------------------------

def test_count_spikes_matches_dense_sum(rng):
    for S in (7, 32, 100, 256):
        dense = _raster(rng, (3, 5, S), 0.4)
        counts = np.asarray(bitpack.count_spikes(bitpack.pack_spikes(dense)))
        np.testing.assert_array_equal(counts, dense.sum(axis=-1))


def test_block_activity_matches_dense_block_sums(rng):
    B, S, block = 6, 256, 128
    dense = _raster(rng, (B, S), 0.1)
    act = np.asarray(
        bitpack.block_activity(bitpack.pack_spikes(dense), block))
    assert act.shape == (B, S // block)
    np.testing.assert_array_equal(
        act, dense.reshape(B, S // block, block).sum(axis=-1))


def test_block_activity_validation():
    packed = jnp.zeros((2, 4), jnp.uint32)
    with pytest.raises(ValueError, match="multiple"):
        bitpack.block_activity(packed, 48)   # not a lane multiple
    with pytest.raises(ValueError, match="tile"):
        bitpack.block_activity(packed, 96)   # 4 lanes / 3-lane blocks


# --------------------------------------------------------------------------
# the AER event path lands on the same layout
# --------------------------------------------------------------------------

@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(T=st.integers(1, 4), B=st.integers(1, 3), S=st.integers(1, 70),
       density=st.floats(0.0, 0.6), seed=st.integers(0, 2**16))
@pytest.mark.slow
def test_aer_to_packed_matches_pack_property(T, B, S, density, seed):
    """Scattering events as bits == packing the dense raster."""
    rng = np.random.default_rng(seed)
    dense = _raster(rng, (T, B, S), density)
    stream = dense_to_aer(dense, int(dense.sum()) + 2)
    np.testing.assert_array_equal(
        np.asarray(aer_to_packed(stream)),
        np.asarray(bitpack.pack_spikes(dense)))


def test_aer_to_packed_matches_pack_deterministic(rng):
    cases = [
        np.zeros((2, 2, 9), np.int32),
        np.ones((2, 1, 33), np.int32),
        _raster(rng, (4, 3, 100), 0.2),
    ]
    for dense in cases:
        stream = dense_to_aer(dense, int(dense.sum()))
        np.testing.assert_array_equal(
            np.asarray(aer_to_packed(stream)),
            np.asarray(bitpack.pack_spikes(dense)))
        assert int(bitpack.count_spikes(
            aer_to_packed(stream)).sum()) == int(stream.count)
