"""End-to-end straggler detection through the metrics registry.

Satellite contract: synthetic per-shard step timings published as
``snn_shard_step_seconds`` gauges and fed to the detector via
``observe_from_registry`` must produce EXACTLY the flags and donor sets
the pure ``StragglerDetector.observe`` computes on the same vectors —
the registry is a transport, never a filter — and the resulting flags
must be mirrored into the ``snn_shard_straggler_flagged`` gauges.
"""

import numpy as np

from repro.distributed.straggler import (StragglerDetector, donor_shards,
                                         observe_from_registry)
from repro.launch.serve_snn import ShardLoadWatch
from repro.obs import MetricsRegistry


def synthetic_timings(n_hosts=4, steps=40, straggler=2, onset=20,
                      seed=0):
    rng = np.random.default_rng(seed)
    times = 1.0 + 0.01 * rng.standard_normal((steps, n_hosts))
    times[onset:, straggler] *= 3.0  # thermal throttling from `onset` on
    return times


def test_registry_path_matches_pure_observe_exactly():
    times = synthetic_timings()
    n = times.shape[1]
    reg = MetricsRegistry()
    det_reg = StragglerDetector(num_hosts=n, warmup_steps=5, patience=3)
    det_pure = StragglerDetector(num_hosts=n, warmup_steps=5, patience=3)

    gauges = reg.gauge("snn_shard_step_seconds")
    flag_gauges = reg.gauge("snn_shard_straggler_flagged")
    any_flagged = False
    for t in times:
        for shard, dt in enumerate(t):
            gauges.labels(shard=shard).set(float(dt))
        flags = observe_from_registry(det_reg, reg)
        expect = det_pure.observe(t)
        np.testing.assert_array_equal(flags, expect)
        np.testing.assert_array_equal(donor_shards(flags),
                                      donor_shards(expect))
        # the flags are exported right back as gauges
        mirrored = [flag_gauges.labels(shard=s).value for s in range(n)]
        np.testing.assert_array_equal(np.asarray(mirrored, bool), flags)
        any_flagged = any_flagged or flags.any()
    assert any_flagged, "the synthetic straggler must eventually flag"
    assert set(donor_shards(flags)) == {0, 1, 3}


def test_registry_path_shares_detector_state():
    # interleaving registry-driven and direct observe() steps on ONE
    # detector is seamless: observe_from_registry is observe + transport
    times = synthetic_timings(seed=1)
    n = times.shape[1]
    reg = MetricsRegistry()
    det_mixed = StragglerDetector(num_hosts=n, warmup_steps=5, patience=3)
    det_pure = StragglerDetector(num_hosts=n, warmup_steps=5, patience=3)
    for i, t in enumerate(times):
        if i % 2:
            for shard, dt in enumerate(t):
                reg.gauge("snn_shard_step_seconds").labels(
                    shard=shard).set(float(dt))
            flags = observe_from_registry(det_mixed, reg)
        else:
            flags = det_mixed.observe(t)
        np.testing.assert_array_equal(flags, det_pure.observe(t))


def test_shard_load_watch_registry_flags_match_bare_watch():
    # the launcher's watch with a registry injected must flag exactly
    # like the bare watch on the same dispatch sequence
    rng = np.random.default_rng(2)
    n_shards, n_slots = 4, 8
    reg = MetricsRegistry()
    with_reg = ShardLoadWatch(n_shards, n_slots, registry=reg)
    bare = ShardLoadWatch(n_shards, n_slots)
    live = list(range(n_slots))
    for i in range(40):
        dt = 0.01 + 0.0001 * rng.standard_normal()
        # shard 1 keeps a heavier live-slot load from round 10 on
        slots = live if i < 10 else [0, 2, 3, 4, 5] + [2, 3] * 3
        with_reg.observe(dt, slots)
        bare.observe(dt, slots)
    np.testing.assert_array_equal(with_reg.flag_counts, bare.flag_counts)
    np.testing.assert_array_equal(with_reg.persistent_flags(),
                                  bare.persistent_flags())
    assert with_reg.report() == bare.report()
    # the last dispatch's attributed times are exported as gauges
    fam = reg.gauge("snn_shard_step_seconds")
    exported = [fam.labels(shard=s).value for s in range(n_shards)]
    # zero-load shards legitimately attribute 0.0; the loaded ones export
    assert all(v >= 0 for v in exported) and max(exported) > 0
