"""Multi-pod dry-run integration: one real (arch x shape x mesh) cell in a
subprocess (the 512-device XLA flag must not leak into this test process).

The full 40-cell x 2-mesh sweep runs via ``python -m repro.launch.dryrun``
and is recorded in EXPERIMENTS.md; this test pins the machinery.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_one_cell(tmp_path):
    out = tmp_path / "dryrun.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-3-2b", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    recs = json.load(open(out))
    assert len(recs) == 1
    rec = recs[0]
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    # memory fits a 16 GB-HBM chip
    assert rec["memory"]["total_bytes"] < 16 * 2**30
    # roofline terms present and positive
    assert rec["roofline"]["memory_s"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_dryrun_skip_cell_documented(tmp_path):
    """long_500k on a full-attention arch must record a documented skip."""
    out = tmp_path / "dryrun.json"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "granite-20b", "--shape", "long_500k",
         "--mesh", "single", "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.load(open(out))[0]
    assert rec["status"] == "skip"
    assert "sub-quadratic" in rec["reason"]
