"""Async front-door contracts: the queue changes WHEN, never WHAT.

The acceptance criterion of the frontend: for the same realized admission
order, ``AsyncSpikeFrontend``-served rasters are byte-identical to direct
synchronous ``SpikeServer.feed`` / one-shot ``SpikeEngine.run`` of each
request's full raster, for every backend x reset mode x gate (full sweep
under ``slow``; the mesh cross is in tests/test_spike_mesh.py). Plus the
front-door lifecycle contracts: cancel-while-queued never touches the
server; deadline expiry mid-stream zeroes the slot carry exactly like any
eviction; backpressure policies do what they say; and admission order +
slot assignment is a deterministic function of the submit/cancel/pump
sequence (hypothesis property with deterministic companions).

With a carry connector attached (spill-on-evict), mid-stream expiry PARKS
the stream instead of killing it: ``resume()`` must continue it
byte-identically to a never-spilled run, cancel-while-parked must never
touch the server, and the determinism property extends over the
detach/attach (spill/resume) ops.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import BACKENDS, GATES, DecaySpec, SpikeEngine
from repro.core.session import AcceleratorSession
from repro.serving.frontend import (AsyncSpikeFrontend, FrontendConfig,
                                    latency_percentiles)
from repro.serving.snn import SpikeServer

from conftest import make_random_net

THRESH = 1 << 16
RESET_MODES = ("zero", "subtract", "hold")


class VirtualClock:
    """Deterministic frontend clock: advances only when told to."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _engine(rng, *, backend="reference", reset="subtract", gate="batch-tile",
            n_in=10, n_phys=16, wmax=1 << 13):
    S = n_in + n_phys
    W = ((rng.random((S, n_phys)) < 0.4)
         * rng.integers(-wmax, wmax, (S, n_phys)))
    return SpikeEngine(jnp.asarray(W, jnp.int32), n_in,
                       decay=DecaySpec.shift(0.25), threshold_raw=THRESH,
                       reset_mode=reset, backend=backend, gate=gate)


def _rasters(rng, lengths, n_in, p=0.35):
    return [(rng.random((T, n_in)) < p).astype(np.int32) for T in lengths]


# --------------------------------------------------------------------------
# Async-vs-synchronous bit-identity
# --------------------------------------------------------------------------

def _assert_async_equals_sync(engine, rng, *, n_slots=2, chunk_steps=3,
                              lengths=(7, 4, 1, 9, 5)):
    """Everything submitted through the frontend must come back
    byte-identical to a one-shot run of its raster (which PR 2 pinned
    equal to synchronous ``feed``)."""
    rasters = _rasters(rng, lengths, engine.n_inputs)
    server = SpikeServer(engine, n_slots=n_slots, chunk_steps=chunk_steps)
    fe = AsyncSpikeFrontend(server, queue_capacity=len(rasters))
    handles = [fe.submit(r) for r in rasters]
    m = fe.drain()
    assert m["counts"]["done"] == len(rasters)
    for h, r in zip(handles, rasters):
        want = np.asarray(engine.run(r[:, None, :])["spikes"])[:, 0]
        got = h.result()["spikes"]
        assert got.dtype == want.dtype == np.int32
        np.testing.assert_array_equal(got, want)
        assert "partial" not in h.result()


@pytest.mark.parametrize("reset", RESET_MODES)
def test_async_bit_identity_reference(rng, reset):
    _assert_async_equals_sync(_engine(rng, reset=reset), rng)


def test_async_bit_identity_per_example_gate(rng):
    _assert_async_equals_sync(_engine(rng, gate="per-example"), rng)


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("reset", RESET_MODES)
@pytest.mark.parametrize("gate", GATES)
def test_async_bit_identity_sweep(rng, backend, reset, gate):
    engine = _engine(rng, backend=backend, reset=reset, gate=gate)
    _assert_async_equals_sync(engine, rng)


def test_async_matches_direct_feed_same_admission_order(rng):
    """The literal acceptance phrasing: replay the REALIZED admission
    order synchronously through ``SpikeServer.feed`` and compare bytes."""
    engine = _engine(rng)
    rasters = _rasters(rng, (6, 3, 5), engine.n_inputs)
    server = SpikeServer(engine, n_slots=2, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=8)
    handles = [fe.submit(r) for r in rasters]
    order = []          # realized admission order, by request index
    while not fe.idle:
        before = {h.rid for h in handles if h.state == "queued"}
        fe.pump()
        after = {h.rid for h in handles if h.state == "queued"}
        order += sorted(before - after)
    sync_server = SpikeServer(engine, n_slots=2, chunk_steps=2)
    for rid in order:
        uid = sync_server.attach()
        got = sync_server.feed({uid: rasters[rid]})[uid]["spikes"]
        sync_server.detach(uid)
        np.testing.assert_array_equal(handles[rid].result()["spikes"], got)


# --------------------------------------------------------------------------
# Lifecycle: cancel, deadlines, carry zeroing
# --------------------------------------------------------------------------

def test_cancel_while_queued_never_touches_server(rng):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=4)
    a, b = (fe.submit(r) for r in _rasters(rng, (4, 4), engine.n_inputs))
    fe.pump()  # a admitted + fed; b still queued
    assert a.state == "running" and b.state == "queued"
    assert b.cancel() is True
    assert b.state == "cancelled" and b.result() is None
    assert fe.queue_depth == 0
    assert len(server.scheduler.active) == 1  # only a ever reached a slot
    assert b.cancel() is False  # terminal: too late
    fe.drain()
    assert a.state == "done"


def test_cancel_mid_stream_keeps_partial_and_zeroes_carry(rng):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=2)
    raster = _rasters(rng, (8,), engine.n_inputs)[0]
    h = fe.submit(raster)
    fe.pump()
    assert h.state == "running" and h.poll()["steps_done"] == 2
    assert h.cancel() is True
    res = h.result()
    assert res["partial"] is True and res["spikes"].shape[0] == 2
    want = np.asarray(engine.run(raster[:2, None, :])["spikes"])[:, 0]
    np.testing.assert_array_equal(res["spikes"], want)
    # eviction semantics: the freed slot is power-on clean
    assert int(np.abs(np.asarray(server.carry["v"])).sum()) == 0
    assert int(np.asarray(server.carry["spikes"]).sum()) == 0


def test_deadline_expiry_queued_vs_mid_stream(rng):
    """A queued request past its deadline is refused; a running one is
    evicted with the slot carry zeroed like any eviction, and the next
    occupant powers up from clean state (byte-identical to a fresh run)."""
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    clock = VirtualClock()
    fe = AsyncSpikeFrontend(server, queue_capacity=4, clock=clock)
    ra, rb, rc = _rasters(rng, (8, 8, 6), engine.n_inputs)
    a = fe.submit(ra, deadline_ms=1_000)   # will expire mid-stream
    b = fe.submit(rb, deadline_ms=1_000)   # will expire while queued
    c = fe.submit(rc)                      # no deadline: must run clean
    fe.pump()
    assert a.state == "running" and b.state == "queued"
    clock.t = 2.0  # both deadlines (t=1.0) now past
    fe.pump()
    assert a.state == "expired" and b.state == "expired"
    assert a.result()["partial"] is True   # kept what was served
    assert b.result() is None              # never consumed a timestep
    m = fe.metrics()["counts"]
    assert m["expired_running"] == 1 and m["expired_queued"] == 1
    fe.drain()
    want = np.asarray(engine.run(rc[:, None, :])["spikes"])[:, 0]
    np.testing.assert_array_equal(c.result()["spikes"], want)


# --------------------------------------------------------------------------
# Backpressure policies
# --------------------------------------------------------------------------

def test_backpressure_reject(rng):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=1, backpressure="reject")
    ra, rb = _rasters(rng, (4, 4), engine.n_inputs)
    a = fe.submit(ra)
    b = fe.submit(rb)
    assert a.state == "queued" and b.state == "rejected"
    assert b.result() is None and b.done
    fe.drain()
    assert a.state == "done"
    assert fe.metrics()["counts"]["rejected"] == 1


def test_backpressure_drop_oldest(rng):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=1,
                            backpressure="drop-oldest")
    ra, rb = _rasters(rng, (4, 4), engine.n_inputs)
    a = fe.submit(ra)
    b = fe.submit(rb)
    assert a.state == "dropped" and b.state == "queued"
    fe.drain()
    assert b.state == "done"
    counts = fe.metrics()["counts"]
    assert counts["dropped"] == 1 and counts["done"] == 1


def test_backpressure_block_pumps_until_space(rng):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=4)
    fe = AsyncSpikeFrontend(server, queue_capacity=1, backpressure="block")
    ra, rb = _rasters(rng, (4, 4), engine.n_inputs)
    a = fe.submit(ra)
    b = fe.submit(rb)  # queue full: submit itself pumps the loop
    assert b.state == "queued"
    assert a.state in ("running", "done")  # progress was forced
    fe.drain()
    assert a.state == "done" and b.state == "done"
    want = np.asarray(engine.run(rb[:, None, :])["spikes"])[:, 0]
    np.testing.assert_array_equal(b.result()["spikes"], want)


def test_constructor_validation(rng):
    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1)
    with pytest.raises(ValueError, match="backpressure"):
        AsyncSpikeFrontend(server, backpressure="explode")
    with pytest.raises(ValueError, match="queue_capacity"):
        AsyncSpikeFrontend(server, queue_capacity=0)
    with pytest.raises(ValueError, match="deadline_ms"):
        AsyncSpikeFrontend(server, deadline_ms=0)
    fe = AsyncSpikeFrontend(server)
    with pytest.raises(ValueError, match="chunk must be"):
        fe.submit(np.zeros((3, engine.n_inputs + 1), np.int32))
    with pytest.raises(ValueError, match="at least 1 timestep"):
        fe.submit(np.zeros((0, engine.n_inputs), np.int32))


# --------------------------------------------------------------------------
# Determinism: admission order + slot assignment from the op sequence
# --------------------------------------------------------------------------

def _run_scenario(engine, lengths, cancel_at, n_slots, chunk_steps,
                  capacity, policy):
    """One full frontend run; returns the observable trace: per-round
    (admitted rid -> slot) plus every request's terminal state + bytes."""
    rng = np.random.default_rng(7)
    rasters = _rasters(rng, lengths, engine.n_inputs)
    server = SpikeServer(engine, n_slots=n_slots, chunk_steps=chunk_steps)
    fe = AsyncSpikeFrontend(server, queue_capacity=capacity,
                            backpressure=policy)
    handles, trace = [], []
    for i, r in enumerate(rasters):
        handles.append(fe.submit(r))
        if i in cancel_at:
            handles[-1].cancel()
    rid_of_uid = {}
    while not fe.idle:
        fe.pump()
        for h in handles:
            uid = h._req.uid
            if uid is not None and uid not in rid_of_uid:
                rid_of_uid[uid] = h.rid
        trace.append(sorted((rid_of_uid[u], s)
                            for u, s in server.scheduler.active.items()))
    states = [h.state for h in handles]
    bytes_out = [None if h.result() is None
                 else h.result()["spikes"].tobytes() for h in handles]
    return trace, states, bytes_out


def test_admission_determinism_deterministic_companion(rng):
    engine = _engine(rng)
    kw = dict(lengths=(5, 3, 7, 2, 6), cancel_at={2}, n_slots=2,
              chunk_steps=3, capacity=3, policy="drop-oldest")
    assert (_run_scenario(engine, **kw) == _run_scenario(engine, **kw))


@hypothesis.given(
    seed=st.integers(0, 2**32 - 1),
    n_slots=st.integers(1, 3),
    chunk_steps=st.integers(1, 4),
    capacity=st.integers(1, 5),
    policy=st.sampled_from(("reject", "drop-oldest")),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_admission_determinism_property(seed, n_slots, chunk_steps,
                                        capacity, policy):
    """Admission order and slot assignment are a pure function of the
    submit/cancel/pump sequence — replaying it reproduces the identical
    trace and identical output bytes."""
    rng = np.random.default_rng(seed)
    engine = _engine(np.random.default_rng(0))
    lengths = tuple(int(t) for t in rng.integers(1, 8, rng.integers(1, 7)))
    cancel_at = set(rng.integers(0, len(lengths),
                                 rng.integers(0, len(lengths))).tolist())
    kw = dict(lengths=lengths, cancel_at=cancel_at, n_slots=n_slots,
              chunk_steps=chunk_steps, capacity=capacity, policy=policy)
    assert (_run_scenario(engine, **kw) == _run_scenario(engine, **kw))


# --------------------------------------------------------------------------
# Spill-on-evict: deadline expiry parks the carry, resume continues it
# --------------------------------------------------------------------------

def _spill_frontend(rng, *, n_slots=1, chunk_steps=2, capacity=4):
    from repro.serving.connector import InMemoryCarryConnector

    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=n_slots, chunk_steps=chunk_steps)
    clock = VirtualClock()
    conn = InMemoryCarryConnector()
    fe = AsyncSpikeFrontend(server, queue_capacity=capacity, clock=clock,
                            connector=conn)
    return engine, server, clock, conn, fe


def test_spill_resume_bit_clean(rng):
    """The spill contract: a mid-stream deadline eviction with a
    connector parks the carry; resume() finishes the stream and the FULL
    raster is byte-identical to a never-spilled run — no 'partial'."""
    engine, server, clock, conn, fe = _spill_frontend(rng)
    raster = _rasters(rng, (10,), engine.n_inputs)[0]
    want = np.asarray(engine.run(raster[:, None, :])["spikes"])[:, 0]

    h = fe.submit(raster, deadline_ms=1_000)
    fe.pump()                      # 2 of 10 steps served
    assert h.state == "running"
    clock.t = 2.0                  # deadline (t=1.0) passes mid-stream
    fe.pump()
    assert h.state == "parked" and not h.done
    assert h.result() is None      # parked is NOT terminal
    assert len(conn) == 1 and server.scheduler.free_slots == 1

    assert fe.resume(h) is True
    assert h.state == "queued"
    fe.drain()
    assert h.state == "done"
    res = h.result()
    assert "partial" not in res
    np.testing.assert_array_equal(res["spikes"], want)
    assert len(conn) == 0          # admission consumed the parked carry
    c = fe.metrics()["counts"]
    assert (c["parked"], c["resumed"], c["done"]) == (1, 1, 1)
    assert c["expired_running"] == 0  # documented keys are always present


def test_spill_interleaves_with_other_traffic(rng):
    """Another request runs in the spilled stream's slot between spill
    and resume — the resumed stream must still come back bit-clean (its
    state lived in the connector, not the slot)."""
    engine, server, clock, conn, fe = _spill_frontend(rng)
    ra, rb = _rasters(rng, (8, 4), engine.n_inputs)
    want_a = np.asarray(engine.run(ra[:, None, :])["spikes"])[:, 0]

    a = fe.submit(ra, deadline_ms=1_000)
    fe.pump()
    clock.t = 2.0
    fe.pump()                      # a parked; its slot is free
    b = fe.submit(rb)              # b claims (and dirties) that slot
    fe.drain()
    assert b.state == "done" and a.state == "parked"
    assert fe.resume(a) is True
    fe.drain()
    np.testing.assert_array_equal(a.result()["spikes"], want_a)
    assert "partial" not in a.result()


def test_cancel_while_parked_never_touches_server(rng):
    engine, server, clock, conn, fe = _spill_frontend(rng)
    h = fe.submit(_rasters(rng, (8,), engine.n_inputs)[0], deadline_ms=500)
    fe.pump()
    clock.t = 1.0
    fe.pump()
    assert h.state == "parked"
    steps_before = server.total_steps
    active_before = dict(server.scheduler.active)

    assert h.cancel() is True
    assert h.state == "cancelled" and h.done
    assert len(conn) == 0                       # spilled carry evicted
    assert server.total_steps == steps_before   # server never touched
    assert dict(server.scheduler.active) == active_before
    assert fe.resume(h) is False                # terminal: too late
    assert h.cancel() is False


def test_parked_request_requeued_past_deadline_returns_to_parked(rng):
    """resume() arms a fresh deadline; if THAT passes while the request
    is still queued, it falls back to 'parked' (carry stays in the
    connector, no leak) and a later resume still finishes bit-clean."""
    engine, server, clock, conn, fe = _spill_frontend(rng)
    raster = _rasters(rng, (8,), engine.n_inputs)[0]
    want = np.asarray(engine.run(raster[:, None, :])["spikes"])[:, 0]

    blocker = fe.submit(_rasters(rng, (6,), engine.n_inputs)[0])
    h = fe.submit(raster, deadline_ms=1_000)
    fe.pump()                      # blocker holds the only slot
    assert blocker.state == "running" and h.state == "queued"
    clock.t = 2.0
    fe.pump()                      # h expires while QUEUED, never parked
    assert h.state == "expired"    # no carry existed -> plain refusal

    h2 = fe.submit(raster, deadline_ms=2_000)
    fe.drain(max_rounds=2)         # blocker finishes; h2 runs a quantum
    assert h2.state == "running"
    clock.t = 5.0
    fe.pump()
    assert h2.state == "parked"
    fe.resume(h2, deadline_ms=1_000)
    clock.t = 99.0                 # fresh deadline passes while queued
    blocker2 = fe.submit(_rasters(rng, (2,), engine.n_inputs)[0])
    fe.pump()
    assert h2.state == "parked" and len(conn) == 1  # back to parked
    assert fe.resume(h2) is True   # no deadline this time
    fe.drain()
    assert blocker2.state == "done" and h2.state == "done"
    np.testing.assert_array_equal(h2.result()["spikes"], want)


def test_resume_under_reject_backpressure_stays_parked(rng):
    engine, server, clock, conn, fe = _spill_frontend(rng, capacity=1)
    h = fe.submit(_rasters(rng, (8,), engine.n_inputs)[0], deadline_ms=500)
    fe.pump()
    clock.t = 1.0
    fe.pump()
    assert h.state == "parked"
    filler = fe.submit(_rasters(rng, (9,), engine.n_inputs)[0])
    fe.pump()                      # filler admitted -> queue has room...
    blocker = fe.submit(_rasters(rng, (9,), engine.n_inputs)[0])
    assert blocker.state == "queued"
    assert fe.resume(h) is False   # ...but now it is full again: reject
    assert h.state == "parked" and len(conn) == 1
    fe.drain()
    assert fe.resume(h) is True    # room now; the carry waited it out
    fe.drain()
    assert h.state == "done"


def test_determinism_extends_over_spill_resume_ops(rng):
    """The determinism contract extended over detach/attach: with spill
    and resume in the op sequence, replaying it reproduces identical
    states, counts, and output bytes."""
    def run():
        from repro.serving.connector import InMemoryCarryConnector

        r = np.random.default_rng(13)
        engine = _engine(np.random.default_rng(5))
        server = SpikeServer(engine, n_slots=2, chunk_steps=2)
        clock = VirtualClock()
        fe = AsyncSpikeFrontend(server, queue_capacity=6, clock=clock,
                                connector=InMemoryCarryConnector())
        lengths = (9, 7, 8, 3, 6)
        # the first two carry tight deadlines (they will spill + resume,
        # possibly repeatedly); the rest run undisturbed alongside them
        handles = [fe.submit(rr, deadline_ms=(2_000 if i < 2 else None))
                   for i, rr in
                   enumerate(_rasters(r, lengths, engine.n_inputs))]
        states = []
        for _ in range(40):
            if fe.idle and not any(h.state == "parked" for h in handles):
                break
            clock.t += 1.1          # every ~2nd quantum crosses a deadline
            fe.pump()
            for h in handles:
                if h.state == "parked":
                    fe.resume(h, deadline_ms=4_000)
            states.append(tuple(h.state for h in handles))
        outs = [None if h.result() is None
                else h.result()["spikes"].tobytes() for h in handles]
        return states, outs, dict(fe.counts)

    a, b = run(), run()
    assert a == b
    states, outs, counts = a
    assert counts.get("parked", 0) > 0      # the scenario really spilled
    assert counts["done"] == 5              # and everyone finished
    # every raster byte-identical to its never-spilled run
    r = np.random.default_rng(13)
    engine = _engine(np.random.default_rng(5))
    for raster, got in zip(_rasters(r, (9, 7, 8, 3, 6), engine.n_inputs),
                           outs):
        want = np.asarray(engine.run(raster[:, None, :])["spikes"])[:, 0]
        assert got == want.tobytes()


# --------------------------------------------------------------------------
# AER requests + session wiring
# --------------------------------------------------------------------------

def test_submit_events_round_trip(rng):
    from repro.events.aer import dense_to_aer

    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=2, chunk_steps=3)
    fe = AsyncSpikeFrontend(server)
    raster = _rasters(rng, (6,), engine.n_inputs)[0]
    stream = dense_to_aer(raster[:, None, :], capacity=raster.sum())
    h = fe.submit_events(stream, events_capacity=256)
    fe.drain()
    want = engine.run(raster[:, None, :])["spikes"]
    res = h.result()
    np.testing.assert_array_equal(res["spikes"], np.asarray(want)[:, 0])
    got_events = np.asarray(res["events"].addrs[:len(res["events"])])
    from repro.events.aer import aer_to_dense
    np.testing.assert_array_equal(
        np.asarray(aer_to_dense(res["events"]))[:, 0], res["spikes"])
    assert got_events.shape[1] == 3


def test_session_serve_frontend_shared_and_bit_identical(rng):
    """Co-resident views share ONE frontend queue, and async view results
    are byte-identical to synchronous view feeds of the same rasters."""
    def build():
        sess = AcceleratorSession()
        r = np.random.default_rng(3)
        sess.deploy("a", make_random_net(r))
        sess.deploy("b", make_random_net(r))
        return sess

    cfg = FrontendConfig(queue_capacity=8)
    sess = build()
    va = sess.serve("a", n_slots=2, chunk_steps=3, frontend=cfg)
    vb = sess.serve("b", n_slots=2, chunk_steps=3, frontend=cfg)
    assert va.frontend is vb.frontend is not None
    # a view served later without frontend= still sees the group's queue
    assert sess.serve("a", n_slots=2, chunk_steps=3).frontend is va.frontend
    with pytest.raises(ValueError, match="one request queue"):
        sess.serve("a", n_slots=2, chunk_steps=3,
                   frontend=FrontendConfig(queue_capacity=9))

    r = np.random.default_rng(11)
    chunk_a = (r.random((7, va.n_inputs)) < 0.4).astype(np.int32)
    chunk_b = (r.random((5, vb.n_inputs)) < 0.4).astype(np.int32)
    ha = va.submit(chunk_a)
    hb = vb.submit(chunk_b)
    va.frontend.drain()

    sync = build()
    for view, chunk, h in ((sync.serve("a", n_slots=2, chunk_steps=3),
                            chunk_a, ha),
                           (sync.serve("b", n_slots=2, chunk_steps=3),
                            chunk_b, hb)):
        uid = view.attach()
        want = view.feed(uid, chunk)
        got = h.result()
        np.testing.assert_array_equal(got["spikes"], want["spikes"])
        np.testing.assert_array_equal(got["output_counts"],
                                      want["output_counts"])
        assert got["predictions"] == want["predictions"]


def test_model_stream_submit_requires_frontend(rng):
    sess = AcceleratorSession()
    sess.deploy("m", make_random_net(np.random.default_rng(0)))
    view = sess.serve("m")
    with pytest.raises(RuntimeError, match="no async frontend"):
        view.submit(np.zeros((3, view.n_inputs), np.int32))


def test_latency_percentiles_shapes():
    assert latency_percentiles([])["p50"] is None
    assert latency_percentiles([])["p99"] is None
    p = latency_percentiles([1.0, 2.0, 3.0])
    assert p["p50"] == 2.0 and p["max"] == 3.0
    assert p["p95"] <= p["p99"] <= p["max"]


# --------------------------------------------------------------------------
# metrics() shape contract: every documented key, always (PR 8 satellite)
# --------------------------------------------------------------------------

def test_metrics_shape_on_empty_run(rng):
    """A frontend that never saw a request still returns every documented
    key with well-defined zeros — no KeyErrors, no missing outcomes."""
    from repro.serving.frontend import OUTCOME_KEYS

    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    fe = AsyncSpikeFrontend(server, queue_capacity=1)
    m = fe.metrics()
    assert set(m) == {"counts", "by_class", "queue_wait", "service",
                      "total", "queue_depth", "rounds"}
    assert m["counts"] == {k: 0 for k in OUTCOME_KEYS}
    # no QoS policy + no traffic = no classes to zero-fill
    assert m["by_class"] == {}
    for section in ("queue_wait", "service", "total"):
        assert m[section] == {"mean": None, "p50": None, "p95": None,
                              "p99": None, "max": None}
    assert m["queue_depth"] == {"max": 0, "mean": 0.0}
    assert m["rounds"] == 0


def test_metrics_by_class_zero_filled_on_empty_qos_run(rng):
    """A QoS frontend that never saw a request still reports every
    policy-declared class with the FULL zero-filled outcome dict and
    all-None percentiles — dashboards index per-class keys without
    existence checks (the PR 8 contract, extended per class)."""
    from repro.serving.frontend import OUTCOME_KEYS
    from repro.serving.qos import QoSClass, QoSPolicy

    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    policy = QoSPolicy(classes={"hi": QoSClass(priority=1),
                                "bg": QoSClass()})
    fe = AsyncSpikeFrontend(server, queue_capacity=1, qos=policy)
    m = fe.metrics()
    assert set(m["by_class"]) == {"hi", "bg"}
    for cls in ("hi", "bg"):
        per = m["by_class"][cls]
        assert set(per) == {"counts", "queue_wait", "service", "total"}
        assert per["counts"] == {k: 0 for k in OUTCOME_KEYS}
        for section in ("queue_wait", "service", "total"):
            assert per[section]["p50"] is None
            assert per[section]["p99"] is None


def test_metrics_shape_on_all_expired_run(rng):
    """An all-expired run (nothing ever retired cleanly) keeps the same
    shape: zero 'done', None service/total percentiles, every key there."""
    from repro.serving.frontend import OUTCOME_KEYS

    engine = _engine(rng)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2)
    clock = VirtualClock()
    fe = AsyncSpikeFrontend(server, queue_capacity=4, clock=clock)
    handles = [fe.submit(r, deadline_ms=1_000)
               for r in _rasters(rng, (4, 4), engine.n_inputs)]
    clock.t = 2.0           # every deadline passed before any admission
    fe.pump()
    assert all(h.state == "expired" for h in handles)
    m = fe.metrics()
    assert set(m["counts"]) == set(OUTCOME_KEYS)
    assert m["counts"]["done"] == 0
    assert m["counts"]["expired"] == 2
    assert m["counts"]["expired_queued"] == 2
    assert m["service"]["p50"] is None and m["total"]["p50"] is None
    assert m["rounds"] == 1
    # per-class mirror: the traffic's class appears zero-filled for
    # every outcome it never reached, latencies all-None
    assert set(m["by_class"]) == {"default"}
    per = m["by_class"]["default"]
    assert set(per["counts"]) == set(OUTCOME_KEYS)
    assert per["counts"]["expired"] == 2 and per["counts"]["done"] == 0
    assert per["total"]["p50"] is None


def test_traced_spill_flow_reconstructs_violation_free(rng):
    """Lifecycle audit over the representative front-door flow: server
    and frontend share one SpanTracer through submit / cancel-queued /
    queued-expiry / mid-stream spill / resume / drain, and the timeline
    reconstruction — which hard-errors on any illegal transition, leaked
    stream, or retire-without-admit — accepts the whole trace with the
    expected outcomes on both the request and the server domain."""
    from repro.obs import SpanTracer
    from repro.obs.timeline import reconstruct
    from repro.serving.connector import InMemoryCarryConnector

    engine = _engine(rng)
    clock = VirtualClock()
    tracer = SpanTracer(clock=clock)
    server = SpikeServer(engine, n_slots=1, chunk_steps=2, tracer=tracer)
    fe = AsyncSpikeFrontend(server, queue_capacity=8, clock=clock,
                            connector=InMemoryCarryConnector(),
                            tracer=tracer)
    spill, plain, victim, late = _rasters(rng, (10, 4, 6, 5),
                                          engine.n_inputs)
    a = fe.submit(spill, deadline_ms=1_000)   # parks mid-stream
    b = fe.submit(plain)                      # queued behind a
    c = fe.submit(victim)                     # cancelled while queued
    d = fe.submit(late, deadline_ms=1_500)    # expires while queued
    assert c.cancel() is True
    fe.pump()                                 # a runs 2 of 10 steps
    clock.t = 2.0                             # both deadlines pass
    fe.pump()
    assert a.state == "parked" and d.state == "expired"
    fe.drain()                                # b completes
    assert fe.resume(a) is True
    fe.drain()
    assert a.state == "done"

    rep = reconstruct(tracer)                 # raises on any violation
    outcomes = {h: rep.stream(h.rid, domain="request").outcome
                for h in (a, b, c, d)}
    assert outcomes == {a: "done", b: "done",
                        c: "cancelled", d: "expired"}
    spilled = rep.stream(a.rid, domain="request")
    assert spilled.n_parks == 1 and spilled.n_admissions == 2
    # every timeline closed legally: all four requests retired, plus
    # three server streams — b's, a's resumed incarnation (resume mints
    # a fresh server uid off the snapshot), and a's FIRST incarnation,
    # which legally ends 'parked' (its carry continued under the new
    # uid; the request domain is the continuous thread)
    assert rep.by_state() == {"retired": 6, "parked": 1}
