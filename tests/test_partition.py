"""Partitioner: logical-axis registry coverage + spec validity + roofline
HLO parsing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro import configs
from repro.configs.shapes import SHAPES, Shape
from repro.distributed import partition as part
from repro.launch.steps import LMHarness
from repro.models.common import AXES, axes_of
from repro.roofline import (
    collective_bytes_from_hlo, model_flops, roofline_terms,
)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", configs.list_archs())
def test_axes_registry_covers_every_param_leaf(arch):
    """Every parameter leaf must resolve to logical axes of matching rank —
    a missing AXES entry silently replicates a weight at 512 devices."""
    mod = configs.get_arch(arch)
    h = LMHarness(arch, cfg=mod.REDUCED)
    shapes = h.param_shapes()
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    for path, leaf in flat:
        key = "/".join(part._pstr(p) for p in path)
        axes = axes_of(key, leaf)
        assert len(axes) == leaf.ndim, (arch, key, leaf.shape, axes)
        if leaf.ndim >= 2 and min(leaf.shape) >= 8 and "norm" not in key:
            # big matrices must shard on at least one dim
            assert any(a is not None for a in axes), (arch, key)


def test_spec_for_divisibility_fallback():
    mesh = _mesh()
    rules = part.PartitionRules(
        rules={"heads": "model", "embed": "data"}, batch_axes=("data",))
    # size-1 axes -> everything replicates (single device)
    spec = part.spec_for(("embed", "heads"), (64, 64), mesh, rules)
    assert spec == PartitionSpec()


def test_spec_for_no_axis_reuse():
    mesh = jax.make_mesh((1,), ("model",))
    rules = part.PartitionRules(rules={"a": "model", "b": "model"})
    # both dims want 'model'; only one may take it (here size 1 -> neither)
    spec = part.spec_for(("a", "b"), (8, 8), mesh, rules)
    assert spec == PartitionSpec()


def test_batch_partition_shapes():
    mesh = _mesh()
    rules = part.PartitionRules.default(mesh)
    shapes = {
        "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
        "mrope_positions": jax.ShapeDtypeStruct((3, 8, 16), jnp.int32),
    }
    sh = part.batch_partition(shapes, mesh, rules)
    assert set(sh) == {"tokens", "mrope_positions"}


@pytest.mark.parametrize("arch", ["granite-3-2b", "zamba2-1.2b", "rwkv6-7b",
                                  "minicpm3-4b"])
def test_cache_partition_covers_cache_leaves(arch):
    mod = configs.get_arch(arch)
    h = LMHarness(arch, cfg=mod.REDUCED)
    mesh = _mesh()
    rules = part.PartitionRules.default(mesh)
    cache_shapes = jax.eval_shape(lambda: h.model.init_cache(4, 16))
    sh = part.cache_partition(cache_shapes, mesh, rules)
    assert (len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
            == len(jax.tree.leaves(cache_shapes)))


# ---------------------------------------------------------------------------
# Roofline helpers
# ---------------------------------------------------------------------------
HLO_SNIPPET = """
  %ar = f32[1024,256]{1,0} all-reduce(f32[1024,256]{1,0} %x), replica_groups={}
  %ag.1 = bf16[64,128]{1,0} all-gather(bf16[8,128]{1,0} %y), dimensions={0}
  %a2a = (f32[16]{0}, f32[16]{0}) all-to-all(f32[16]{0} %a, f32[16]{0} %b)
  %cp-start = bf16[32]{0} collective-permute-start(bf16[32]{0} %z)
  %rs = f32[128]{0} reduce-scatter(f32[1024]{0} %w), dimensions={0}
"""


def test_collective_bytes_parsing():
    out = collective_bytes_from_hlo(HLO_SNIPPET)
    assert out["counts"] == {"all-reduce": 1, "all-gather": 1,
                             "all-to-all": 1, "collective-permute": 1,
                             "reduce-scatter": 1}
    ar = 1024 * 256 * 4
    ag = 64 * 128 * 2
    a2a = 2 * 16 * 4
    cp = 32 * 2
    rs = 128 * 4
    assert out["bytes_by_kind"]["all-reduce"] == ar
    assert out["total_bytes"] == ar * 2 + ag + a2a + cp + rs


def test_roofline_terms_math():
    cfg = configs.get_arch("granite-3-2b").CONFIG
    shape = SHAPES["train_4k"]
    t = roofline_terms(flops_per_device=197e12, bytes_per_device=819e9,
                       collective_bytes_per_device=50e9, cfg=cfg,
                       shape=shape, n_chips=256)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    mf = model_flops(cfg, shape, 256)
    assert mf == pytest.approx(
        6.0 * cfg.active_param_count() * 4096 * 256)
    # decode counts one token per sequence
    dec = model_flops(cfg, SHAPES["decode_32k"], 256)
    assert dec == pytest.approx(2.0 * cfg.active_param_count() * 128)


def test_dominant_term_selection():
    cfg = configs.get_arch("granite-3-2b").CONFIG
    t = roofline_terms(flops_per_device=1e12, bytes_per_device=819e9 * 5,
                       collective_bytes_per_device=0.0, cfg=cfg,
                       shape=SHAPES["train_4k"], n_chips=256)
    assert t["dominant"] == "memory"
    assert t["bound_s"] == pytest.approx(5.0)
